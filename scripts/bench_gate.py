#!/usr/bin/env python3
"""Bench regression gate: diff the fresh BENCH_cluster.json against the
committed baseline.

Usage: bench_gate.py BASELINE.json FRESH.json [--tolerance 0.40]

Compares the DES throughput harness (`cluster/des_run_2cell`,
`sim_events_per_sec`). Fails (exit 1) when the fresh number is more than
`tolerance` *below* the baseline — a generous gate, because smoke-budget
numbers are noisy and CI runners vary. Speedups never fail; a speedup
beyond the tolerance prints a reminder to refresh the baseline.

A baseline marked `"provisional": true` (committed before any CI runner
measured it) reports the comparison but never fails: it seeds the perf
trajectory without enforcing numbers no machine has produced yet.
Refresh it with `repro bench --json --smoke` on a CI-class machine and
drop the flag to arm the gate.
"""

import json
import sys

DES_HARNESS = "cluster/des_run_2cell"
THROUGHPUT_UNIT = "sim_events_per_sec"


def des_events_per_sec(doc, path):
    for r in doc.get("results", []):
        if r.get("name") == DES_HARNESS:
            t = r.get("throughput") or {}
            if t.get("unit") != THROUGHPUT_UNIT:
                sys.exit(f"{path}: {DES_HARNESS} reports {t.get('unit')!r}, "
                         f"expected {THROUGHPUT_UNIT!r}")
            return float(t["value"])
    sys.exit(f"{path}: no {DES_HARNESS} result")


def main(argv):
    if len(argv) < 3:
        sys.exit(__doc__)
    baseline_path, fresh_path = argv[1], argv[2]
    tolerance = 0.40
    if "--tolerance" in argv:
        tolerance = float(argv[argv.index("--tolerance") + 1])

    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    base = des_events_per_sec(baseline, baseline_path)
    now = des_events_per_sec(fresh, fresh_path)
    ratio = now / base if base > 0 else float("inf")
    print(f"DES events/sec: baseline {base:,.0f} -> fresh {now:,.0f} "
          f"(x{ratio:.2f}, gate: >= x{1.0 - tolerance:.2f})")

    if baseline.get("provisional"):
        print("baseline is provisional (never measured on a CI runner): "
              "reporting only, not gating. Refresh it with "
              "`repro bench --json --smoke` and drop the flag to arm the gate.")
        return 0
    if ratio < 1.0 - tolerance:
        print(f"FAIL: DES throughput regressed more than {tolerance:.0%}")
        return 1
    if ratio > 1.0 + tolerance:
        print(f"note: DES throughput improved more than {tolerance:.0%} — "
              "consider refreshing the committed baseline")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
