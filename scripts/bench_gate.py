#!/usr/bin/env python3
"""Bench regression gate: diff a fresh BENCH_cluster.json against a
baseline.

Usage: bench_gate.py [--report-only] BASELINE.json FRESH.json
                     [--tolerance 0.40]
       bench_gate.py --ratchet BASELINE.json FRESH.json

Gates on the DES throughput harnesses (`sim_events_per_sec`): exit 1
when a fresh number is more than `tolerance` *below* the baseline — a
generous gate, because smoke-budget numbers are noisy and CI runners
vary. Speedups never fail; a speedup beyond the tolerance prints a
reminder to refresh the baseline. Every other harness's mean_ns is
reported alongside for context (not gated).

Two DES harnesses are gated when present: the serial
`cluster/des_run_2cell` (always) and the sharded
`cluster/des_run_8cell_sharded` (skipped against baselines that predate
it, so the window self-heals across the schema change). On runners with
>= 4 cores the sharded/serial events-per-sec ratio of the *fresh* doc is
additionally held to a speedup floor: x1.5 on full-budget runs, relaxed
to x1.1 for smoke budgets (a few hundred simulated events barely
amortize worker spawn, but parallelism must still win).

The gate disarms (prints the comparison, always exits 0) when either:

* `--report-only` is passed — CI uses this for the bootstrap path,
  where a runner with no CI-measured baseline compares against the
  committed `BENCH_cluster.json` seed. Baselines measured on other
  hardware (a laptop, a different runner class) must never hard-fail
  the build, whatever their provisional flag says.
* the baseline is marked `"provisional": true` — the hand-seeded file
  committed before any machine measured it.

Armed gating happens in CI against a rolling actions cache of recent
main-branch measured runs (`repro bench --json` writes
`"provisional": false`). `--ratchet` maintains that cache: it appends
FRESH to a window of the last 5 runs (history-*.json next to BASELINE)
and rewrites BASELINE as the window's *median* by DES events/sec. The
median damps both failure modes of a single-run baseline: one lucky
fast run cannot pin the gate at max-of-noise (it is outvoted by the
window), and one slow run cannot drag the baseline down, so
sub-tolerance regressions only move the gate after they persist across
a majority of the window.
"""

import json
import os
import sys

DES_HARNESS = "cluster/des_run_2cell"
SERIAL_8CELL_HARNESS = "cluster/des_run_8cell"
SHARDED_HARNESS = "cluster/des_run_8cell_sharded"
THROUGHPUT_UNIT = "sim_events_per_sec"
SPEEDUP_FLOOR = 1.5
SPEEDUP_FLOOR_SMOKE = 1.1
SPEEDUP_MIN_CORES = 4


def des_events_per_sec(doc, path):
    for r in doc.get("results", []):
        if r.get("name") == DES_HARNESS:
            t = r.get("throughput") or {}
            if t.get("unit") != THROUGHPUT_UNIT:
                sys.exit(f"{path}: {DES_HARNESS} reports {t.get('unit')!r}, "
                         f"expected {THROUGHPUT_UNIT!r}")
            return float(t["value"])
    sys.exit(f"{path}: no {DES_HARNESS} result")


def opt_events_per_sec(doc, name):
    """Events/sec of a named harness, or None when the doc predates it.
    Older baselines in the rolling cache lack the sharded twins; they
    must report-and-skip, never fail."""
    for r in doc.get("results", []):
        if r.get("name") == name:
            t = r.get("throughput") or {}
            if t.get("unit") == THROUGHPUT_UNIT:
                return float(t["value"])
    return None


def report_harness_deltas(baseline, fresh):
    """Per-harness mean_ns context (informational, never gated)."""
    base_by_name = {r.get("name"): r for r in baseline.get("results", [])}
    for r in fresh.get("results", []):
        name = r.get("name")
        b = base_by_name.get(name)
        if not b or not b.get("mean_ns") or not r.get("mean_ns"):
            continue
        ratio = float(r["mean_ns"]) / float(b["mean_ns"])
        print(f"  {name}: mean {b['mean_ns']:,.0f} ns -> {r['mean_ns']:,.0f} ns "
              f"(x{ratio:.2f})")


WINDOW = 5


def try_des_events_per_sec(path):
    """DES events/sec of a history file, or None for any file this
    version of the script cannot read (older schema, corrupt JSON, …).
    The window must self-heal across schema changes, never strand CI."""
    try:
        with open(path) as f:
            doc = json.load(f)
        for r in doc.get("results", []):
            if r.get("name") == DES_HARNESS:
                t = r.get("throughput") or {}
                if t.get("unit") == THROUGHPUT_UNIT:
                    return float(t["value"])
        return None
    except (OSError, ValueError, TypeError):
        return None


def ratchet(baseline_path, fresh_path):
    """Fold FRESH into the history window; BASELINE becomes the median."""
    import glob
    import os
    import shutil
    base_dir = os.path.dirname(baseline_path) or "."
    os.makedirs(base_dir, exist_ok=True)
    history = sorted(glob.glob(os.path.join(base_dir, "history-*.json")))
    next_idx = 0
    if history:
        next_idx = int(history[-1].rsplit("-", 1)[1].split(".")[0]) + 1
    shutil.copyfile(fresh_path,
                    os.path.join(base_dir, f"history-{next_idx:06d}.json"))
    history = sorted(glob.glob(os.path.join(base_dir, "history-*.json")))
    for stale in history[:-WINDOW]:
        os.remove(stale)
    history = history[-WINDOW:]

    rates = []
    for p in history:
        v = try_des_events_per_sec(p)
        if v is None:
            print(f"ratchet: dropping unreadable window entry {p} "
                  "(older schema or corrupt)")
            os.remove(p)
            continue
        rates.append((v, p))
    if not rates:
        sys.exit(f"ratchet: no readable run in the window, including "
                 f"the fresh {fresh_path}")
    rates.sort()
    median_rate, median_path = rates[(len(rates) - 1) // 2]
    shutil.copyfile(median_path, baseline_path)
    print(f"ratchet: window of {len(rates)} run(s) "
          f"[{rates[0][0]:,.0f} .. {rates[-1][0]:,.0f}] events/sec; "
          f"baseline <- median {median_rate:,.0f}")
    return 0


def main(argv):
    args = list(argv[1:])
    if "--ratchet" in args:
        args.remove("--ratchet")
        if len(args) < 2:
            sys.exit(__doc__)
        return ratchet(args[0], args[1])
    report_only = "--report-only" in args
    if report_only:
        args.remove("--report-only")
    tolerance = 0.40
    if "--tolerance" in args:
        i = args.index("--tolerance")
        tolerance = float(args[i + 1])
        del args[i:i + 2]
    if len(args) < 2:
        sys.exit(__doc__)
    baseline_path, fresh_path = args[0], args[1]

    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    report_harness_deltas(baseline, fresh)
    base = des_events_per_sec(baseline, baseline_path)
    now = des_events_per_sec(fresh, fresh_path)
    ratio = now / base if base > 0 else float("inf")
    print(f"DES events/sec: baseline {base:,.0f} -> fresh {now:,.0f} "
          f"(x{ratio:.2f}, gate: >= x{1.0 - tolerance:.2f})")

    sharded_base = opt_events_per_sec(baseline, SHARDED_HARNESS)
    sharded_now = opt_events_per_sec(fresh, SHARDED_HARNESS)
    serial8_now = opt_events_per_sec(fresh, SERIAL_8CELL_HARNESS)
    sharded_ratio = None
    if sharded_now is not None and sharded_base is not None:
        sharded_ratio = (sharded_now / sharded_base if sharded_base > 0
                         else float("inf"))
        print(f"sharded DES events/sec: baseline {sharded_base:,.0f} -> "
              f"fresh {sharded_now:,.0f} (x{sharded_ratio:.2f}, "
              f"gate: >= x{1.0 - tolerance:.2f})")
    elif sharded_now is not None:
        print(f"sharded DES events/sec: fresh {sharded_now:,.0f} "
              "(baseline predates the sharded harness; regression gate "
              "skipped this run)")
    speedup = None
    speedup_floor = (SPEEDUP_FLOOR_SMOKE if fresh.get("smoke")
                     else SPEEDUP_FLOOR)
    cores = os.cpu_count() or 1
    if sharded_now is not None and serial8_now:
        speedup = sharded_now / serial8_now
        armed = "armed" if cores >= SPEEDUP_MIN_CORES else (
            f"disarmed: {cores} cores < {SPEEDUP_MIN_CORES}")
        print(f"sharding speedup: x{speedup:.2f} events/sec over the "
              f"serial 8-cell twin (floor x{speedup_floor:.1f}, {armed})")

    if report_only:
        print("report-only mode (bootstrap baseline from another machine): "
              "not gating. The main-branch baseline cache arms the gate.")
        return 0
    if baseline.get("provisional"):
        print("baseline is provisional (never measured on a CI runner): "
              "reporting only, not gating. The first measured main run arms "
              "the gate via the CI baseline cache.")
        return 0
    failed = False
    if ratio < 1.0 - tolerance:
        print(f"FAIL: DES throughput regressed more than {tolerance:.0%} "
              f"vs the measured baseline")
        failed = True
    if sharded_ratio is not None and sharded_ratio < 1.0 - tolerance:
        print(f"FAIL: sharded DES throughput regressed more than "
              f"{tolerance:.0%} vs the measured baseline")
        failed = True
    if (speedup is not None and cores >= SPEEDUP_MIN_CORES
            and speedup < speedup_floor):
        print(f"FAIL: sharding speedup x{speedup:.2f} is below the "
              f"x{speedup_floor:.1f} floor on a {cores}-core runner")
        failed = True
    if failed:
        return 1
    if ratio > 1.0 + tolerance:
        print(f"note: DES throughput improved more than {tolerance:.0%} — "
              "consider refreshing the baseline")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
