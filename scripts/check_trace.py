#!/usr/bin/env python3
"""Validate `repro trace` artifacts.

Usage: check_trace.py [--expect-faults] [--expect-depletion] TRACE.json [TIMELINE.csv]

Checks the Chrome trace-event JSON the telemetry layer exports:

* the document parses and has a non-empty `traceEvents` array;
* every `B` (duration begin) is closed by a matching `E` on the same
  `(pid, tid)` lane, stack-balanced;
* every async `b` has exactly one `e` with the same id;
* timestamps are monotone non-decreasing per lane (the exporter sorts
  ends before instants before begins at equal timestamps);
* every lane an event uses carries `thread_name` metadata.

And, when given, the timeline CSV:

* the pinned header;
* sample times strictly increasing per cell;
* finite, non-negative backlog/utilization and drop_rate in [0, 1];
* battery_min finite and in [0, 1].

With `--expect-faults`, additionally require the trace to carry the
fault-injection lanes: at least one event in the "fault" category
(device_crash / device_recover / slowdown / backhaul / redispatch /
battery_depleted) and, if hedging fired, matching "hedge" events —
CI's chaos smoke uses this to prove the fault plan actually reached
the artifact.

With `--expect-depletion`, require the energy story to reach both
artifacts: at least one battery_depleted instant in the trace, and a
battery_min timeline value that actually drains below 1.0 — CI's
energy smoke uses this to prove battery churn fired.

Exits non-zero with a message on the first violation — CI runs this
against a fresh `repro trace` smoke artifact.
"""

import csv
import json
import math
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}")
    sys.exit(1)


FAULT_NAMES = {
    "device_crash",
    "device_recover",
    "slowdown",
    "backhaul",
    "redispatch",
    "battery_depleted",
}


def check_trace(path, expect_faults=False, expect_depletion=False):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")

    depth = {}          # lane -> open B count
    last_ts = {}        # lane -> last timestamp seen
    open_async = {}     # id -> open b count
    named_lanes = set()
    counts = {}
    cat_counts = {}     # category -> event count (fault/hedge lanes)
    for i, e in enumerate(events):
        ph = e.get("ph")
        counts[ph] = counts.get(ph, 0) + 1
        cat = e.get("cat")
        if cat:
            cat_counts[cat] = cat_counts.get(cat, 0) + 1
        if ph == "M":
            if e.get("name") == "thread_name":
                named_lanes.add((e.get("pid"), e.get("tid")))
            continue
        lane = (e.get("pid"), e.get("tid"))
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            fail(f"{path}: event {i} has no numeric ts")
        if ts < last_ts.get(lane, float("-inf")):
            fail(f"{path}: lane {lane} ts {ts} after {last_ts[lane]}")
        last_ts[lane] = ts
        if lane not in named_lanes:
            fail(f"{path}: lane {lane} used before thread_name metadata")
        if ph == "B":
            depth[lane] = depth.get(lane, 0) + 1
        elif ph == "E":
            depth[lane] = depth.get(lane, 0) - 1
            if depth[lane] < 0:
                fail(f"{path}: lane {lane} has E with no open B")
        elif ph == "b":
            aid = e.get("id")
            if aid is None:
                fail(f"{path}: event {i} is 'b' without an id")
            open_async[aid] = open_async.get(aid, 0) + 1
        elif ph == "e":
            aid = e.get("id")
            if aid not in open_async:
                fail(f"{path}: 'e' id {aid} was never opened")
            open_async[aid] -= 1
            if open_async[aid] != 0:
                fail(f"{path}: async id {aid} closed more than once")
        elif ph != "i":
            fail(f"{path}: unexpected phase {ph!r}")
    for lane, d in depth.items():
        if d != 0:
            fail(f"{path}: lane {lane} has {d} unclosed B span(s)")
    for aid, c in open_async.items():
        if c != 0:
            fail(f"{path}: async span {aid} never closed")
    if counts.get("B", 0) == 0:
        fail(f"{path}: no duration spans at all")
    fault_names = {
        e.get("name", "").split()[0]
        for e in events
        if e.get("cat") == "fault" and e.get("name")
    }
    if expect_faults:
        n_fault = cat_counts.get("fault", 0)
        if n_fault == 0:
            fail(f"{path}: --expect-faults, but no 'fault'-category events")
        if not fault_names & FAULT_NAMES:
            fail(f"{path}: fault events carry unrecognized names: {sorted(fault_names)}")
        n_hedge = cat_counts.get("hedge", 0)
        print(f"check_trace: {path} fault lanes OK — {n_fault} fault, {n_hedge} hedge")
    if expect_depletion:
        n_depleted = sum(
            1
            for e in events
            if e.get("cat") == "fault"
            and e.get("name", "").split()[0] == "battery_depleted"
        )
        if n_depleted == 0:
            fail(f"{path}: --expect-depletion, but no battery_depleted events")
        print(f"check_trace: {path} energy lane OK — {n_depleted} battery_depleted")
    print(
        f"check_trace: {path} OK — "
        + ", ".join(f"{counts.get(p, 0)} {p}" for p in ["M", "B", "E", "b", "e", "i"])
    )


TIMELINE_HEADER = [
    "t_s",
    "cell",
    "backlog_s",
    "utilization",
    "drop_rate",
    "live_replicas",
    "online_devices",
    "degraded_devices",
    "battery_min",
]


def check_timeline(path, expect_depletion=False):
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    if not rows or rows[0] != TIMELINE_HEADER:
        fail(f"{path}: header mismatch: {rows[0] if rows else 'empty file'}")
    if len(rows) < 2:
        fail(f"{path}: no samples")
    last_t = {}
    battery_floor = 1.0
    for i, row in enumerate(rows[1:], start=2):
        t, cell = float(row[0]), int(row[1])
        backlog, util, drop = float(row[2]), float(row[3]), float(row[4])
        battery = float(row[8])
        if cell in last_t and t <= last_t[cell]:
            fail(f"{path}:{i}: cell {cell} t {t} not after {last_t[cell]}")
        last_t[cell] = t
        for name, v in [("backlog_s", backlog), ("utilization", util)]:
            if not math.isfinite(v) or v < 0.0:
                fail(f"{path}:{i}: {name} = {v}")
        if not 0.0 <= drop <= 1.0:
            fail(f"{path}:{i}: drop_rate = {drop}")
        if not (math.isfinite(battery) and 0.0 <= battery <= 1.0):
            fail(f"{path}:{i}: battery_min = {battery}")
        battery_floor = min(battery_floor, battery)
    if expect_depletion and battery_floor >= 1.0:
        fail(f"{path}: --expect-depletion, but battery_min never dropped below 1.0")
    print(f"check_trace: {path} OK — {len(rows) - 1} samples, {len(last_t)} cells")


def main():
    args = sys.argv[1:]
    expect_faults = "--expect-faults" in args
    expect_depletion = "--expect-depletion" in args
    args = [a for a in args if a not in ("--expect-faults", "--expect-depletion")]
    if len(args) < 1 or len(args) > 2:
        print(__doc__)
        sys.exit(2)
    check_trace(args[0], expect_faults=expect_faults, expect_depletion=expect_depletion)
    if len(args) == 2:
        check_timeline(args[1], expect_depletion=expect_depletion)


if __name__ == "__main__":
    main()
