"""AOT pipeline integrity: lowering, manifest, and weight serialisation.

Checks the build-time contract consumed by the rust runtime: every entry
point lowers to parseable HLO text with ENTRY + tuple root, the manifest
indexes weights.bin correctly, and shapes agree between manifest and model.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.ModelConfig(
    vocab=64, d_model=16, d_hidden=32, n_experts=4, n_heads=2, n_blocks=2, seq_len=32
)


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.emit(CFG, out, seed=0)
    return out, manifest


class TestLowering:
    def test_all_entry_points_emitted(self, emitted):
        out, manifest = emitted
        expected = {
            "embed",
            "attention",
            "gate",
            "expert",
            "expert_normed",
            "experts_stacked",
            "combine",
            "lm_head",
        }
        assert set(manifest["artifacts"]) == expected
        for name in expected:
            assert os.path.exists(os.path.join(out, f"{name}.hlo.txt"))

    def test_hlo_text_is_parseable_hlo(self, emitted):
        out, manifest = emitted
        for name, meta in manifest["artifacts"].items():
            text = open(os.path.join(out, meta["file"])).read()
            assert "ENTRY" in text, f"{name}: no ENTRY computation"
            assert "HloModule" in text, f"{name}: not HLO text"

    def test_return_tuple_lowering(self, emitted):
        """Root must be a tuple — the rust side unwraps with to_tuple1."""
        out, manifest = emitted
        for name, meta in manifest["artifacts"].items():
            text = open(os.path.join(out, meta["file"])).read()
            entry = text.split("ENTRY")[-1]
            root = [l for l in entry.splitlines() if "ROOT" in l]
            assert root and "tuple(" in root[0].replace(") ", "("), (
                f"{name}: ROOT is not a tuple: {root}"
            )

    def test_arg_signatures_match_model(self, emitted):
        _, manifest = emitted
        eps = aot.entry_points(CFG)
        for name, meta in manifest["artifacts"].items():
            want = [list(a.shape) for a in eps[name][1]]
            got = [a["shape"] for a in meta["args"]]
            assert got == want, f"{name}: {got} != {want}"


class TestWeights:
    def test_weights_roundtrip(self, emitted):
        """weights.bin + manifest reconstructs init_weights exactly."""
        out, manifest = emitted
        blob = np.fromfile(os.path.join(out, "weights.bin"), dtype="<f4")
        ref = M.init_weights(CFG, seed=0)
        assert len(manifest["weights"]["tensors"]) == len(ref)
        for t in manifest["weights"]["tensors"]:
            size = int(np.prod(t["shape"]))
            got = blob[t["offset"] : t["offset"] + size].reshape(t["shape"])
            np.testing.assert_array_equal(got, np.asarray(ref[t["name"]]))

    def test_offsets_contiguous_sorted(self, emitted):
        _, manifest = emitted
        off = 0
        names = []
        for t in manifest["weights"]["tensors"]:
            assert t["offset"] == off
            off += int(np.prod(t["shape"]))
            names.append(t["name"])
        assert names == sorted(names)

    def test_manifest_config_roundtrip(self, emitted):
        _, manifest = emitted
        c = manifest["config"]
        assert c["d_model"] == CFG.d_model
        assert c["n_experts"] == CFG.n_experts
        assert c["total_params"] == CFG.total_params

    def test_manifest_json_valid(self, emitted):
        out, _ = emitted
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["weights"]["dtype"] == "f32"
