"""L2 correctness: model entry points compose, shapes hold, routing behaves.

These tests validate the *composition* the rust coordinator performs —
attention → gate → expert → combine equals the fused block_dense oracle —
plus the robustness property the paper relies on (§IV-A: "MoE-based LLMs
are highly robust, even when expert selection deviates from the trained
gating network's outputs").
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(
    vocab=128, d_model=32, d_hidden=64, n_experts=4, n_heads=4, n_blocks=2, seq_len=64
)


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, seed=0)


@pytest.fixture(scope="module")
def ids():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.seq_len,), 0, CFG.vocab)


class TestEntryPoints:
    def test_embed_shape(self, weights, ids):
        x = M.embed(ids, weights["emb"])[0]
        assert x.shape == (CFG.seq_len, CFG.d_model)

    def test_attention_residual(self, weights, ids):
        """Zero projections leave the residual stream untouched."""
        x = M.embed(ids, weights["emb"])[0]
        z = jnp.zeros((CFG.d_model, CFG.d_model))
        out = M.attention_block(x, weights["blk0.attn.gamma"], z, z, z, z, num_heads=CFG.n_heads)[0]
        np.testing.assert_allclose(out, x, rtol=1e-6)

    def test_gate_is_distribution(self, weights, ids):
        x = M.embed(ids, weights["emb"])[0]
        w = M.gate(x, weights["blk0.moe.gamma"], weights["blk0.moe.wg"])[0]
        assert w.shape == (CFG.seq_len, CFG.n_experts)
        np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)

    def test_expert_output_shape_preserved(self, weights, ids):
        """Paper §III-A: uplink size == downlink size (same tensor shape)."""
        x = M.embed(ids, weights["emb"])[0]
        y = M.expert(x, weights["blk0.expert0.w1"], weights["blk0.expert0.w3"], weights["blk0.expert0.w2"])[0]
        assert y.shape == x.shape

    def test_expert_normed_equals_norm_then_expert(self, weights, ids):
        x = M.embed(ids, weights["emb"])[0]
        g = weights["blk0.moe.gamma"]
        e = ("blk0.expert0.w1", "blk0.expert0.w3", "blk0.expert0.w2")
        direct = M.expert_normed(x, g, *(weights[k] for k in e))[0]
        manual = M.expert(ref.rms_norm(x, g), *(weights[k] for k in e))[0]
        np.testing.assert_allclose(direct, manual, rtol=1e-5, atol=1e-6)

    def test_experts_stacked_matches_per_expert(self, weights, ids):
        """The fused all-experts entry point equals n expert_normed calls."""
        x = M.embed(ids, weights["emb"])[0]
        g = weights["blk0.moe.gamma"]
        w1s = jnp.stack([weights[f"blk0.expert{e}.w1"] for e in range(CFG.n_experts)])
        w3s = jnp.stack([weights[f"blk0.expert{e}.w3"] for e in range(CFG.n_experts)])
        w2s = jnp.stack([weights[f"blk0.expert{e}.w2"] for e in range(CFG.n_experts)])
        fused = M.experts_stacked(x, g, w1s, w3s, w2s)[0]
        assert fused.shape == (CFG.n_experts, CFG.seq_len, CFG.d_model)
        for e in range(CFG.n_experts):
            single = M.expert_normed(
                x, g,
                weights[f"blk0.expert{e}.w1"],
                weights[f"blk0.expert{e}.w3"],
                weights[f"blk0.expert{e}.w2"],
            )[0]
            np.testing.assert_allclose(fused[e], single, rtol=2e-5, atol=2e-5)

    def test_lm_head_shape(self, weights, ids):
        x = M.embed(ids, weights["emb"])[0]
        logits = M.lm_head(x, weights["final.gamma"], weights["emb"])[0]
        assert logits.shape == (CFG.seq_len, CFG.vocab)


class TestComposition:
    def test_split_path_equals_dense_block(self, weights, ids):
        """The coordinator's 4-artifact path == the fused block oracle.

        This is the contract the rust dispatch loop depends on: running
        attention, gate, per-expert FFN and combine as separate executables
        must reproduce block_dense bit-for-bit (up to f32 tolerance).
        """
        i = 0
        x = M.embed(ids, weights["emb"])[0]
        # -- split path (what rust does)
        h = M.attention_block(
            x,
            weights[f"blk{i}.attn.gamma"],
            weights[f"blk{i}.attn.wq"],
            weights[f"blk{i}.attn.wk"],
            weights[f"blk{i}.attn.wv"],
            weights[f"blk{i}.attn.wo"],
            num_heads=CFG.n_heads,
        )[0]
        w = M.gate(h, weights[f"blk{i}.moe.gamma"], weights[f"blk{i}.moe.wg"])[0]
        mask = ref.top_k_mask(w, CFG.top_k).astype(jnp.float32)
        outs = jnp.stack(
            [
                M.expert_normed(
                    h,
                    weights[f"blk{i}.moe.gamma"],
                    weights[f"blk{i}.expert{e}.w1"],
                    weights[f"blk{i}.expert{e}.w3"],
                    weights[f"blk{i}.expert{e}.w2"],
                )[0]
                for e in range(CFG.n_experts)
            ]
        )
        split = M.combine(h, w, mask, outs)[0]
        # -- fused oracle
        w1s = jnp.stack([weights[f"blk{i}.expert{e}.w1"] for e in range(CFG.n_experts)])
        w3s = jnp.stack([weights[f"blk{i}.expert{e}.w3"] for e in range(CFG.n_experts)])
        w2s = jnp.stack([weights[f"blk{i}.expert{e}.w2"] for e in range(CFG.n_experts)])
        fused = M.block_dense(
            x,
            weights[f"blk{i}.attn.gamma"],
            weights[f"blk{i}.attn.wq"],
            weights[f"blk{i}.attn.wk"],
            weights[f"blk{i}.attn.wv"],
            weights[f"blk{i}.attn.wo"],
            weights[f"blk{i}.moe.gamma"],
            weights[f"blk{i}.moe.wg"],
            w1s,
            w3s,
            w2s,
            num_heads=CFG.n_heads,
            top_k=CFG.top_k,
        )[0]
        np.testing.assert_allclose(split, fused, rtol=2e-4, atol=2e-4)

    def test_forward_dense_finite(self, weights, ids):
        logits = M.forward_dense(ids, weights, CFG)
        assert logits.shape == (CFG.seq_len, CFG.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_forward_deterministic(self, weights, ids):
        a = M.forward_dense(ids, weights, CFG)
        b = M.forward_dense(ids, weights, CFG)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestRoutingRobustness:
    """The paper's core empirical premise: dropping the lowest-weight expert
    of the top-2 perturbs outputs only mildly (§IV-A)."""

    def test_top1_close_to_top2(self, weights, ids):
        x = M.embed(ids, weights["emb"])[0]
        h = M.attention_block(
            x,
            weights["blk0.attn.gamma"],
            weights["blk0.attn.wq"],
            weights["blk0.attn.wk"],
            weights["blk0.attn.wv"],
            weights["blk0.attn.wo"],
            num_heads=CFG.n_heads,
        )[0]
        w = M.gate(h, weights["blk0.moe.gamma"], weights["blk0.moe.wg"])[0]
        outs = jnp.stack(
            [
                M.expert_normed(
                    h,
                    weights["blk0.moe.gamma"],
                    weights[f"blk0.expert{e}.w1"],
                    weights[f"blk0.expert{e}.w3"],
                    weights[f"blk0.expert{e}.w2"],
                )[0]
                for e in range(CFG.n_experts)
            ]
        )
        o2 = M.combine(h, w, ref.top_k_mask(w, 2).astype(jnp.float32), outs)[0]
        o1 = M.combine(h, w, ref.top_k_mask(w, 1).astype(jnp.float32), outs)[0]
        # A trained router is sharp (top-1 weight >> top-2), making the
        # perturbation small; a random-init router is near-uniform, the
        # worst case for this property. Even then the streams must remain
        # strongly aligned — direction is what downstream blocks consume.
        cos = float(
            jnp.sum(o1 * o2) / (jnp.linalg.norm(o1) * jnp.linalg.norm(o2))
        )
        assert cos > 0.75, f"top-1 output decorrelates from top-2: cos={cos:.3f}"
        assert np.isfinite(np.asarray(o1)).all()


class TestConfig:
    def test_param_count(self):
        w = M.init_weights(CFG, seed=0)
        total = sum(int(np.prod(a.shape)) for a in w.values())
        assert total == CFG.total_params

    def test_seed_determinism(self):
        a = M.init_weights(CFG, seed=3)
        b = M.init_weights(CFG, seed=3)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    def test_seed_sensitivity(self):
        a = M.init_weights(CFG, seed=3)["emb"]
        b = M.init_weights(CFG, seed=4)["emb"]
        assert not np.allclose(np.asarray(a), np.asarray(b))
