"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.py — the core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import gating as gate_k
from compile.kernels import moe_ffn as ffn_k
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

# Deadline off: first call per shape JIT-compiles, which trips hypothesis'
# per-example timing otherwise.
HSET = settings(max_examples=12, deadline=None)


def rand(key, shape, scale=0.1, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- moe_ffn


class TestExpertFfn:
    @HSET
    @given(
        j=st.sampled_from([8, 64, 128, 256]),
        m=st.sampled_from([16, 64, 128]),
        mh=st.sampled_from([32, 128, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, j, m, mh, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        x = rand(ks[0], (j, m), 1.0)
        w1, w3 = rand(ks[1], (m, mh)), rand(ks[2], (m, mh))
        w2 = rand(ks[3], (mh, m))
        got = ffn_k.expert_ffn(x, w1, w3, w2)
        want = ref.expert_ffn(x, w1, w3, w2)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_multi_tile_accumulation(self):
        """mh spanning several bh tiles exercises the accumulator path."""
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        j, m, mh = 128, 64, 512  # 4 hidden tiles at bh=128
        x = rand(ks[0], (j, m), 1.0)
        w1, w3, w2 = rand(ks[1], (m, mh)), rand(ks[2], (m, mh)), rand(ks[3], (mh, m))
        got = ffn_k.expert_ffn(x, w1, w3, w2, tiling=ffn_k.FfnTiling(bj=64, bh=128))
        np.testing.assert_allclose(got, ref.expert_ffn(x, w1, w3, w2), rtol=3e-5, atol=3e-5)

    def test_bad_tiling_raises(self):
        x = jnp.zeros((100, 16))
        w = jnp.zeros((16, 96))
        w2 = jnp.zeros((96, 16))
        with pytest.raises(ValueError, match="must divide"):
            ffn_k.expert_ffn(x, w, w, w2, tiling=ffn_k.FfnTiling(bj=64, bh=64))

    def test_zero_input_gives_zero(self):
        x = jnp.zeros((64, 32))
        w1 = jnp.ones((32, 128)) * 0.1
        w3 = jnp.ones((32, 128)) * 0.1
        w2 = jnp.ones((128, 32)) * 0.1
        out = ffn_k.expert_ffn(x, w1, w3, w2)
        np.testing.assert_allclose(out, jnp.zeros_like(x), atol=1e-7)

    def test_flops_matches_eq5(self):
        """Eq. (5): L_comp = 4·m·mh + 2·mh·m + η·mh + mh per token."""
        m, mh, eta = 256, 512, 7
        assert ffn_k.flops(1, m, mh, eta) == 4 * m * mh + 2 * mh * m + eta * mh + mh
        assert ffn_k.flops(10, m, mh, eta) == 10 * ffn_k.flops(1, m, mh, eta)

    def test_vmem_budget(self):
        """Default tiling for the shipped config fits a 16 MiB VMEM budget."""
        assert ffn_k.vmem_bytes(256, 512) < 16 * 1024 * 1024

    def test_mxu_estimate_full_tiles(self):
        u = ffn_k.mxu_utilization_estimate(256, 512, ffn_k.FfnTiling(128, 128))
        assert u == pytest.approx(1.0)
        u_small = ffn_k.mxu_utilization_estimate(256, 512, ffn_k.FfnTiling(8, 128))
        assert u_small < 0.1


# ---------------------------------------------------------------- gating


class TestGating:
    @HSET
    @given(
        j=st.sampled_from([8, 64, 128, 256]),
        m=st.sampled_from([16, 64, 256]),
        n=st.sampled_from([2, 4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, j, m, n, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = rand(ks[0], (j, m), 1.0)
        wg = rand(ks[1], (m, n))
        got = gate_k.gating(x, wg)
        np.testing.assert_allclose(got, ref.gating(x, wg), rtol=1e-5, atol=1e-6)

    @HSET
    @given(j=st.sampled_from([8, 128]), seed=st.integers(0, 2**31 - 1))
    def test_rows_sum_to_one(self, j, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = rand(ks[0], (j, 32), 1.0)
        wg = rand(ks[1], (32, 8))
        w = gate_k.gating(x, wg)
        np.testing.assert_allclose(w.sum(-1), np.ones(j), rtol=1e-5)
        assert (np.asarray(w) >= 0).all()

    def test_large_logits_stable(self):
        """Softmax stability: huge logits must not produce NaN/inf."""
        x = jnp.full((8, 16), 100.0)
        wg = jnp.eye(16)[:, :8] * 100.0
        w = gate_k.gating(x, wg)
        assert np.isfinite(np.asarray(w)).all()

    def test_too_many_experts_raises(self):
        with pytest.raises(ValueError, match="exceeds"):
            gate_k.gating(jnp.zeros((8, 16)), jnp.zeros((16, 200)))


# ---------------------------------------------------------------- attention


class TestAttention:
    @HSET
    @given(
        j=st.sampled_from([64, 128, 256]),
        m=st.sampled_from([32, 64]),
        h=st.sampled_from([2, 4, 8]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, j, m, h, causal, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = rand(ks[0], (j, m), 1.0)
        wq, wk, wv, wo = (rand(k, (m, m)) for k in ks[1:])
        got = attn_k.attention(x, wq, wk, wv, wo, num_heads=h, bq=64, bk=64, causal=causal)
        want = ref.attention(x, wq, wk, wv, wo, h, causal=causal)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_causality(self):
        """Perturbing a future token must not change earlier outputs."""
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        j, m = 128, 32
        x = rand(ks[0], (j, m), 1.0)
        wq, wk, wv = (rand(k, (m, m)) for k in ks[1:4])
        wo = jnp.eye(m)
        base = attn_k.attention(x, wq, wk, wv, wo, num_heads=4, bq=64, bk=64)
        x2 = x.at[-1].add(10.0)
        pert = attn_k.attention(x2, wq, wk, wv, wo, num_heads=4, bq=64, bk=64)
        np.testing.assert_allclose(base[:-1], pert[:-1], rtol=1e-5, atol=1e-5)

    def test_single_tile(self):
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        j, m = 64, 64
        x = rand(ks[0], (j, m), 1.0)
        wq, wk, wv, wo = (rand(k, (m, m)) for k in ks[1:])
        got = attn_k.attention(x, wq, wk, wv, wo, num_heads=8)
        np.testing.assert_allclose(got, ref.attention(x, wq, wk, wv, wo, 8), rtol=3e-4, atol=3e-4)

    def test_bad_tiles_raise(self):
        with pytest.raises(ValueError, match="multiple"):
            attn_k.attention(
                jnp.zeros((100, 32)), *(jnp.zeros((32, 32)),) * 4, num_heads=4, bq=64, bk=64
            )


# ------------------------------------------------------------ combine/topk


class TestCombine:
    @HSET
    @given(
        j=st.sampled_from([4, 16, 64]),
        n=st.sampled_from([4, 8]),
        k=st.sampled_from([1, 2, 3]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_top_k_mask_selects_k(self, j, n, k, seed):
        w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed), (j, n)), -1)
        mask = ref.top_k_mask(w, k)
        # Random gaussians make ties measure-zero: exactly k per row.
        assert (np.asarray(mask).sum(-1) == k).all()
        # Masked weights dominate unmasked ones per row.
        wm = np.where(np.asarray(mask), np.asarray(w), np.inf).min(-1)
        wu = np.where(~np.asarray(mask), np.asarray(w), -np.inf).max(-1)
        assert (wm >= wu).all()

    def test_combine_renormalises(self):
        """With identical expert outputs, combine is mask-invariant."""
        j, n, m = 8, 4, 16
        w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (j, n)), -1)
        y = jnp.broadcast_to(jnp.arange(m, dtype=jnp.float32), (n, j, m))
        full = ref.moe_combine(w, jnp.ones((j, n)), y)
        top1 = ref.moe_combine(w, ref.top_k_mask(w, 1), y)
        np.testing.assert_allclose(full, top1, rtol=1e-5)

    def test_combine_empty_mask_is_zero(self):
        """A fully-dropped token contributes zero (guard against 0/0)."""
        j, n, m = 4, 4, 8
        w = jnp.full((j, n), 0.25)
        y = jnp.ones((n, j, m))
        out = ref.moe_combine(w, jnp.zeros((j, n)), y)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, np.zeros((j, m)), atol=1e-6)
