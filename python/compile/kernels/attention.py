"""Pallas blocked causal attention kernel — the BS-side sequence hot-spot.

The attention mechanism runs at the MEC server (paper §II-B) and is where
the *attention waiting latency* accrues: the next block's attention cannot
start until the slowest device returns its tokens (paper Fig. 3). The
compute itself is a standard multi-head causal self-attention.

TPU adaptation: the paper's substrate computes the full J×J score matrix on
GPU. Here we use an online-softmax (flash-style) blocked kernel so the
score matrix is never materialised in HBM:

  * grid = (H, J/bq): one head and one query row-tile per step.
  * keys/values for the whole (causal prefix of the) sequence stream
    through VMEM in bk-sized column tiles inside a fori_loop, maintaining
    the running max `mx`, normaliser `sm`, and accumulator `acc`.
  * q/k/v tiles are MXU-shaped ([bq, hd] @ [hd, bk] with hd a multiple
    of 8 and bq, bk multiples of 128 where the sequence allows).

interpret=True — see moe_ffn.py. Projections (wq/wk/wv/wo) are left to XLA
(plain dots fuse fine); the kernel covers the quadratic part.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, seq_len: int, causal: bool):
    """One (head, query-tile) step of online-softmax attention.

    q_ref: [bq, hd] query tile (pre-scaled by 1/sqrt(hd) at call site).
    k_ref/v_ref: [J, hd] full per-head key/value (streamed in bk chunks).
    o_ref: [bq, hd] output tile.
    """
    qi = pl.program_id(1)
    bq, hd = q_ref.shape
    q = q_ref[...]

    nk = seq_len // bk

    def body(kb, carry):
        acc, mx, sm = carry
        k = k_ref[pl.dslice(kb * bk, bk), :]            # [bk, hd]
        v = v_ref[pl.dslice(kb * bk, bk), :]            # [bk, hd]
        s = q @ k.T                                     # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, -1e30)
        new_mx = jnp.maximum(mx, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - new_mx)                         # [bq, bk]
        scale = jnp.exp(mx - new_mx)
        new_sm = sm * scale + jnp.sum(p, axis=-1, keepdims=True)
        new_acc = acc * scale + p @ v                   # [bq, hd]
        return new_acc, new_mx, new_sm

    acc0 = jnp.zeros((bq, hd), q.dtype)
    mx0 = jnp.full((bq, 1), -1e30, q.dtype)
    sm0 = jnp.zeros((bq, 1), q.dtype)
    acc, _, sm = jax.lax.fori_loop(0, nk, body, (acc0, mx0, sm0))
    o_ref[...] = acc / jnp.maximum(sm, 1e-30)


@functools.partial(jax.jit, static_argnames=("num_heads", "bq", "bk", "causal"))
def attention(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wo: jax.Array,
    num_heads: int,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
) -> jax.Array:
    """Multi-head causal self-attention with a blocked-softmax core.

    Args:
      x: [J, m]; J % bq == 0 and J % bk == 0 (coordinator pads).
      wq/wk/wv/wo: [m, m] projections.
      num_heads: H; m % H == 0.

    Returns:
      [J, m] attention output (same contract as ref.attention).
    """
    j, m = x.shape
    hd = m // num_heads
    bq = min(bq, j)
    bk = min(bk, j)
    if j % bq or j % bk:
        raise ValueError(f"J={j} must be a multiple of bq={bq} and bk={bk}")

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, x.dtype))
    # [H, J, hd] per-head projections — plain XLA dots.
    q = (x @ wq).reshape(j, num_heads, hd).transpose(1, 0, 2) * scale
    k = (x @ wk).reshape(j, num_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(j, num_heads, hd).transpose(1, 0, 2)

    out = pl.pallas_call(
        functools.partial(_mha_kernel, bk=bk, seq_len=j, causal=causal),
        grid=(num_heads, j // bq),
        in_specs=[
            # None squeezes the head axis out of the kernel refs.
            pl.BlockSpec((None, bq, hd), lambda h, i: (h, i, 0)),  # q tile
            pl.BlockSpec((None, j, hd), lambda h, i: (h, 0, 0)),   # full k (streamed)
            pl.BlockSpec((None, j, hd), lambda h, i: (h, 0, 0)),   # full v (streamed)
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_heads, j, hd), x.dtype),
        interpret=True,
    )(q, k, v)

    return out.transpose(1, 0, 2).reshape(j, m) @ wo
