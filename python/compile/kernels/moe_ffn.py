"""Pallas SwiGLU expert-FFN kernel — the device-side compute hot-spot.

This is the computation every mobile device runs for every token routed to
it (paper Fig. 2): ``y = w2(silu(w1 x) ⊙ w3 x)``, whose FLOP count is the
paper's Eq. (5): ``L_comp = 4 m·mh + 2 mh·m + η·mh + mh``.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): the paper's experts ran
on GPU FFNs (threadblock tiling over HBM/shared-mem). Here the kernel is
tiled for VMEM via BlockSpec:

  * grid = (J / bj, mh / bh): each step holds an x row-tile [bj, m], a
    column tile of w1 and w3 [m, bh], and a row tile of w2 [bh, m] in VMEM.
  * the two up-projections and the SiLU gate are FUSED — the [bj, bh]
    intermediate ``silu(a) ⊙ b`` lives only in VMEM/registers and never
    round-trips to HBM (on GPU this is the shared-memory fusion the paper's
    substrate, Mixtral's kernels, perform).
  * the hidden dimension is the reduction axis for the down-projection, so
    each grid step accumulates its partial ``(bj, m)`` product into the
    output ref; the grid iterates hidden-tiles innermost for locality.
  * tile sizes default to multiples of 128 to map onto the 128×128 MXU.

interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls, so
the kernel is validated in interpret mode and its TPU efficiency is
estimated analytically (see vmem_bytes / mxu_flops below and
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class FfnTiling(NamedTuple):
    """Block sizes for the fused SwiGLU kernel.

    bj: token-rows per grid step (MXU sublane dim; multiple of 8, ideally 128)
    bh: hidden-columns per grid step (MXU lane dim; multiple of 128)
    """

    bj: int = 128
    bh: int = 128


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, *, nh_steps: int):
    """Fused SwiGLU grid step.

    Grid is (J/bj, mh/bh) with the hidden axis innermost. Each step computes
    gate = silu(x·w1_tile) ⊙ (x·w3_tile)   -> [bj, bh]   (VMEM only)
    and accumulates gate · w2_tile          -> [bj, m]
    into o_ref. The first hidden step zero-initialises the accumulator.
    """
    h = pl.program_id(1)

    x = x_ref[...]            # [bj, m]
    a = x @ w1_ref[...]       # [bj, bh]
    b = x @ w3_ref[...]       # [bj, bh]
    gate = a * jax.nn.sigmoid(a) * b  # SiLU(a) ⊙ b, fused in VMEM
    partial = gate @ w2_ref[...]      # [bj, m]

    @pl.when(h == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(h != 0)
    def _accum():
        o_ref[...] += partial

    del nh_steps  # part of the signature for cost introspection


@functools.partial(jax.jit, static_argnames=("tiling",))
def expert_ffn(
    x: jax.Array,
    w1: jax.Array,
    w3: jax.Array,
    w2: jax.Array,
    tiling: FfnTiling = FfnTiling(),
) -> jax.Array:
    """SwiGLU expert FFN via the fused Pallas kernel.

    Args:
      x:  [J, m] tokens routed to this expert.
      w1: [m, mh] gate projection.
      w3: [m, mh] up projection.
      w2: [mh, m] down projection.
      tiling: VMEM block sizes; J % bj == 0 and mh % bh == 0 required
        (the coordinator pads token batches to the tile boundary).

    Returns:
      [J, m] expert output.
    """
    j, m = x.shape
    mh = w1.shape[1]
    bj = min(tiling.bj, j)
    bh = min(tiling.bh, mh)
    if j % bj or mh % bh:
        raise ValueError(f"J={j} must divide bj={bj} and mh={mh} divide bh={bh}")
    grid = (j // bj, mh // bh)

    return pl.pallas_call(
        functools.partial(_ffn_kernel, nh_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bj, m), lambda i, h: (i, 0)),   # x row tile
            pl.BlockSpec((m, bh), lambda i, h: (0, h)),   # w1 col tile
            pl.BlockSpec((m, bh), lambda i, h: (0, h)),   # w3 col tile
            pl.BlockSpec((bh, m), lambda i, h: (h, 0)),   # w2 row tile
        ],
        out_specs=pl.BlockSpec((bj, m), lambda i, h: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((j, m), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w1, w3, w2)


def auto_tiling(j: int, m: int, mh: int, vmem_budget: int = 14 * 1024 * 1024) -> FfnTiling:
    """Largest MXU-aligned tiling whose working set fits the VMEM budget.

    Fewer grid steps mean less loop overhead (interpret mode) and fewer
    HBM↔VMEM round-trips of the x tile (TPU); the budget keeps the choice
    honest for real hardware. Tries (bj, bh) from full-extent down in
    multiples of 128 (J itself may be smaller than 128 for tiny configs).
    """
    def candidates(limit: int):
        c = [limit] if limit % 128 == 0 else []
        c += [b for b in range(limit - limit % 128, 127, -128)]
        return c or [limit]

    for bj in candidates(j):
        if j % bj:
            continue
        for bh in candidates(mh):
            if mh % bh:
                continue
            if vmem_bytes(m, mh, FfnTiling(bj, bh)) <= vmem_budget:
                return FfnTiling(bj, bh)
    return FfnTiling(min(128, j), min(128, mh))


def vmem_bytes(m: int, mh: int, tiling: FfnTiling = FfnTiling(), dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM working set of the fused kernel, in bytes.

    x tile [bj, m] + w1/w3 col tiles [m, bh]·2 + w2 row tile [bh, m]
    + gate intermediate [bj, bh] + output accumulator [bj, m].
    Used by the perf analysis to check the ≈16 MiB VMEM budget.
    """
    bj, bh = tiling.bj, tiling.bh
    elems = bj * m + 2 * m * bh + bh * m + bj * bh + bj * m
    return elems * dtype_bytes


def flops(j: int, m: int, mh: int, eta: int = 7) -> int:
    """FLOPs for J tokens — J × paper Eq. (5).

    L_comp = 4·m·mh + 2·mh·m + η·mh + mh  per token:
      4·m·mh  — the two up projections (each m·mh MACs = 2·m·mh FLOPs)
      2·mh·m  — the down projection
      η·mh    — the activation (η FLOPs/element; SiLU ≈ 7)
      mh      — the element-wise gate multiply
    """
    per_token = 4 * m * mh + 2 * mh * m + eta * mh + mh
    return j * per_token


def mxu_utilization_estimate(m: int, mh: int, tiling: FfnTiling = FfnTiling()) -> float:
    """Estimated MXU utilization of one grid step (analytic, not measured).

    Fraction of the 128×128 systolic array covered by each matmul tile,
    weighted by the FLOP share of the three matmuls. Interpret-mode wall
    time is NOT a TPU proxy; this is the number reported in §Perf.
    """
    bj, bh = tiling.bj, tiling.bh
    def tile_cover(rows: int, cols: int) -> float:
        return min(rows, 128) / 128.0 * min(cols, 128) / 128.0
    # up projections: [bj, m] @ [m, bh]; down: [bj, bh] @ [bh, m]
    f_up = 2 * (2 * m * bh * bj)
    f_down = 2 * bh * m * bj
    u_up = tile_cover(bj, bh)
    u_down = tile_cover(bj, min(m, 128))
    return (f_up * u_up + f_down * u_down) / (f_up + f_down)
