"""Pallas gating-network (router) kernel — the BS-side routing hot-spot.

The gating network is a single linear projection followed by a softmax over
experts (paper §II-A). On the BS this runs for every token of every MoE
block, so it is fused into one Pallas kernel: logits, a numerically-stable
row softmax, and (optionally) the top-k mask all stay in VMEM.

The expert axis n is small (8 in the paper), far below one 128-lane tile,
so the kernel tiles only the token axis: grid = (J / bj,), each step holding
an x row-tile [bj, m], the whole router matrix [m, n] (n ≤ 128), and the
[bj, n] logits in VMEM.

interpret=True — see moe_ffn.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gating_kernel(x_ref, wg_ref, w_ref):
    """One token-tile step: fused projection + stable softmax."""
    logits = x_ref[...] @ wg_ref[...]                      # [bj, n]
    z = logits - jnp.max(logits, axis=-1, keepdims=True)   # stability
    e = jnp.exp(z)
    w_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bj",))
def gating(x: jax.Array, wg: jax.Array, bj: int = 128) -> jax.Array:
    """Router weights for each token.

    Args:
      x:  [J, m] token embeddings; J % bj must be 0 (coordinator pads).
      wg: [m, n] router projection, n ≤ 128.
      bj: token-rows per grid step.

    Returns:
      [J, n] softmax weights (rows sum to 1) — the w_j of paper Eq. (1).
    """
    j, m = x.shape
    n = wg.shape[1]
    bj = min(bj, j)
    if j % bj:
        raise ValueError(f"J={j} must be a multiple of bj={bj}")
    if n > 128:
        raise ValueError(f"n={n} experts exceeds one lane tile (128)")

    return pl.pallas_call(
        _gating_kernel,
        grid=(j // bj,),
        in_specs=[
            pl.BlockSpec((bj, m), lambda i: (i, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bj, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((j, n), x.dtype),
        interpret=True,
    )(x, wg)
