"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy only (no pallas, no custom calls). pytest
compares kernel output against these oracles with `assert_allclose`; they
are the single source of numerical truth for Layer 1.

Shapes follow the paper's notation:
  J  — number of tokens in the batch  (paper: total input tokens)
  m  — token embedding dimension      (paper: m)
  mh — expert FFN hidden dimension    (paper: m_h)
  n  — number of experts              (paper: n)
  H  — number of attention heads
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x: jax.Array) -> jax.Array:
    """SiLU/swish activation: x * sigmoid(x)."""
    return x * jax.nn.sigmoid(x)


def expert_ffn(
    x: jax.Array,
    w1: jax.Array,
    w3: jax.Array,
    w2: jax.Array,
) -> jax.Array:
    """SwiGLU expert FFN (paper Fig. 2, Mixtral-style).

    y = (silu(x @ w1) * (x @ w3)) @ w2

    Args:
      x:  [J, m]  token embeddings.
      w1: [m, mh] gate projection.
      w3: [m, mh] up projection.
      w2: [mh, m] down projection.

    Returns:
      [J, m] expert output, same shape as input (paper §III-A: "the output
      tensor retains the same shape as the input tensor").
    """
    return (silu(x @ w1) * (x @ w3)) @ w2


def gating(x: jax.Array, wg: jax.Array) -> jax.Array:
    """Gating network (router): softmax over expert logits.

    Args:
      x:  [J, m] token embeddings.
      wg: [m, n] router projection.

    Returns:
      [J, n] per-token expert weights (rows sum to 1).
    """
    return jax.nn.softmax(x @ wg, axis=-1)


def top_k_mask(w: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the top-k entries per row of w ([J, n])."""
    # kth largest value per row; ties broaden the mask, which matches the
    # renormalisation semantics used downstream.
    kth = jnp.sort(w, axis=-1)[:, -k][:, None]
    return w >= kth


def moe_combine(w: jax.Array, mask: jax.Array, expert_outs: jax.Array) -> jax.Array:
    """Combine expert outputs with masked, renormalised gate weights.

    o_j = sum_k  w'_{j,k} * y_{j,k}           (paper Eq. (1))
    with w' = (w * mask) / sum(w * mask).

    Args:
      w:           [J, n] gate weights.
      mask:        [J, n] selection mask (float or bool).
      expert_outs: [n, J, m] stacked per-expert outputs.

    Returns:
      [J, m] combined output.
    """
    wm = w * mask.astype(w.dtype)
    wm = wm / jnp.maximum(wm.sum(axis=-1, keepdims=True), 1e-9)
    return jnp.einsum("jn,njm->jm", wm, expert_outs)


def attention(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wo: jax.Array,
    num_heads: int,
    causal: bool = True,
) -> jax.Array:
    """Multi-head (causal) self-attention, the BS-side module.

    Args:
      x:  [J, m] token embeddings.
      wq, wk, wv, wo: [m, m] projections.
      num_heads: H; m must be divisible by H.
      causal: apply a lower-triangular mask (decoder-style).

    Returns:
      [J, m] attention output.
    """
    j, m = x.shape
    hd = m // num_heads
    q = (x @ wq).reshape(j, num_heads, hd).transpose(1, 0, 2)  # [H, J, hd]
    k = (x @ wk).reshape(j, num_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(j, num_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.asarray(hd, x.dtype))
    if causal:
        mask = jnp.tril(jnp.ones((j, j), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, jnp.asarray(-1e30, x.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, v)  # [H, J, hd]
    return out.transpose(1, 0, 2).reshape(j, m) @ wo


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis."""
    scale = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * scale * gamma
