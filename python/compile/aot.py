"""AOT compile path: lower every model entry point to HLO text.

Usage (from python/): ``python -m compile.aot --out ../artifacts``

Emits, per entry point, ``<name>.hlo.txt`` (HLO *text*, NOT a serialized
HloModuleProto: jax >= 0.5 writes protos with 64-bit instruction ids which
xla_extension 0.5.1 rejects — `proto.id() <= INT_MAX`; the text parser
reassigns ids and round-trips cleanly, see /opt/xla-example/README.md),
plus:

  weights.bin     all model weights, f32 little-endian, concatenated
  manifest.json   model config, weight table (name/shape/offset), and the
                  argument signature of every artifact

The rust runtime (`rust/src/runtime/`) reads the manifest, maps weights out
of weights.bin, compiles each .hlo.txt on the PJRT CPU client once, and
serves from the compiled executables. Python is never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def entry_points(cfg: M.ModelConfig) -> Dict[str, Tuple[Callable, List[jax.ShapeDtypeStruct]]]:
    """Name -> (fn, example arg specs) for every AOT artifact."""
    m, mh, n, j, v = cfg.d_model, cfg.d_hidden, cfg.n_experts, cfg.seq_len, cfg.vocab
    f32 = jnp.float32
    return {
        "embed": (M.embed, [spec([j], jnp.int32), spec([v, m])]),
        "attention": (
            functools.partial(M.attention_block, num_heads=cfg.n_heads),
            [spec([j, m]), spec([m]), spec([m, m]), spec([m, m]), spec([m, m]), spec([m, m])],
        ),
        "gate": (M.gate, [spec([j, m]), spec([m]), spec([m, n])]),
        "expert": (M.expert, [spec([j, m]), spec([m, mh]), spec([m, mh]), spec([mh, m])]),
        "expert_normed": (
            M.expert_normed,
            [spec([j, m]), spec([m]), spec([m, mh]), spec([m, mh]), spec([mh, m])],
        ),
        "experts_stacked": (
            M.experts_stacked,
            [spec([j, m]), spec([m]), spec([n, m, mh]), spec([n, m, mh]), spec([n, mh, m])],
        ),
        "combine": (M.combine, [spec([j, m]), spec([j, n], f32), spec([j, n], f32), spec([n, j, m])]),
        "lm_head": (M.lm_head, [spec([j, m]), spec([m]), spec([v, m])]),
    }


def emit(cfg: M.ModelConfig, out_dir: str, seed: int = 0) -> dict:
    """Lower all entry points + serialise weights. Returns the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    eps = entry_points(cfg)
    artifacts = {}
    for name, (fn, args) in eps.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
        }
        print(f"  {name:14s} -> {path} ({len(text)} chars)")

    weights = M.init_weights(cfg, seed=seed)
    table = []
    offset = 0
    bin_path = os.path.join(out_dir, "weights.bin")
    with open(bin_path, "wb") as f:
        for key in sorted(weights):
            arr = np.asarray(weights[key], dtype=np.float32)
            f.write(arr.tobytes(order="C"))
            table.append({"name": key, "shape": list(arr.shape), "offset": offset})
            offset += arr.size
    print(f"  weights.bin    -> {bin_path} ({offset * 4} bytes, {len(table)} tensors)")

    manifest = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "d_hidden": cfg.d_hidden,
            "n_experts": cfg.n_experts,
            "n_heads": cfg.n_heads,
            "n_blocks": cfg.n_blocks,
            "seq_len": cfg.seq_len,
            "top_k": cfg.top_k,
            "seed": seed,
            "total_params": cfg.total_params,
        },
        "artifacts": artifacts,
        "weights": {"file": "weights.bin", "dtype": "f32", "tensors": table},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output dir (or a .hlo.txt path whose dir is used)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--vocab", type=int, default=None)
    p.add_argument("--d-model", type=int, default=None)
    p.add_argument("--d-hidden", type=int, default=None)
    p.add_argument("--n-experts", type=int, default=None)
    p.add_argument("--n-heads", type=int, default=None)
    p.add_argument("--n-blocks", type=int, default=None)
    p.add_argument("--seq-len", type=int, default=None)
    args = p.parse_args()

    out = args.out
    if out.endswith(".hlo.txt"):  # Makefile passes the stamp file path
        out = os.path.dirname(out)

    overrides = {
        k: v
        for k, v in {
            "vocab": args.vocab,
            "d_model": args.d_model,
            "d_hidden": args.d_hidden,
            "n_experts": args.n_experts,
            "n_heads": args.n_heads,
            "n_blocks": args.n_blocks,
            "seq_len": args.seq_len,
        }.items()
        if v is not None
    }
    cfg = M.ModelConfig(**overrides)
    print(f"AOT: {cfg.total_params/1e6:.1f}M params -> {out}")
    emit(cfg, out, seed=args.seed)


if __name__ == "__main__":
    main()
