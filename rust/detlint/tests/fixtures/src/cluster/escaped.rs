//! Fixture: every escape here carries a reason, so the file is clean —
//! same-line and line-above placements are both exercised.

use std::collections::HashMap; // detlint: allow(nondet) fixture: iterated in sorted key order only

// detlint: allow(nondet) fixture: the alias keeps remaining uses token-free
type Map = HashMap<u32, u32>;

pub fn f(m: &Map) -> u32 {
    // detlint: allow(panic) fixture: key 0 inserted by every caller
    *m.get(&0).unwrap()
}
