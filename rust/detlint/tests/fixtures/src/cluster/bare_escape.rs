//! Fixture: a reason-less escape (line 5) suppresses nothing — the
//! panic violation stands AND the escape itself is flagged.

pub fn f(x: Option<u32>) -> u32 {
    x.unwrap() // detlint: allow(panic)
}
