//! Fixture: nondet violations on lines 5 and 7 and a panic violation
//! on line 8. The HashMap in the string (line 11) and in the comment
//! (line 12) must NOT be flagged.

use std::collections::HashMap;

pub fn f(m: &HashMap<u32, u32>) -> u32 {
    *m.get(&0).unwrap()
}

pub const S: &str = "HashMap in a string is fine";
// HashMap in a comment is fine too.
