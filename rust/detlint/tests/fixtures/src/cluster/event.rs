//! Fixture: one visibility violation (line 4); the lane-aware method
//! below is the sanctioned API and stays legal.

pub fn schedule_at(_at: u64) {}

pub fn schedule_at_in_lane(_at: u64, _lane: u32) {}
