//! Fixture: one hotpath-alloc violation (line 5, inside the manifest
//! fn) while the identical allocation in `slow_path` stays legal.

pub fn fast_path() -> Vec<u32> {
    let v = Vec::new();
    v
}

pub fn slow_path() -> Vec<u32> {
    let v = Vec::new();
    v
}
