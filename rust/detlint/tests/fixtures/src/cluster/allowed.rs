//! Fixture: this path is on the nondet allowlist, so the tier rules do
//! not apply here at all.

use std::collections::HashMap;

pub fn f(m: &HashMap<u32, u32>) -> Option<u32> {
    m.get(&0).copied()
}
