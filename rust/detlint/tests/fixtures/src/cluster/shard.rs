//! Fixture: float-order violations outside the canonical drain (lines
//! 9 and 10); the same reduction inside `merge_in_order` is legal.

pub fn merge_in_order(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn elsewhere(xs: &[f64], mut shed_tokens: f64) -> f64 {
    let t = xs.iter().sum::<f64>();
    shed_tokens += t;
    shed_tokens
}
