//! Integration tests: the fixture tree seeds exactly one family of
//! violations per rule, and the linter must report each at its exact
//! `file:line` — no more, no less. Then the shipped config must parse,
//! and the real source tree must lint clean under it (the pass is a CI
//! gate; a red self-check here fails before CI does).

use std::path::Path;

fn fixture_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn fixture_config() -> detlint::Config {
    let src = std::fs::read_to_string(fixture_root().join("detlint.toml"))
        .expect("fixture config readable");
    detlint::Config::parse(&src).expect("fixture config parses")
}

#[test]
fn fixture_tree_reports_exact_findings() {
    let cfg = fixture_config();
    let report = detlint::lint_tree(&fixture_root().join("src"), &cfg).expect("tree walks");
    let got: Vec<(String, usize, String)> = report
        .violations
        .iter()
        .map(|v| (v.file.clone(), v.line, v.rule.clone()))
        .collect();
    let want: Vec<(String, usize, String)> = [
        ("cluster/bad_nondet.rs", 5, "nondet"),
        ("cluster/bad_nondet.rs", 7, "nondet"),
        ("cluster/bad_nondet.rs", 8, "panic"),
        ("cluster/bare_escape.rs", 5, "escape"),
        ("cluster/bare_escape.rs", 5, "panic"),
        ("cluster/event.rs", 4, "visibility"),
        ("cluster/hot.rs", 5, "hotpath-alloc"),
        ("cluster/shard.rs", 9, "float-order"),
        ("cluster/shard.rs", 10, "float-order"),
    ]
    .iter()
    .map(|(f, l, r)| (f.to_string(), *l, r.to_string()))
    .collect();
    let mut got_sorted = got.clone();
    got_sorted.sort();
    assert_eq!(got_sorted, want, "full findings: {:#?}", report.violations);
}

#[test]
fn escaped_fixture_is_clean_and_counted() {
    let cfg = fixture_config();
    let src = std::fs::read_to_string(fixture_root().join("src/cluster/escaped.rs"))
        .expect("fixture readable");
    let report = detlint::lint_file("cluster/escaped.rs", &src, &cfg);
    assert!(report.is_clean(), "escaped.rs: {:?}", report.violations);
    assert_eq!(report.escapes_used.get("nondet"), Some(&2));
    assert_eq!(report.escapes_used.get("panic"), Some(&1));
}

#[test]
fn allowlisted_fixture_is_clean() {
    let cfg = fixture_config();
    let src = std::fs::read_to_string(fixture_root().join("src/cluster/allowed.rs"))
        .expect("fixture readable");
    let report = detlint::lint_file("cluster/allowed.rs", &src, &cfg);
    assert!(report.is_clean(), "allowed.rs: {:?}", report.violations);
}

#[test]
fn reason_less_escape_is_double_flagged() {
    let cfg = fixture_config();
    let src = std::fs::read_to_string(fixture_root().join("src/cluster/bare_escape.rs"))
        .expect("fixture readable");
    let report = detlint::lint_file("cluster/bare_escape.rs", &src, &cfg);
    let rules: Vec<&str> = report.violations.iter().map(|v| v.rule.as_str()).collect();
    assert!(rules.contains(&"panic"), "original finding must survive");
    assert!(rules.contains(&"escape"), "the bare escape itself is flagged");
    assert_eq!(report.escapes_used.get("panic"), None);
}

#[test]
fn diagnostics_format_is_file_line_rule() {
    let cfg = fixture_config();
    let src = std::fs::read_to_string(fixture_root().join("src/cluster/hot.rs"))
        .expect("fixture readable");
    let report = detlint::lint_file("cluster/hot.rs", &src, &cfg);
    assert_eq!(report.violations.len(), 1);
    let line = report.violations[0].to_string();
    assert!(
        line.starts_with("cluster/hot.rs:5: [hotpath-alloc]"),
        "diagnostic {line:?}"
    );
}

#[test]
fn shipped_config_parses_and_covers_every_rule_family() {
    let shipped = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../detlint.toml");
    let src = std::fs::read_to_string(&shipped).expect("shipped detlint.toml readable");
    let cfg = detlint::Config::parse(&src).expect("shipped detlint.toml parses");
    assert!(!cfg.nondet_dirs.is_empty());
    assert!(!cfg.nondet_tokens.is_empty());
    assert!(!cfg.panic_tokens.is_empty());
    assert!(!cfg.hotpath_tokens.is_empty());
    assert!(!cfg.hotpath_fns.is_empty());
    assert!(!cfg.float_files.is_empty());
    assert!(!cfg.float_canonical.is_empty());
    assert!(!cfg.vis_files.is_empty());
    assert!(!cfg.vis_tokens.is_empty());
}

#[test]
fn real_source_tree_is_clean_under_shipped_config() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let shipped = manifest.join("../../detlint.toml");
    let src = std::fs::read_to_string(&shipped).expect("shipped detlint.toml readable");
    let cfg = detlint::Config::parse(&src).expect("shipped detlint.toml parses");
    let report = detlint::lint_tree(&manifest.join("../src"), &cfg).expect("rust/src walks");
    assert!(
        report.is_clean(),
        "rust/src must lint clean; findings:\n{}",
        report
            .violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // The escape inventory is non-empty by design: every unwaivable
    // unwrap/alloc carries a reviewed reason.
    assert!(report.escapes_used.values().sum::<usize>() > 0);
}
