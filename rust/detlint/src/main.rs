//! detlint CLI.
//!
//! ```text
//! detlint <src-root> --config detlint.toml [--summary]
//! ```
//!
//! Prints one `file:line: [rule] detail` per finding and exits non-zero
//! when any violation survives. `--summary` appends per-rule violation
//! and escape counts (CI prints these so the escape inventory is
//! reviewed, not just tolerated).

use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<String> = None;
    let mut config_path: Option<String> = None;
    let mut summary = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                if i >= args.len() {
                    eprintln!("detlint: --config needs a path");
                    return ExitCode::from(2);
                }
                config_path = Some(args[i].clone());
            }
            "--summary" => summary = true,
            "--help" | "-h" => {
                println!("usage: detlint <src-root> --config detlint.toml [--summary]");
                return ExitCode::SUCCESS;
            }
            other if root.is_none() && !other.starts_with('-') => root = Some(other.to_string()),
            other => {
                eprintln!("detlint: unexpected argument {other:?}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    let Some(root) = root else {
        eprintln!("usage: detlint <src-root> --config detlint.toml [--summary]");
        return ExitCode::from(2);
    };
    let Some(config_path) = config_path else {
        eprintln!("detlint: a --config file is required");
        return ExitCode::from(2);
    };

    let config_src = match std::fs::read_to_string(&config_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("detlint: reading {config_path}: {e}");
            return ExitCode::from(2);
        }
    };
    let cfg = match detlint::Config::parse(&config_src) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("detlint: parsing {config_path}: {e}");
            return ExitCode::from(2);
        }
    };
    let report = match detlint::lint_tree(std::path::Path::new(&root), &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: walking {root}: {e}");
            return ExitCode::from(2);
        }
    };

    for v in &report.violations {
        println!("{v}");
    }
    if summary {
        use std::collections::BTreeMap;
        let mut per_rule: BTreeMap<&str, usize> = BTreeMap::new();
        for v in &report.violations {
            *per_rule.entry(v.rule.as_str()).or_insert(0) += 1;
        }
        println!("detlint summary:");
        for rule in ["nondet", "hotpath-alloc", "float-order", "panic", "visibility", "escape"] {
            let viol = per_rule.get(rule).copied().unwrap_or(0);
            let esc = report.escapes_used.get(rule).copied().unwrap_or(0);
            println!("  {rule:<14} {viol} violation(s), {esc} escape(s) in use");
        }
    }
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {} violation(s)", report.violations.len());
        ExitCode::FAILURE
    }
}
