//! detlint — the determinism & hot-path static-analysis pass.
//!
//! The simulator's headline guarantee is *byte identity*: serial,
//! sharded, probed and fault-injected runs of the same config produce
//! bit-identical outcomes. That contract is easy to break silently —
//! one `HashMap` iteration, one wall-clock read, one reordered f64
//! reduction — and no unit test reliably catches the breakage, because
//! hash seeds and thread schedules only vary *between* runs. So the
//! contract is enforced statically, by this pass, over `rust/src/**`.
//!
//! Four rule families, each scoped by the config (`detlint.toml`):
//!
//! - **nondet** — wall-clock (`Instant::now`, `SystemTime`), process
//!   environment (`std::env`), ambient RNG (`thread_rng`) and
//!   hash-ordered containers (`HashMap`/`HashSet`) are forbidden in the
//!   deterministic tier; the allowlist names the modules that *are* the
//!   boundary to the outside world (the bench timer, the real clock).
//! - **hotpath-alloc** — the manifest names functions documented as
//!   allocation-free at steady state; allocation tokens in their bodies
//!   are flagged.
//! - **float-order** — unordered f64 reductions in the sharded engine
//!   outside the canonical-order drain functions: float addition does
//!   not associate, so any sum whose order depends on thread timing
//!   breaks byte identity.
//! - **panic** / **visibility** — `unwrap`/`expect` in the tier (each
//!   use must argue its infallibility in an escape reason), and `pub`
//!   lane-0 schedule wrappers that would let callers bypass the
//!   lane-aware `EventQueue` ordering API.
//!
//! Any finding can be suppressed with
//! `// detlint: allow(<rule>) <reason>` on the same line or alone on
//! the line above — but the reason is mandatory; a reason-less escape
//! is itself a violation (rule `escape`). `#[cfg(test)] mod` blocks are
//! skipped entirely.
//!
//! The scanner is a hand-rolled tokenizer — comment and string-literal
//! stripping plus brace matching — not a full parser. That keeps the
//! crate dependency-free (it must build in the same offline environment
//! as the simulator) at the cost of token-level matching: rules match
//! code text, so they are scoped narrowly by the config rather than
//! applied syntactically.

use std::collections::BTreeMap;

// ---------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------

/// Parsed `detlint.toml`. Only the TOML subset the config needs:
/// `[section]` headers, `key = "string"` and `key = [ "a", "b" ]`
/// (arrays may span lines), `#` comments.
#[derive(Debug, Default, Clone)]
pub struct Config {
    /// Deterministic-tier directories (relative to the source root).
    pub nondet_dirs: Vec<String>,
    /// Path prefixes exempt from the nondet/panic tier rules.
    pub nondet_allowed: Vec<String>,
    /// Forbidden nondeterminism tokens.
    pub nondet_tokens: Vec<String>,
    /// Forbidden panic tokens (tier-scoped like nondet).
    pub panic_tokens: Vec<String>,
    /// Allocation tokens forbidden in manifest functions.
    pub hotpath_tokens: Vec<String>,
    /// Allocation-free manifest: file path → function names.
    pub hotpath_fns: BTreeMap<String, Vec<String>>,
    /// Files the float-order rule applies to.
    pub float_files: Vec<String>,
    /// Functions whose bodies replay in canonical order (exempt).
    pub float_canonical: Vec<String>,
    /// Accumulator identifiers whose `+=` is flagged.
    pub float_accumulators: Vec<String>,
    /// Files the visibility rule applies to.
    pub vis_files: Vec<String>,
    /// Forbidden public-API tokens in those files.
    pub vis_tokens: Vec<String>,
}

/// One `key = value` in the TOML subset.
#[derive(Debug, Clone, PartialEq)]
enum TomlValue {
    Str(String),
    List(Vec<String>),
}

/// Parse the TOML subset into section → key → value.
fn parse_toml_lite(src: &str) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>, String> {
    let mut out: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    let mut lines = src.lines().enumerate();
    while let Some((ln, raw)) = lines.next() {
        let line = strip_toml_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let Some((key, rest)) = line.split_once('=') else {
            return Err(format!("line {}: expected `key = value`, got {line:?}", ln + 1));
        };
        let key = key.trim().to_string();
        let mut rest = rest.trim().to_string();
        if rest.starts_with('[') {
            // Array, possibly spanning lines: accumulate until the
            // closing bracket (string contents never contain brackets
            // in this config dialect).
            while !rest.contains(']') {
                let Some((_, more)) = lines.next() else {
                    return Err(format!("line {}: unterminated array for {key}", ln + 1));
                };
                rest.push(' ');
                rest.push_str(strip_toml_comment(more).trim());
            }
            let inner = rest
                .trim_start_matches('[')
                .rsplit_once(']')
                .map(|(i, _)| i)
                .unwrap_or("");
            let mut items = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                items.push(unquote(part).map_err(|e| format!("key {key}: {e}"))?);
            }
            out.entry(section.clone())
                .or_default()
                .insert(key, TomlValue::List(items));
        } else {
            let s = unquote(&rest).map_err(|e| format!("key {key}: {e}"))?;
            out.entry(section.clone())
                .or_default()
                .insert(key, TomlValue::Str(s));
        }
    }
    Ok(out)
}

/// Drop a trailing `#` comment (quote-aware).
fn strip_toml_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(s: &str) -> Result<String, String> {
    let s = s.trim();
    if s.len() >= 2 && s.starts_with('"') && s.ends_with('"') {
        Ok(s[1..s.len() - 1].to_string())
    } else {
        Err(format!("expected a quoted string, got {s:?}"))
    }
}

impl Config {
    /// Parse the shipped `detlint.toml` dialect.
    pub fn parse(src: &str) -> Result<Self, String> {
        let doc = parse_toml_lite(src)?;
        let list = |sec: &str, key: &str| -> Vec<String> {
            match doc.get(sec).and_then(|s| s.get(key)) {
                Some(TomlValue::List(v)) => v.clone(),
                Some(TomlValue::Str(s)) => vec![s.clone()],
                None => Vec::new(),
            }
        };
        let mut cfg = Config {
            nondet_dirs: list("nondet", "dirs"),
            nondet_allowed: list("nondet", "allowed"),
            nondet_tokens: list("nondet", "tokens"),
            panic_tokens: list("panic", "tokens"),
            hotpath_tokens: list("hotpath", "tokens"),
            hotpath_fns: BTreeMap::new(),
            float_files: list("float-order", "files"),
            float_canonical: list("float-order", "canonical"),
            float_accumulators: list("float-order", "accumulators"),
            vis_files: list("visibility", "files"),
            vis_tokens: list("visibility", "tokens"),
        };
        for entry in list("hotpath", "fns") {
            let Some((path, name)) = entry.rsplit_once(':') else {
                return Err(format!("hotpath fn {entry:?}: expected \"path:fn_name\""));
            };
            cfg.hotpath_fns
                .entry(path.to_string())
                .or_default()
                .push(name.to_string());
        }
        Ok(cfg)
    }
}

// ---------------------------------------------------------------------
// Scanner: comment/string stripping + escape collection
// ---------------------------------------------------------------------

/// One `// detlint: allow(rule) reason` escape comment.
#[derive(Debug, Clone, PartialEq)]
pub struct Escape {
    pub rule: String,
    pub reason: String,
    /// Whether code preceded the comment on its line (same-line escape)
    /// — otherwise the escape applies to the *next* line.
    pub on_code_line: bool,
}

/// A source file with comments and string/char contents blanked out,
/// plus the escape comments found along the way (keyed by 1-based line).
#[derive(Debug)]
pub struct Stripped {
    pub lines: Vec<String>,
    pub escapes: BTreeMap<usize, Escape>,
}

/// Parse an escape out of a line comment's text (after the `//`).
fn parse_escape(comment: &str, on_code_line: bool) -> Option<Escape> {
    let t = comment.trim();
    let t = t.strip_prefix("detlint:")?.trim_start();
    let t = t.strip_prefix("allow(")?;
    let (rule, rest) = t.split_once(')')?;
    Some(Escape {
        rule: rule.trim().to_string(),
        reason: rest.trim().to_string(),
        on_code_line,
    })
}

/// Strip comments and string/char literal *contents* from `src`,
/// preserving line structure so findings report real line numbers.
pub fn strip_code(src: &str) -> Stripped {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
    }
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut escapes = BTreeMap::new();
    let mut cur = String::new();
    let mut cur_had_code = false;
    let mut comment = String::new();
    let mut state = St::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if state == St::LineComment {
                if let Some(e) = parse_escape(&comment, cur_had_code) {
                    escapes.insert(line, e);
                }
                comment.clear();
                state = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            cur_had_code = false;
            line += 1;
            i += 1;
            continue;
        }
        match state {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = St::LineComment;
                    comment.clear();
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = St::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    cur.push('"');
                    cur_had_code = true;
                    state = St::Str;
                    i += 1;
                } else if c == 'r' && matches!(chars.get(i + 1), Some('"') | Some('#')) {
                    // Possible raw string r"..." / r#"..."#.
                    let mut k = i + 1;
                    let mut hashes = 0usize;
                    while chars.get(k) == Some(&'#') {
                        hashes += 1;
                        k += 1;
                    }
                    if chars.get(k) == Some(&'"') {
                        raw_hashes = hashes;
                        cur.push('r');
                        for _ in 0..hashes {
                            cur.push('#');
                        }
                        cur.push('"');
                        cur_had_code = true;
                        state = St::RawStr;
                        i = k + 1;
                    } else {
                        cur.push(c);
                        cur_had_code = true;
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: 'x' / '\..' are
                    // literals; anything else is a lifetime tick.
                    if chars.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' {
                            j += 1;
                        }
                        cur.push_str("' '");
                        cur_had_code = true;
                        i = j + 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        cur.push_str("' '");
                        cur_had_code = true;
                        i += 3;
                    } else {
                        cur.push(c);
                        cur_had_code = true;
                        i += 1;
                    }
                } else {
                    if !c.is_whitespace() {
                        cur_had_code = true;
                    }
                    cur.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::BlockComment => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        state = St::Code;
                    }
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        cur.push('"');
                        state = St::Code;
                    }
                    i += 1;
                }
            }
            St::RawStr => {
                if c == '"' && chars[i + 1..].iter().take(raw_hashes).filter(|&&h| h == '#').count() == raw_hashes {
                    cur.push('"');
                    for _ in 0..raw_hashes {
                        cur.push('#');
                    }
                    state = St::Code;
                    i += 1 + raw_hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    if state == St::LineComment {
        if let Some(e) = parse_escape(&comment, cur_had_code) {
            escapes.insert(line, e);
        }
    }
    if !cur.is_empty() || state == St::LineComment {
        lines.push(cur);
    }
    Stripped { lines, escapes }
}

// ---------------------------------------------------------------------
// Structure: test modules and function bodies (brace matching)
// ---------------------------------------------------------------------

/// Per-line mask of `#[cfg(test)] mod … { }` blocks (index 0 = line 1).
pub fn test_mod_mask(lines: &[String]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        if !lines[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut started = false;
        let mut j = i;
        while j < lines.len() {
            for ch in lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            mask[j] = true;
            if started && depth == 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Per-line mask of every body of `fn name` in the file — all impls;
/// trait declarations (`;` before any `{`) are skipped.
pub fn fn_body_mask(lines: &[String], name: &str) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let needle = format!("fn {name}");
    for start in 0..lines.len() {
        let l = &lines[start];
        let Some(pos) = l.find(&needle) else { continue };
        // Word boundary after the name (e.g. `fn step` must not match
        // `fn step_all`).
        let after = l[pos + needle.len()..].chars().next();
        if matches!(after, Some(c) if c == '_' || c.is_alphanumeric()) {
            continue;
        }
        let mut depth = 0i64;
        let mut started = false;
        let mut decl_only = false;
        let mut j = start;
        let mut body = Vec::new();
        'scan: while j < lines.len() {
            for ch in lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    ';' if !started && depth == 0 => {
                        decl_only = true;
                        break 'scan;
                    }
                    _ => {}
                }
            }
            body.push(j);
            if started && depth == 0 {
                break;
            }
            j += 1;
        }
        if !decl_only {
            for j in body {
                mask[j] = true;
            }
        }
    }
    mask
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

/// One finding: `file:line` plus the rule and what matched.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    /// Path relative to the linted source root, `/`-separated.
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub rule: String,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.detail)
    }
}

/// Result of linting one file or tree: findings plus how many valid
/// escapes suppressed something (per rule), for the `--summary` output.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub escapes_used: BTreeMap<String, usize>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

fn path_in(rel: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| {
        if p.ends_with('/') {
            rel.starts_with(p.as_str())
        } else {
            rel == p || rel.starts_with(&format!("{p}/"))
        }
    })
}

/// Lint one file's source text. `rel` is the path relative to the
/// source root with `/` separators (used for rule scoping).
pub fn lint_file(rel: &str, src: &str, cfg: &Config) -> Report {
    let stripped = strip_code(src);
    let lines = &stripped.lines;
    let escapes = &stripped.escapes;
    let tests = test_mod_mask(lines);
    let mut report = Report::default();

    let in_tests = |line: usize| tests.get(line - 1).copied().unwrap_or(false);

    // Escape resolution: a same-line escape (comment after code)
    // suppresses its own line; an escape alone on a line suppresses the
    // next line. Valid (reasoned) escapes count toward the summary;
    // reason-less ones suppress nothing and are reported separately.
    let check = |line: usize, rule: &str, detail: String, report: &mut Report| {
        if in_tests(line) {
            return;
        }
        let escape = escapes
            .get(&line)
            .filter(|e| e.on_code_line && e.rule == rule)
            .or_else(|| {
                line.checked_sub(1)
                    .and_then(|p| escapes.get(&p))
                    .filter(|e| !e.on_code_line && e.rule == rule)
            });
        if let Some(e) = escape {
            if !e.reason.is_empty() {
                *report.escapes_used.entry(rule.to_string()).or_insert(0) += 1;
                return;
            }
            // Reason-less escapes fall through: the original finding
            // stands, and the escape itself is flagged below.
        }
        report.violations.push(Violation {
            file: rel.to_string(),
            line,
            rule: rule.to_string(),
            detail,
        });
    };

    // --- nondet & panic: deterministic-tier scoping.
    let tier_dirs: Vec<String> = cfg.nondet_dirs.iter().map(|d| format!("{d}/")).collect();
    let in_tier = tier_dirs.iter().any(|d| rel.starts_with(d.as_str()))
        || cfg.nondet_dirs.iter().any(|d| rel == format!("{d}.rs"));
    let allowed = path_in(rel, &cfg.nondet_allowed);
    if in_tier && !allowed {
        for (idx, l) in lines.iter().enumerate() {
            let line = idx + 1;
            for tok in &cfg.nondet_tokens {
                if l.contains(tok.as_str()) {
                    check(line, "nondet", format!("forbidden token `{tok}`"), &mut report);
                }
            }
            for tok in &cfg.panic_tokens {
                if l.contains(tok.as_str()) {
                    check(line, "panic", format!("forbidden token `{tok}`"), &mut report);
                }
            }
        }
    }

    // --- hotpath-alloc: manifest functions must not allocate.
    if let Some(fns) = cfg.hotpath_fns.get(rel) {
        for fname in fns {
            let body = fn_body_mask(lines, fname);
            for (idx, l) in lines.iter().enumerate() {
                if !body[idx] || tests.get(idx).copied().unwrap_or(false) {
                    continue;
                }
                let line = idx + 1;
                for tok in &cfg.hotpath_tokens {
                    if l.contains(tok.as_str()) {
                        check(
                            line,
                            "hotpath-alloc",
                            format!("`{tok}` in allocation-free fn `{fname}`"),
                            &mut report,
                        );
                    }
                }
            }
        }
    }

    // --- float-order: unordered f64 reductions outside canonical fns.
    if cfg.float_files.iter().any(|f| f == rel) {
        let mut canonical = vec![false; lines.len()];
        for fname in &cfg.float_canonical {
            for (i, b) in fn_body_mask(lines, fname).into_iter().enumerate() {
                if b {
                    canonical[i] = true;
                }
            }
        }
        for (idx, l) in lines.iter().enumerate() {
            if canonical[idx] {
                continue;
            }
            let line = idx + 1;
            if l.contains(".sum::<f64>()") {
                check(
                    line,
                    "float-order",
                    "unordered f64 reduction `.sum::<f64>()`".to_string(),
                    &mut report,
                );
            }
            for ident in &cfg.float_accumulators {
                // `ident +=` possibly with spaces: normalize by
                // removing spaces around the operator.
                let squeezed: String = l.split_whitespace().collect::<Vec<_>>().join(" ");
                if squeezed.contains(&format!("{ident} +=")) || l.contains(&format!("{ident}+=")) {
                    check(
                        line,
                        "float-order",
                        format!("f64 accumulator `{ident} +=` outside canonical-order drain"),
                        &mut report,
                    );
                }
            }
        }
    }

    // --- visibility: pub wrappers bypassing the lane-aware queue API.
    if cfg.vis_files.iter().any(|f| f == rel) {
        for (idx, l) in lines.iter().enumerate() {
            let line = idx + 1;
            for tok in &cfg.vis_tokens {
                if l.contains(tok.as_str()) {
                    check(
                        line,
                        "visibility",
                        format!("`{}` bypasses the lane-aware EventQueue API", tok.trim_end_matches('(')),
                        &mut report,
                    );
                }
            }
        }
    }

    // --- escape hygiene: a reason-less escape is itself a violation,
    // wherever it appears.
    for (&line, e) in escapes {
        if e.reason.is_empty() {
            report.violations.push(Violation {
                file: rel.to_string(),
                line,
                rule: "escape".to_string(),
                detail: format!("escape `allow({})` without a reason", e.rule),
            });
        }
    }

    report.violations.sort_by(|a, b| a.line.cmp(&b.line).then_with(|| a.rule.cmp(&b.rule)));
    report
}

/// Lint every `.rs` file under `root` (sorted walk, so output order is
/// stable across filesystems).
pub fn lint_tree(root: &std::path::Path, cfg: &Config) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&path)?;
        let file_report = lint_file(&rel, &src, cfg);
        report.violations.extend(file_report.violations);
        for (rule, n) in file_report.escapes_used {
            *report.escapes_used.entry(rule).or_insert(0) += n;
        }
    }
    Ok(report)
}

fn collect_rs_files(dir: &std::path::Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::parse(
            r#"
[nondet]
dirs = ["cluster", "moe"]
allowed = ["cluster/allowed.rs"]
tokens = ["HashMap", "Instant::now"]

[panic]
tokens = [".unwrap()", ".expect("]

[hotpath]
tokens = ["Vec::new", ".collect()"]
fns = ["cluster/hot.rs:fast_path"]

[float-order]
files = ["cluster/shard.rs"]
canonical = ["merge_in_order"]
accumulators = ["shed_tokens"]

[visibility]
files = ["cluster/event.rs"]
tokens = ["pub fn schedule_at("]
"#,
        )
        .expect("test config parses")
    }

    #[test]
    fn toml_lite_parses_sections_and_lists() {
        let c = cfg();
        assert_eq!(c.nondet_dirs, vec!["cluster", "moe"]);
        assert_eq!(c.panic_tokens, vec![".unwrap()", ".expect("]);
        assert_eq!(c.hotpath_fns["cluster/hot.rs"], vec!["fast_path"]);
    }

    #[test]
    fn toml_lite_multiline_arrays_and_comments() {
        let doc = parse_toml_lite(
            "# top comment\n[s]\nxs = [\n  \"a\", # trailing\n  \"b\",\n]\ny = \"z\"\n",
        )
        .expect("parses");
        assert_eq!(
            doc["s"]["xs"],
            TomlValue::List(vec!["a".into(), "b".into()])
        );
        assert_eq!(doc["s"]["y"], TomlValue::Str("z".into()));
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let s = strip_code("let x = \"HashMap\"; // HashMap in comment\n/* HashMap */ let y = 1;\n");
        assert!(!s.lines[0].contains("HashMap"));
        assert!(!s.lines[1].contains("HashMap"));
        assert!(s.lines[1].contains("let y = 1;"));
    }

    #[test]
    fn char_literals_and_lifetimes_survive() {
        let s = strip_code("fn f<'a>(x: &'a str) -> char { '\"' }\n");
        // The lifetime tick must not open a string and eat the rest.
        assert!(s.lines[0].contains("fn f<'a>"));
        assert!(!s.lines[0].contains('"'));
    }

    #[test]
    fn nondet_flagged_in_tier_only() {
        let c = cfg();
        let bad = lint_file("cluster/a.rs", "use std::collections::HashMap;\n", &c);
        assert_eq!(bad.violations.len(), 1);
        assert_eq!(bad.violations[0].rule, "nondet");
        assert_eq!(bad.violations[0].line, 1);
        let ok = lint_file("util/a.rs", "use std::collections::HashMap;\n", &c);
        assert!(ok.is_clean());
        let allowed = lint_file("cluster/allowed.rs", "use std::collections::HashMap;\n", &c);
        assert!(allowed.is_clean());
    }

    #[test]
    fn test_modules_are_skipped() {
        let c = cfg();
        let src = "fn main() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn f() { x.unwrap(); }\n}\n";
        assert!(lint_file("cluster/a.rs", src, &c).is_clean());
    }

    #[test]
    fn escapes_suppress_with_reason_only() {
        let c = cfg();
        let same = "let m = HashMap::new(); // detlint: allow(nondet) local, drained in key order\n";
        assert!(lint_file("cluster/a.rs", same, &c).is_clean());
        let above = "// detlint: allow(nondet) local, drained in key order\nlet m = HashMap::new();\n";
        assert!(lint_file("cluster/a.rs", above, &c).is_clean());
        // Wrong rule name: no suppression.
        let wrong = "let m = HashMap::new(); // detlint: allow(panic) some reason\n";
        assert_eq!(lint_file("cluster/a.rs", wrong, &c).violations.len(), 1);
        // Reason-less: original violation stands AND the escape is flagged.
        let bare = "let m = HashMap::new(); // detlint: allow(nondet)\n";
        let r = lint_file("cluster/a.rs", bare, &c);
        assert_eq!(r.violations.len(), 2);
        assert!(r.violations.iter().any(|v| v.rule == "escape"));
        assert!(r.violations.iter().any(|v| v.rule == "nondet"));
    }

    #[test]
    fn escape_use_is_counted() {
        let c = cfg();
        let src = "x.unwrap(); // detlint: allow(panic) infallible here\n";
        let r = lint_file("cluster/a.rs", src, &c);
        assert!(r.is_clean());
        assert_eq!(r.escapes_used.get("panic"), Some(&1));
    }

    #[test]
    fn hotpath_alloc_scoped_to_manifest_fn() {
        let c = cfg();
        let src = "fn fast_path() {\n    let v = Vec::new();\n}\nfn slow_path() {\n    let v = Vec::new();\n}\n";
        let r = lint_file("cluster/hot.rs", src, &c);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].line, 2);
        assert_eq!(r.violations[0].rule, "hotpath-alloc");
    }

    #[test]
    fn fn_body_mask_skips_trait_declarations() {
        let lines: Vec<String> = "trait T {\n    fn fast_path(&self);\n}\nfn fast_path() {\n    body();\n}\n"
            .lines()
            .map(String::from)
            .collect();
        let mask = fn_body_mask(&lines, "fast_path");
        assert!(!mask[1], "declaration line must not start a body");
        assert!(mask[4], "real body line 5 covered");
    }

    #[test]
    fn fn_body_mask_respects_word_boundary() {
        let lines: Vec<String> = "fn fast_path_extra() {\n    let v = Vec::new();\n}\n"
            .lines()
            .map(String::from)
            .collect();
        assert!(fn_body_mask(&lines, "fast_path").iter().all(|&b| !b));
    }

    #[test]
    fn float_order_outside_canonical_fns() {
        let c = cfg();
        let src = "fn merge_in_order() {\n    total += xs.iter().sum::<f64>();\n}\nfn elsewhere() {\n    let t = xs.iter().sum::<f64>();\n    shed_tokens += s;\n}\n";
        let r = lint_file("cluster/shard.rs", src, &c);
        let rules: Vec<&str> = r.violations.iter().map(|v| v.rule.as_str()).collect();
        assert_eq!(rules, vec!["float-order", "float-order"]);
        assert_eq!(r.violations[0].line, 5);
        assert_eq!(r.violations[1].line, 6);
    }

    #[test]
    fn visibility_rule_hits_pub_wrappers() {
        let c = cfg();
        let src = "pub fn schedule_at(&mut self) {}\n";
        let r = lint_file("cluster/event.rs", src, &c);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "visibility");
        // Same token in a non-listed file: clean.
        assert!(lint_file("cluster/other.rs", src, &c).is_clean());
    }
}
