//! Cluster DES benchmarks: event-loop throughput, placement
//! optimization, and replica dispatch — the hot paths behind
//! `repro cluster`.

use wdmoe::cluster::{ClusterSim, Dispatcher, Placement};
use wdmoe::config::{ClusterConfig, DispatchKind};
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::workload::{ArrivalProcess, Benchmark};

fn main() {
    let budget = default_budget();

    // Full DES run: 60 requests x 8 blocks through a 2-cell cluster.
    for (name, dispatch, cache) in [
        ("cluster_run/static_cache1", DispatchKind::Static, 1),
        ("cluster_run/load_aware_cache2", DispatchKind::LoadAware, 2),
    ] {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 8;
        cfg.dispatch = dispatch;
        cfg.cache_capacity = cache;
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(60, Benchmark::Piqa, 0);
        bench(name, budget, || {
            let mut sim = ClusterSim::new(cfg.clone()).unwrap();
            sim.run(&arrivals).completed
        });
    }

    // Placement optimizer on a heterogeneous 16-device fleet.
    let t: Vec<f64> = (0..16).map(|k| 2e-5 * (1.0 + k as f64)).collect();
    let load = vec![1.0; 16];
    bench("placement_optimize/16dev_cache4", budget, || {
        Placement::optimize(16, &t, &load, 4).experts_per_device()
    });

    // Dispatch decision on a backlogged fleet.
    let d = Dispatcher::new(DispatchKind::LoadAware);
    let busy: Vec<u64> = (0..16).map(|k| k as u64 * 1_000_000).collect();
    let online = vec![true; 16];
    let replicas: Vec<usize> = (0..16).collect();
    bench("dispatch_choose/16_replicas", budget, || {
        d.choose(&replicas, 40.0, 500_000, &busy, &t, &online)
    });
}
