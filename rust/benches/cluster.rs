//! Cluster DES benchmarks: event-loop throughput, placement
//! optimization, and replica dispatch — the hot paths behind
//! `repro cluster`.

use wdmoe::cluster::{ClusterSim, Placement};
use wdmoe::config::{ClusterConfig, DispatchKind};
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::workload::{ArrivalProcess, Benchmark};

fn main() {
    let budget = default_budget();

    // Full DES run: 60 requests x 8 blocks through a 2-cell cluster.
    // One simulator per arm, reset between runs — what a sweep point
    // costs without construction, and with the allocation-free hot path.
    for (name, dispatch, cache) in [
        ("cluster_run/static_cache1", DispatchKind::Static, 1),
        ("cluster_run/load_aware_cache2", DispatchKind::LoadAware, 2),
    ] {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 8;
        cfg.dispatch = dispatch;
        cfg.cache_capacity = cache;
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(60, Benchmark::Piqa, 0);
        let mut sim = ClusterSim::new(&cfg).unwrap();
        bench(name, budget, || {
            sim.reset().unwrap();
            sim.run(&arrivals).completed
        });
    }

    // Placement optimizer on a heterogeneous 16-device fleet.
    let t: Vec<f64> = (0..16).map(|k| 2e-5 * (1.0 + k as f64)).collect();
    let load = vec![1.0; 16];
    bench("placement_optimize/16dev_cache4", budget, || {
        Placement::optimize(16, &t, &load, 4).experts_per_device()
    });

    // Dispatch decision on a backlogged fleet, and whole-DES throughput
    // (events/sec) — the shared harnesses `repro bench` serializes.
    wdmoe::repro::benchsuite::dispatch_harness(budget);
    wdmoe::repro::benchsuite::des_harness(budget, 60);
    wdmoe::repro::benchsuite::des_nullprobe_harness(budget, 60);
    wdmoe::repro::benchsuite::des_8cell_harnesses(budget, 60);
}
