//! Bandwidth-allocation benchmarks — the P3 convex solver.
//!
//! The solver runs once per batch (paper §IV-B); at 32 blocks × 8 devices
//! it must stay well under the batch's air-interface latency. Also
//! benches the simplex projection primitive.

use wdmoe::config::SystemConfig;
use wdmoe::optim::{minimize_sum_max, project_simplex, PerBlockLoad, SolverOptions};
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::util::Rng;
use wdmoe::wireless::bandwidth::AllocationInput;
use wdmoe::wireless::ChannelSimulator;

fn main() {
    let budget = default_budget();
    let mut rng = Rng::seed_from_u64(0);

    // Simplex projection across sizes.
    for &n in &[8usize, 64, 1024] {
        let v: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        bench(&format!("project_simplex/U={n}"), budget, || {
            project_simplex(&v, 100e6)
        });
    }

    // Full P3 solve on the paper fleet with 32 blocks of loads.
    let cfg = SystemConfig::paper_simulation();
    let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
    let real = chan.expected_realization();
    let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
    let t_comp: Vec<f64> = cfg.devices.iter().map(|d| l_comp / d.compute_flops).collect();
    for &blocks in &[1usize, 8, 32] {
        let loads: Vec<PerBlockLoad> = (0..blocks)
            .map(|i| PerBlockLoad {
                tokens: (0..8).map(|k| 50.0 + ((i * 13 + k * 7) % 100) as f64).collect(),
            })
            .collect();
        let input = AllocationInput {
            channel_cfg: &cfg.channel,
            realization: &real,
            loads: &loads,
            t_comp_per_token: &t_comp,
            l_comm_bits: cfg.model.l_comm_bits(cfg.channel.quant_bits),
        };
        let links = input.links();
        let opts = SolverOptions::default();
        bench(&format!("p3_solve/blocks={blocks}"), budget, || {
            minimize_sum_max(&links, &loads, 100e6, &opts)
        });
    }
}
