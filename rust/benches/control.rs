//! Control-plane benchmarks: the P3 re-solve the adaptive plane pays at
//! every epoch (cold vs warm start) and the full epoch tick (re-solve +
//! placement re-balance) — the costs that must stay off the DES hot path.

use wdmoe::cluster::ClusterSim;
use wdmoe::config::{ClusterConfig, ControlKind, SystemConfig};
use wdmoe::control::LinkState;
use wdmoe::devices::Fleet;
use wdmoe::optim::{PerBlockLoad, SolverOptions};
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::wireless::ChannelSimulator;

fn main() {
    let budget = default_budget();
    let cfg = SystemConfig::paper_simulation();
    let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
    let real = chan.expected_realization();
    let fleet = Fleet::new(&cfg.devices, 0);
    let t_comp = fleet.t_comp_nominal(cfg.model.l_comp_flops(cfg.activation_eta));
    let state = LinkState::new(
        &cfg.channel,
        &real,
        &t_comp,
        cfg.model.l_comm_bits(cfg.channel.quant_bits),
    );
    let opts = SolverOptions::default();

    // Cold solve on the paper's 8-device fleet.
    let loads = [PerBlockLoad {
        tokens: (0..8).map(|k| (20 + k * 7) as f64).collect(),
    }];
    let cold = state.solve(&loads, &opts, None);
    bench("control_solve/cold_8dev", budget, || {
        state.solve(&loads, &opts, None).objective
    });

    // Warm solve: previous optimum, loads shifted 10% (the epoch case).
    let perturbed = [PerBlockLoad {
        tokens: loads[0].tokens.iter().map(|q| q * 1.1).collect(),
    }];
    bench("control_solve/warm_8dev", budget, || {
        state.solve(&perturbed, &opts, Some(&cold.bandwidth)).objective
    });

    // Full adaptive epoch tick: demand-driven re-solve + placement
    // re-balance. Demand alternates so hysteresis never suppresses it.
    let mut ccfg = ClusterConfig::single_cell();
    ccfg.control = ControlKind::Adaptive;
    ccfg.model.n_blocks = 4;
    let mut sim = ClusterSim::new(ccfg).unwrap();
    let experts: Vec<f64> = (0..8).map(|k| 5.0 + k as f64).collect();
    let mut flip = false;
    bench("control_epoch/adaptive_8dev", budget, || {
        flip = !flip;
        let demand: Vec<f64> = (0..8)
            .map(|k| {
                let base = 10.0 + k as f64 * 5.0;
                if (k % 2 == 0) == flip {
                    base * 3.0
                } else {
                    base
                }
            })
            .collect();
        sim.control_epoch(0, &demand, &experts)
    });
}
