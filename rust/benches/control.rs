//! Control-plane benchmarks: the P3 re-solve the adaptive plane pays at
//! every epoch (cold vs warm start) and the full epoch tick (re-solve +
//! placement re-balance) — the costs that must stay off the DES hot path.
//!
//! The workspace-path solver and epoch-tick harnesses are the shared
//! ones from [`wdmoe::repro::benchsuite`] (same code `repro bench`
//! serializes into BENCH_cluster.json, so the numbers can't drift);
//! this binary adds the allocating-wrapper variants alongside for
//! reference.

use wdmoe::optim::PerBlockLoad;
use wdmoe::repro::benchsuite;
use wdmoe::util::bench::{bench, default_budget};

fn main() {
    let budget = default_budget();

    // Shared harnesses: zero-allocation cold + warm solve, epoch tick.
    benchsuite::solver_harnesses(budget);
    benchsuite::epoch_tick_harness(budget);

    // Allocating-wrapper variants of the same solves, for comparison.
    let state = benchsuite::paper_link_state();
    let opts = Default::default();
    let loads = benchsuite::solver_load();
    let cold = state.solve(&loads, &opts, None);
    bench("control_solve/cold_8dev_alloc", budget, || {
        state.solve(&loads, &opts, None).objective
    });
    let perturbed = [PerBlockLoad {
        tokens: loads[0].tokens.iter().map(|q| q * 1.1).collect(),
    }];
    bench("control_solve/warm_8dev_alloc", budget, || {
        state.solve(&perturbed, &opts, Some(&cold.bandwidth)).objective
    });
}
