//! Expert-selection policy benchmarks — the per-block hot path.
//!
//! L3 must not bottleneck dispatch: for ARC-C-scale batches (~4300
//! tokens) the policy runs once per MoE block (32×/batch), so its cost
//! must stay ≪ the millisecond-scale per-block air-interface latency.

use wdmoe::config::PolicyConfig;
use wdmoe::latency::TokenLatencies;
use wdmoe::moe::selection::{
    SelectionContext, SelectionPolicy, TestbedPolicy, VanillaTopK, WdmoePolicy,
};
use wdmoe::moe::GateWeights;
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::workload::WorkloadGen;

fn main() {
    let budget = default_budget();
    let u = 8;
    let lat = TokenLatencies {
        per_token: (0..u).map(|k| 1e-4 * (1.0 + k as f64)).collect(),
    };
    let online = vec![true; u];

    for &tokens in &[256usize, 4300, 32000] {
        let mut wl = WorkloadGen::new(0, 32000);
        let gate = GateWeights::new(wl.synthetic_gate_weights(tokens, u, 1.5));
        let ctx = SelectionContext {
            latencies: &lat,
            top_k: 2,
            online: &online,
        };

        let mut v = VanillaTopK;
        bench(&format!("vanilla_top2/J={tokens}"), budget, || {
            v.select(&gate, &ctx)
        });

        let mut w = WdmoePolicy::new(PolicyConfig::default());
        bench(&format!("wdmoe_alg1/J={tokens}"), budget, || {
            w.select(&gate, &ctx)
        });

        let mut t = TestbedPolicy::new(PolicyConfig::default(), u);
        for k in 0..u {
            t.observe(k, lat.per_token[k]);
        }
        bench(&format!("testbed_alg2/J={tokens}"), budget, || {
            t.select(&gate, &ctx)
        });
    }
}
