//! Channel-substrate benchmarks: fading draws, Shannon rates, latency
//! evaluation — the innermost arithmetic of the simulator.

use wdmoe::config::SystemConfig;
use wdmoe::latency::TokenLatencies;
use wdmoe::optim::PerBlockLoad;
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::wireless::bandwidth::AllocationInput;
use wdmoe::wireless::{shannon_rate, ChannelSimulator};

fn main() {
    let budget = default_budget();
    let cfg = SystemConfig::paper_simulation();

    bench("shannon_rate", budget, || {
        shannon_rate(12.5e6, 10.0, 4.7e-9, 3.98e-21)
    });

    let mut fading = cfg.clone();
    fading.channel.fading_blocks = 1;
    let mut sim = ChannelSimulator::new(&fading.channel, &fading.devices, 0);
    bench("fading_redraw/U=8", budget, || {
        sim.advance_block();
        sim.realization().gains[0].down
    });

    let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
    let real = chan.expected_realization();
    let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
    let t_comp: Vec<f64> = cfg.devices.iter().map(|d| l_comp / d.compute_flops).collect();
    let loads: Vec<PerBlockLoad> = vec![];
    let input = AllocationInput {
        channel_cfg: &cfg.channel,
        realization: &real,
        loads: &loads,
        t_comp_per_token: &t_comp,
        l_comm_bits: cfg.model.l_comm_bits(cfg.channel.quant_bits),
    };
    let links = input.links();
    let bw = vec![12.5e6; 8];
    bench("token_latencies/U=8", budget, || {
        TokenLatencies::from_links(&links, &bw)
    });

    let lat = TokenLatencies::from_links(&links, &bw);
    let counts: Vec<f64> = (0..8).map(|k| 100.0 + k as f64).collect();
    bench("block_latency/U=8", budget, || {
        wdmoe::latency::block_latency(&lat, &counts)
    });
}
