//! PJRT runtime benchmarks: artifact execution on the request path.
//!
//! Measures the per-call cost of each compiled entry point (literal
//! upload + execute + download) and a whole serving forward pass. Skips
//! gracefully when artifacts have not been built.

use std::path::Path;
use wdmoe::config::{PolicyKind, SystemConfig};
use wdmoe::model::ServingModel;
use wdmoe::moe::selection::make_policy;
use wdmoe::runtime::Runtime;
use wdmoe::util::bench::{bench, default_budget};
use wdmoe::wireless::bandwidth::OptimalAllocator;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime bench skipped: run `make artifacts` first");
        return;
    }
    let budget = default_budget();
    let rt = Runtime::load(dir).expect("loading artifacts");
    let c = rt.manifest.config.clone();

    // Per-artifact execution.
    let x = vec![0.05f32; c.seq_len * c.d_model];
    let xl = Runtime::literal_f32(&x, &[c.seq_len, c.d_model]).unwrap();
    let gamma = rt.weight_literal("blk0.moe.gamma").unwrap();
    let wg = rt.weight_literal("blk0.moe.wg").unwrap();
    bench("execute/gate", budget, || {
        rt.execute("gate", &[&xl, &gamma, &wg]).unwrap()
    });

    let w1 = rt.weight_literal("blk0.expert0.w1").unwrap();
    let w3 = rt.weight_literal("blk0.expert0.w3").unwrap();
    let w2 = rt.weight_literal("blk0.expert0.w2").unwrap();
    bench("execute/expert_normed", budget, || {
        rt.execute("expert_normed", &[&xl, &gamma, &w1, &w3, &w2])
            .unwrap()
    });

    // Fused all-experts path (one call vs n) — kept for comparison; the
    // serving default is chosen from this measurement (EXPERIMENTS §Perf).
    if rt.manifest.artifacts.contains_key("experts_stacked") {
        let stack = |suffix: &str, a: usize, b: usize| {
            let mut flat = Vec::new();
            for e in 0..c.n_experts {
                let (_, d) = rt.weights.get(&format!("blk0.expert{e}.{suffix}")).unwrap();
                flat.extend_from_slice(d);
            }
            Runtime::literal_f32(&flat, &[c.n_experts, a, b]).unwrap()
        };
        let s1 = stack("w1", c.d_model, c.d_hidden);
        let s3 = stack("w3", c.d_model, c.d_hidden);
        let s2 = stack("w2", c.d_hidden, c.d_model);
        bench("execute/experts_stacked(all-n)", budget, || {
            rt.execute("experts_stacked", &[&xl, &gamma, &s1, &s3, &s2])
                .unwrap()
        });
    }

    let ag = rt.weight_literal("blk0.attn.gamma").unwrap();
    let wq = rt.weight_literal("blk0.attn.wq").unwrap();
    let wk = rt.weight_literal("blk0.attn.wk").unwrap();
    let wv = rt.weight_literal("blk0.attn.wv").unwrap();
    let wo = rt.weight_literal("blk0.attn.wo").unwrap();
    bench("execute/attention", budget, || {
        rt.execute("attention", &[&xl, &ag, &wq, &wk, &wv, &wo])
            .unwrap()
    });

    // Literal construction overhead (host -> Literal).
    bench("literal_f32/JxM", budget, || {
        Runtime::literal_f32(&x, &[c.seq_len, c.d_model]).unwrap()
    });

    // Whole forward pass (all blocks, all experts, combine, lm_head).
    let mut model = ServingModel::load(dir, SystemConfig::artifact_serving()).unwrap();
    let ids: Vec<i32> = (0..c.seq_len as i32).map(|i| i % c.vocab as i32).collect();
    let alloc = OptimalAllocator::default();
    bench("serving_forward/full", std::time::Duration::from_secs(2), || {
        let mut policy = make_policy(PolicyKind::Wdmoe, &model.cfg.policy, 8, 0);
        model.forward(&ids, policy.as_mut(), &alloc).unwrap().compute_ms
    });
}
