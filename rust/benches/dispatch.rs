//! End-to-end dispatch benchmarks: one full batch through the analytic
//! simulator (gate → select → allocate → latency accounting, 32 blocks).
//!
//! These regenerate the cost behind every paper table: `repro table2`
//! runs exactly this per (dataset × variant). Maps to paper Table II /
//! Fig. 7 as the harness hot path.

use wdmoe::config::SystemConfig;
use wdmoe::coordinator::sim::{Simulator, Variant};
use wdmoe::util::bench::{bench, default_budget};

fn main() {
    let budget = default_budget();
    for &tokens in &[60usize, 4300] {
        for (name, v) in [
            ("mixtral", Variant::mixtral_based()),
            ("wdmoe_no_bw", Variant::wdmoe_no_bandwidth()),
            ("wdmoe_full", Variant::wdmoe_full()),
        ] {
            bench(&format!("sim_batch/{name}/J={tokens}"), budget, || {
                let mut sim = Simulator::new(SystemConfig::paper_simulation());
                sim.run_variant(tokens, v).latency_ms()
            });
        }
    }

    // Testbed batch (per-block fading + jitter).
    bench("testbed_batch/J=120", budget, || {
        let cfg = SystemConfig::paper_testbed();
        let mut sim = wdmoe::testbed::TestbedSim::new(cfg.clone());
        let mut p = wdmoe::moe::selection::make_policy(
            wdmoe::config::PolicyKind::Testbed,
            &cfg.policy,
            4,
            0,
        );
        sim.run_batch(120, p.as_mut()).mean_layer_ms
    });
}
