//! Sim-time timeline sampling — per-cell load curves as CSV.
//!
//! [`TimelineSampler`] is a [`Probe`] that asks the DES for a snapshot
//! every `cadence` sim-nanoseconds and records one row per cell per
//! tick: backlog seconds, utilization, drop rate and live replica
//! count. `to_csv` renders the whole run as a tidy long-format CSV
//! (one `(t_s, cell)` pair per row) ready for plotting.
//!
//! Sampling is piecewise-constant on the DES event sequence: a tick at
//! `t` reports the state after the last event at or before `t`, so two
//! runs of the same config and seed produce byte-identical CSVs.

use super::{CellSample, Probe, TelemetryEvent};
use crate::cluster::Nanos;

/// One sampled `(tick, cell)` measurement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineRow {
    /// Sample time, sim nanoseconds.
    pub t: Nanos,
    pub cell: usize,
    /// Outstanding queued work, seconds.
    pub backlog_s: f64,
    /// Mean device utilization since t=0: cumulative busy seconds over
    /// `t × devices`. Includes committed-ahead work (queued service
    /// time already assigned to a device), so a saturated cell can
    /// transiently exceed 1.
    pub utilization: f64,
    /// Cumulative per-cell drop fraction (drops / arrivals so far).
    pub drop_rate: f64,
    /// Expert replicas currently hosted on online devices.
    pub live_replicas: usize,
    /// Devices currently online.
    pub online_devices: usize,
    /// Devices running with a fault-plan service-time multiplier other
    /// than 1.0 (straggler episode and/or link dip in progress).
    pub degraded_devices: usize,
    /// Minimum remaining battery fraction across the cell's devices
    /// (1.0 when the energy model is off or batteries are unbounded).
    pub battery_min: f64,
}

/// A [`Probe`] recording per-cell load curves on a fixed sim-time
/// cadence.
#[derive(Debug, Clone)]
pub struct TimelineSampler {
    cadence: Nanos,
    /// Cumulative arrivals per cell (by landing cell, post-handover).
    arrivals: Vec<u64>,
    /// Cumulative queue-limit drops per cell.
    drops: Vec<u64>,
    rows: Vec<TimelineRow>,
}

impl TimelineSampler {
    /// Sample every `cadence` sim-nanoseconds (clamped to ≥ 1 ns so the
    /// tick sequence is strictly increasing).
    pub fn new(cadence: Nanos) -> Self {
        Self {
            cadence: cadence.max(1),
            arrivals: Vec::new(),
            drops: Vec::new(),
            rows: Vec::new(),
        }
    }

    /// All recorded rows, in sampling order (ticks strictly increasing;
    /// cells in index order within a tick).
    pub fn rows(&self) -> &[TimelineRow] {
        &self.rows
    }

    fn ensure_cell(&mut self, cell: usize) {
        if cell >= self.arrivals.len() {
            self.arrivals.resize(cell + 1, 0);
            self.drops.resize(cell + 1, 0);
        }
    }

    /// Long-format CSV of the timeline.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "t_s,cell,backlog_s,utilization,drop_rate,live_replicas,online_devices,degraded_devices,battery_min\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{:.6},{},{:.6},{:.6},{:.6},{},{},{},{:.6}\n",
                r.t as f64 / 1e9,
                r.cell,
                r.backlog_s,
                r.utilization,
                r.drop_rate,
                r.live_replicas,
                r.online_devices,
                r.degraded_devices,
                r.battery_min
            ));
        }
        out
    }
}

impl Probe for TimelineSampler {
    fn sample_cadence(&self) -> Option<Nanos> {
        Some(self.cadence)
    }

    fn on_event(&mut self, event: &TelemetryEvent) {
        match *event {
            TelemetryEvent::Arrive { cell, .. } => {
                self.ensure_cell(cell);
                self.arrivals[cell] += 1;
            }
            TelemetryEvent::Dropped { cell, .. } => {
                self.ensure_cell(cell);
                self.drops[cell] += 1;
            }
            _ => {}
        }
    }

    fn on_sample(&mut self, t: Nanos, cells: &[CellSample]) {
        let t_s = t as f64 / 1e9;
        for (ci, c) in cells.iter().enumerate() {
            self.ensure_cell(ci);
            let capacity_s = t_s * c.devices as f64;
            let utilization = if capacity_s > 0.0 {
                c.busy_s / capacity_s
            } else {
                0.0
            };
            let drop_rate = if self.arrivals[ci] > 0 {
                self.drops[ci] as f64 / self.arrivals[ci] as f64
            } else {
                0.0
            };
            self.rows.push(TimelineRow {
                t,
                cell: ci,
                backlog_s: c.backlog_s,
                utilization,
                drop_rate,
                live_replicas: c.live_replicas,
                online_devices: c.online_devices,
                degraded_devices: c.degraded_devices,
                battery_min: c.battery_min,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(backlog_s: f64, busy_s: f64) -> CellSample {
        CellSample {
            backlog_s,
            busy_s,
            devices: 2,
            online_devices: 2,
            live_replicas: 8,
            degraded_devices: 0,
            battery_min: 1.0,
        }
    }

    #[test]
    fn rows_are_strictly_increasing_per_cell() {
        let mut tl = TimelineSampler::new(1_000_000);
        tl.on_sample(1_000_000, &[sample(0.1, 0.0), sample(0.2, 0.0)]);
        tl.on_sample(2_000_000, &[sample(0.3, 0.001), sample(0.1, 0.0)]);
        let csv = tl.to_csv();
        assert!(csv.starts_with("t_s,cell,"));
        assert_eq!(csv.lines().count(), 5);
        for cell in 0..2usize {
            let ts: Vec<Nanos> = tl
                .rows()
                .iter()
                .filter(|r| r.cell == cell)
                .map(|r| r.t)
                .collect();
            assert!(ts.windows(2).all(|w| w[0] < w[1]), "cell {cell}: {ts:?}");
        }
    }

    #[test]
    fn drop_rate_is_cumulative_per_cell() {
        let mut tl = TimelineSampler::new(1);
        for req in 0..4 {
            tl.on_event(&TelemetryEvent::Arrive {
                req,
                tokens: 10,
                rr_home: 0,
                cell: 0,
                t: req as Nanos,
            });
        }
        tl.on_event(&TelemetryEvent::Dropped {
            req: 3,
            cell: 0,
            t: 5,
        });
        tl.on_sample(10, &[sample(0.0, 0.0)]);
        assert!((tl.rows()[0].drop_rate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn utilization_normalizes_by_capacity() {
        let mut tl = TimelineSampler::new(1);
        // 2 devices, 1 s horizon, 1 busy-second total → 0.5 mean util.
        tl.on_sample(1_000_000_000, &[sample(0.0, 1.0)]);
        assert!((tl.rows()[0].utilization - 0.5).abs() < 1e-12);
        assert_eq!(tl.rows()[0].live_replicas, 8);
    }

    #[test]
    fn zero_cadence_is_clamped() {
        assert_eq!(TimelineSampler::new(0).sample_cadence(), Some(1));
    }
}
