//! Chrome trace-event export — follow sampled requests through the DES.
//!
//! [`ChromeTracer`] is a [`Probe`] that buffers structured events and
//! renders the Chrome trace-event JSON format (the `{"traceEvents":
//! [...]}` flavor), which loads directly in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! ## Lane layout
//!
//! * **Process "requests"** — one thread lane per sampled request;
//!   `B`/`E` duration spans per MoE block (blocks of one request are
//!   strictly sequential, so the pairs nest trivially), instants for
//!   arrive / completed / dropped.
//! * **Process "cell N"** — thread 0 is the control lane (instants for
//!   re-solves, device on/off, sheds, borrow staging/rollback); thread
//!   `k+1` is device `k`'s lane, carrying `B`/`E` compute spans (the
//!   device queue is FIFO over a single `busy_until` clock, so compute
//!   spans never overlap) plus async `b`/`e` spans for queue waits,
//!   backhaul hops and Eq. 11 barriers — those *can* overlap each
//!   other, which is exactly what the async phases exist for.
//!
//! ## Well-formedness
//!
//! Export sorts events by `(ts, phase-rank)` with ends before instants
//! before begins at equal timestamps, so every `B` closes with a
//! matching `E` on its lane, every `b` has an `e` with the same id, and
//! timestamps are monotone per lane. `scripts/check_trace.py` and
//! `rust/tests/telemetry.rs` verify these properties on real output.

use super::{Probe, TelemetryEvent};
use crate::cluster::Nanos;
use crate::util::Json;
use std::collections::BTreeMap;

/// One buffered trace event, pre-serialization.
#[derive(Debug, Clone)]
struct Ev {
    ph: char,
    name: String,
    cat: &'static str,
    pid: u64,
    tid: u64,
    ts: Nanos,
    /// Async span id (`b`/`e` phases only).
    id: Option<u64>,
    args: Vec<(&'static str, Json)>,
}

/// Process id of the per-request lanes.
const PID_REQUESTS: u64 = 1;

/// Process id of cell `ci`'s lanes.
fn pid_cell(ci: usize) -> u64 {
    ci as u64 + 2
}

/// Sort rank at equal timestamps: close spans, then mark instants,
/// then open new spans. Keeps zero-gap back-to-back spans well nested.
fn phase_rank(ph: char) -> u8 {
    match ph {
        'E' | 'e' => 0,
        'i' => 1,
        _ => 2,
    }
}

/// A [`Probe`] that records sampled requests' journeys and exports
/// Chrome trace-event JSON. Construct with [`ChromeTracer::new`] (trace
/// every request) or [`ChromeTracer::with_sample_every`] (every n-th).
#[derive(Debug, Clone)]
pub struct ChromeTracer {
    sample_every: usize,
    next_async_id: u64,
    events: Vec<Ev>,
    /// pid → process_name metadata.
    procs: BTreeMap<u64, String>,
    /// (pid, tid) → thread_name metadata.
    threads: BTreeMap<(u64, u64), String>,
}

impl Default for ChromeTracer {
    fn default() -> Self {
        Self::new()
    }
}

impl ChromeTracer {
    /// Trace every request.
    pub fn new() -> Self {
        Self::with_sample_every(1)
    }

    /// Trace every `sample_every`-th request (`req % n == 0`). A value
    /// of 0 is treated as 1.
    pub fn with_sample_every(sample_every: usize) -> Self {
        Self {
            sample_every: sample_every.max(1),
            next_async_id: 0,
            events: Vec::new(),
            procs: BTreeMap::new(),
            threads: BTreeMap::new(),
        }
    }

    /// Number of buffered trace events (metadata excluded).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    fn sampled(&self, req: usize) -> bool {
        req % self.sample_every == 0
    }

    fn req_lane(&mut self, req: usize) -> (u64, u64) {
        self.procs
            .entry(PID_REQUESTS)
            .or_insert_with(|| "requests".to_string());
        let tid = req as u64;
        self.threads
            .entry((PID_REQUESTS, tid))
            .or_insert_with(|| format!("req {req}"));
        (PID_REQUESTS, tid)
    }

    fn control_lane(&mut self, cell: usize) -> (u64, u64) {
        let pid = pid_cell(cell);
        self.procs
            .entry(pid)
            .or_insert_with(|| format!("cell {cell}"));
        self.threads
            .entry((pid, 0))
            .or_insert_with(|| "control".to_string());
        (pid, 0)
    }

    fn device_lane(&mut self, cell: usize, device: usize) -> (u64, u64) {
        let pid = pid_cell(cell);
        self.procs
            .entry(pid)
            .or_insert_with(|| format!("cell {cell}"));
        let tid = device as u64 + 1;
        self.threads
            .entry((pid, tid))
            .or_insert_with(|| format!("dev {device}"));
        (pid, tid)
    }

    fn instant(
        &mut self,
        lane: (u64, u64),
        ts: Nanos,
        name: String,
        cat: &'static str,
        args: Vec<(&'static str, Json)>,
    ) {
        self.events.push(Ev {
            ph: 'i',
            name,
            cat,
            pid: lane.0,
            tid: lane.1,
            ts,
            id: None,
            args,
        });
    }

    /// `B`/`E` duration pair — only for structurally non-overlapping
    /// lanes (device compute, request blocks). Degenerate zero-length
    /// spans collapse to an instant so the pair ordering stays valid.
    fn span(
        &mut self,
        lane: (u64, u64),
        start: Nanos,
        end: Nanos,
        name: String,
        cat: &'static str,
        args: Vec<(&'static str, Json)>,
    ) {
        if end <= start {
            self.instant(lane, start, name, cat, args);
            return;
        }
        self.events.push(Ev {
            ph: 'B',
            name: name.clone(),
            cat,
            pid: lane.0,
            tid: lane.1,
            ts: start,
            id: None,
            args,
        });
        self.events.push(Ev {
            ph: 'E',
            name,
            cat,
            pid: lane.0,
            tid: lane.1,
            ts: end,
            id: None,
            args: Vec::new(),
        });
    }

    /// Async `b`/`e` pair with a fresh id — for spans that may overlap
    /// others on the same lane (queue waits, backhaul hops, barriers).
    fn async_span(
        &mut self,
        lane: (u64, u64),
        start: Nanos,
        end: Nanos,
        name: String,
        cat: &'static str,
        args: Vec<(&'static str, Json)>,
    ) {
        if end <= start {
            return;
        }
        let id = self.next_async_id;
        self.next_async_id += 1;
        self.events.push(Ev {
            ph: 'b',
            name: name.clone(),
            cat,
            pid: lane.0,
            tid: lane.1,
            ts: start,
            id: Some(id),
            args,
        });
        self.events.push(Ev {
            ph: 'e',
            name,
            cat,
            pid: lane.0,
            tid: lane.1,
            ts: end,
            id: Some(id),
            args: Vec::new(),
        });
    }

    /// Render the buffered events as the Chrome trace-event JSON
    /// document. Deterministic: metadata first (sorted by lane), then
    /// events stably sorted by `(ts, phase-rank, emission order)`.
    pub fn to_json(&self) -> Json {
        let mut out: Vec<Json> = Vec::new();
        for (&pid, name) in &self.procs {
            out.push(Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
        }
        for (&(pid, tid), name) in &self.threads {
            out.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
        }
        let mut order: Vec<usize> = (0..self.events.len()).collect();
        order.sort_by_key(|&i| {
            let e = &self.events[i];
            (e.ts, phase_rank(e.ph), i)
        });
        for i in order {
            let e = &self.events[i];
            let mut fields = vec![
                ("name", Json::str(&e.name)),
                ("cat", Json::str(e.cat)),
                ("ph", Json::str(&e.ph.to_string())),
                ("pid", Json::Num(e.pid as f64)),
                ("tid", Json::Num(e.tid as f64)),
                // Chrome trace ts is in microseconds.
                ("ts", Json::Num(e.ts as f64 / 1000.0)),
            ];
            if let Some(id) = e.id {
                fields.push(("id", Json::str(&format!("0x{id:x}"))));
            }
            if !e.args.is_empty() {
                fields.push(("args", Json::obj(e.args.clone())));
            }
            out.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(out)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }
}

impl Probe for ChromeTracer {
    fn on_event(&mut self, event: &TelemetryEvent) {
        match *event {
            TelemetryEvent::Arrive {
                req,
                tokens,
                rr_home,
                cell,
                t,
            } => {
                if self.sampled(req) {
                    let lane = self.req_lane(req);
                    self.instant(
                        lane,
                        t,
                        "arrive".to_string(),
                        "mark",
                        vec![
                            ("tokens", Json::Num(tokens as f64)),
                            ("cell", Json::Num(cell as f64)),
                            ("rr_home", Json::Num(rr_home as f64)),
                        ],
                    );
                }
            }
            TelemetryEvent::GroupPlaced {
                req,
                cell,
                device,
                expert,
                tokens,
                enqueue,
                start,
                done,
            } => {
                if self.sampled(req) {
                    let lane = self.device_lane(cell, device);
                    self.async_span(
                        lane,
                        enqueue,
                        start,
                        format!("queue e{expert}"),
                        "queue",
                        vec![
                            ("req", Json::Num(req as f64)),
                            ("tokens", Json::Num(tokens)),
                        ],
                    );
                    self.span(
                        lane,
                        start,
                        done,
                        format!("compute e{expert}"),
                        "compute",
                        vec![
                            ("req", Json::Num(req as f64)),
                            ("tokens", Json::Num(tokens)),
                        ],
                    );
                }
            }
            TelemetryEvent::GroupShed {
                req,
                cell,
                expert,
                tokens,
                t,
            } => {
                if self.sampled(req) {
                    let lane = self.control_lane(cell);
                    self.instant(
                        lane,
                        t,
                        format!("shed e{expert}"),
                        "mark",
                        vec![
                            ("req", Json::Num(req as f64)),
                            ("tokens", Json::Num(tokens)),
                        ],
                    );
                }
            }
            TelemetryEvent::BorrowStaged {
                req,
                home,
                cell,
                device,
                expert,
                tokens,
                t,
                barrier,
            } => {
                if self.sampled(req) {
                    let lane = self.control_lane(cell);
                    self.instant(
                        lane,
                        t,
                        format!("borrow_staged e{expert}"),
                        "mark",
                        vec![
                            ("req", Json::Num(req as f64)),
                            ("home", Json::Num(home as f64)),
                            ("device", Json::Num(device as f64)),
                            ("tokens", Json::Num(tokens)),
                            ("barrier_us", Json::Num(barrier as f64 / 1000.0)),
                        ],
                    );
                }
            }
            TelemetryEvent::BorrowRolledBack {
                req,
                home,
                staged,
                t,
            } => {
                if self.sampled(req) {
                    let lane = self.control_lane(home);
                    self.instant(
                        lane,
                        t,
                        "borrow_rollback".to_string(),
                        "mark",
                        vec![
                            ("req", Json::Num(req as f64)),
                            ("staged", Json::Num(staged as f64)),
                        ],
                    );
                }
            }
            TelemetryEvent::BorrowCommitted {
                req,
                home,
                cell,
                device,
                expert,
                tokens,
                sent,
                landed,
                start,
                done,
                barrier,
            } => {
                if self.sampled(req) {
                    let lane = self.device_lane(cell, device);
                    let args = vec![
                        ("req", Json::Num(req as f64)),
                        ("home", Json::Num(home as f64)),
                        ("tokens", Json::Num(tokens)),
                    ];
                    self.async_span(
                        lane,
                        sent,
                        landed,
                        format!("backhaul e{expert}"),
                        "backhaul",
                        args.clone(),
                    );
                    self.async_span(
                        lane,
                        landed,
                        start,
                        format!("queue e{expert}"),
                        "queue",
                        args.clone(),
                    );
                    self.span(
                        lane,
                        start,
                        done,
                        format!("compute e{expert} (borrowed)"),
                        "compute",
                        args.clone(),
                    );
                    self.async_span(
                        lane,
                        done,
                        barrier,
                        format!("barrier e{expert}"),
                        "barrier",
                        args,
                    );
                }
            }
            TelemetryEvent::Block {
                req,
                cell,
                block,
                start,
                end,
            } => {
                if self.sampled(req) {
                    let lane = self.req_lane(req);
                    self.span(
                        lane,
                        start,
                        end,
                        format!("block {block}"),
                        "block",
                        vec![("cell", Json::Num(cell as f64))],
                    );
                }
            }
            TelemetryEvent::Completed {
                req,
                cell,
                t,
                latency_ms,
            } => {
                if self.sampled(req) {
                    let lane = self.req_lane(req);
                    self.instant(
                        lane,
                        t,
                        "completed".to_string(),
                        "mark",
                        vec![
                            ("cell", Json::Num(cell as f64)),
                            ("latency_ms", Json::Num(latency_ms)),
                        ],
                    );
                }
            }
            TelemetryEvent::Dropped { req, cell, t } => {
                if self.sampled(req) {
                    let lane = self.req_lane(req);
                    self.instant(
                        lane,
                        t,
                        "dropped".to_string(),
                        "mark",
                        vec![("cell", Json::Num(cell as f64))],
                    );
                }
            }
            TelemetryEvent::DeviceOnline {
                cell,
                device,
                online,
            } => {
                let lane = self.control_lane(cell);
                let name = if online {
                    format!("device_online dev{device}")
                } else {
                    format!("device_offline dev{device}")
                };
                self.instant(lane, 0, name, "control", Vec::new());
            }
            TelemetryEvent::ControlResolve {
                cell,
                t,
                iterations,
                objective,
                warm,
                converged,
            } => {
                let lane = self.control_lane(cell);
                self.instant(
                    lane,
                    t,
                    "resolve".to_string(),
                    "control",
                    vec![
                        ("iterations", Json::Num(iterations as f64)),
                        ("objective", Json::Num(objective)),
                        ("warm", Json::Bool(warm)),
                        ("converged", Json::Bool(converged)),
                    ],
                );
            }
            TelemetryEvent::DeviceCrashed { cell, device, t } => {
                let lane = self.control_lane(cell);
                self.instant(lane, t, format!("device_crash dev{device}"), "fault", Vec::new());
            }
            TelemetryEvent::DeviceRecovered { cell, device, t } => {
                let lane = self.control_lane(cell);
                self.instant(
                    lane,
                    t,
                    format!("device_recover dev{device}"),
                    "fault",
                    Vec::new(),
                );
            }
            TelemetryEvent::DeviceSlowdown {
                cell,
                device,
                mult,
                t,
            } => {
                let lane = self.device_lane(cell, device);
                self.instant(
                    lane,
                    t,
                    format!("slowdown x{mult}"),
                    "fault",
                    vec![("mult", Json::Num(mult))],
                );
            }
            TelemetryEvent::BackhaulFault { cell, mult, t } => {
                let lane = self.control_lane(cell);
                self.instant(
                    lane,
                    t,
                    format!("backhaul x{mult}"),
                    "fault",
                    vec![("mult", Json::Num(mult))],
                );
            }
            TelemetryEvent::Redispatched {
                req,
                cell,
                expert,
                device,
                tokens,
                t,
                done,
            } => {
                let lane = self.device_lane(cell, device);
                self.instant(
                    lane,
                    t,
                    format!("redispatch e{expert}"),
                    "fault",
                    vec![
                        ("req", Json::Num(req as f64)),
                        ("tokens", Json::Num(tokens)),
                        ("done_us", Json::Num(done as f64 / 1e3)),
                    ],
                );
            }
            TelemetryEvent::Hedged {
                req,
                cell,
                expert,
                primary,
                device,
                tokens,
                t,
            } => {
                let lane = self.device_lane(cell, device);
                self.instant(
                    lane,
                    t,
                    format!("hedge e{expert}"),
                    "hedge",
                    vec![
                        ("req", Json::Num(req as f64)),
                        ("primary", Json::Num(primary as f64)),
                        ("tokens", Json::Num(tokens)),
                    ],
                );
            }
            TelemetryEvent::BatteryDepleted { cell, device, t } => {
                let lane = self.control_lane(cell);
                self.instant(
                    lane,
                    t,
                    format!("battery_depleted dev{device}"),
                    "fault",
                    Vec::new(),
                );
            }
            // High-volume per-decision events are aggregated elsewhere;
            // the tracer keeps lanes readable.
            TelemetryEvent::DispatchDecision { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placed(req: usize, start: Nanos, done: Nanos) -> TelemetryEvent {
        TelemetryEvent::GroupPlaced {
            req,
            cell: 0,
            device: 2,
            expert: 3,
            tokens: 10.0,
            enqueue: start.saturating_sub(500),
            start,
            done,
        }
    }

    #[test]
    fn spans_pair_up_and_sort_by_time() {
        let mut tr = ChromeTracer::new();
        // Out-of-order emission: the later span first.
        tr.on_event(&placed(1, 5_000, 9_000));
        tr.on_event(&placed(0, 1_000, 5_000));
        let doc = tr.to_json().to_string();
        let back = Json::parse(&doc).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        let phases: Vec<String> = evs
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap().to_string())
            .collect();
        let n_b = phases.iter().filter(|p| *p == "B").count();
        let n_e = phases.iter().filter(|p| *p == "E").count();
        assert_eq!(n_b, 2);
        assert_eq!(n_b, n_e);
        // Back-to-back at ts 5000: the E closes before the next B opens.
        let first_b = phases.iter().position(|p| p == "B").unwrap();
        let first_e = phases.iter().position(|p| p == "E").unwrap();
        assert!(first_b < first_e, "first span must open before it closes");
        let ts: Vec<f64> = evs
            .iter()
            .filter(|e| {
                let p = e.get("ph").unwrap().as_str().unwrap();
                p == "B" || p == "E"
            })
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ts not monotone: {ts:?}");
    }

    #[test]
    fn async_spans_carry_matching_ids() {
        let mut tr = ChromeTracer::new();
        tr.on_event(&placed(0, 2_000, 4_000)); // queue wait 1500..2000
        let back = Json::parse(&tr.to_json().to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        let open: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "b")
            .collect();
        let close: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "e")
            .collect();
        assert_eq!(open.len(), 1);
        assert_eq!(close.len(), 1);
        assert_eq!(
            open[0].get("id").unwrap().as_str().unwrap(),
            close[0].get("id").unwrap().as_str().unwrap()
        );
    }

    #[test]
    fn sampling_skips_unsampled_requests() {
        let mut tr = ChromeTracer::with_sample_every(2);
        tr.on_event(&placed(0, 1_000, 2_000));
        tr.on_event(&placed(1, 1_000, 2_000));
        tr.on_event(&placed(2, 3_000, 4_000));
        // Requests 0 and 2 traced, request 1 skipped.
        let back = Json::parse(&tr.to_json().to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        let n_b = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "B")
            .count();
        assert_eq!(n_b, 2);
    }

    #[test]
    fn zero_length_span_degrades_to_instant() {
        let mut tr = ChromeTracer::new();
        tr.on_event(&TelemetryEvent::Block {
            req: 0,
            cell: 0,
            block: 0,
            start: 7_000,
            end: 7_000,
        });
        let back = Json::parse(&tr.to_json().to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs
            .iter()
            .all(|e| e.get("ph").unwrap().as_str().unwrap() != "B"));
        assert!(evs
            .iter()
            .any(|e| e.get("ph").unwrap().as_str().unwrap() == "i"));
    }

    #[test]
    fn metadata_names_every_lane() {
        let mut tr = ChromeTracer::new();
        tr.on_event(&placed(0, 1_000, 2_000));
        tr.on_event(&TelemetryEvent::ControlResolve {
            cell: 0,
            t: 500,
            iterations: 12,
            objective: 0.5,
            warm: true,
            converged: true,
        });
        let back = Json::parse(&tr.to_json().to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        let meta: Vec<String> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .map(|e| {
                e.get("args")
                    .unwrap()
                    .get("name")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert!(meta.iter().any(|n| n == "cell 0"));
        assert!(meta.iter().any(|n| n == "dev 2"));
        assert!(meta.iter().any(|n| n == "control"));
    }
}
