//! # `telemetry` — deterministic, opt-in observability for the cluster DES
//!
//! Every latency number the repo reports elsewhere is an end-of-run
//! aggregate; this module is where a *single* token group's journey
//! becomes visible — queue vs compute vs backhaul vs the Eq. 11
//! barrier. It is threaded through the serving stack as a typed event
//! stream:
//!
//! * [`Probe`] — the observer trait. [`crate::cluster::sim::ClusterSim`]
//!   (and through it `cluster/dispatch`, `cluster/handover` and the
//!   control planes) pushes [`TelemetryEvent`]s into the probe at every
//!   structurally interesting point: arrivals, dispatch decisions,
//!   group placements (queue enter / service start / service finish),
//!   sheds, borrow staging / commit / rollback, drops, device on/off
//!   toggles and control re-solves carrying their
//!   [`crate::optim::SolveStats`].
//! * [`NullProbe`] — the default no-op observer. Every trait method has
//!   an empty default body, so `run()` (which delegates to
//!   `run_probed(.., &mut NullProbe)`) monomorphizes to exactly the
//!   pre-telemetry hot path: no branches, no stores, nothing for the
//!   optimizer to keep. The `cluster/des_run_2cell_nullprobe` bench
//!   harness pins this down against the events/sec ratchet.
//! * [`ChromeTracer`] — follows sampled requests and exports Chrome
//!   trace-event JSON (one lane per device, spans for
//!   queue/compute/backhaul/barrier) that loads directly in Perfetto.
//! * [`TimelineSampler`] — samples per-cell backlog seconds,
//!   utilization, drop rate and live replica count on a fixed sim-time
//!   cadence and renders a timeline CSV.
//!
//! ## The contract: probes observe, never perturb
//!
//! Probes receive copies of simulator state; nothing they return feeds
//! back. The DES takes no decision based on whether a probe is
//! attached, so simulated outcomes with telemetry on are bit-equal to
//! telemetry off — `rust/tests/telemetry.rs` enforces this, and the
//! pre-existing byte-identity sweep tests in `rust/tests/experiment.rs`
//! pin the telemetry-off CSVs to their pre-telemetry bytes.
//!
//! Determinism carries over: events are emitted in DES event order and
//! carry integer-nanosecond sim time, so two runs of the same config
//! and seed produce byte-identical trace JSON and timeline CSVs.

pub mod timeline;
pub mod trace;

pub use timeline::{TimelineRow, TimelineSampler};
pub use trace::ChromeTracer;

use crate::cluster::Nanos;

/// One structured observation from the serving stack. All fields are
/// plain copies — holding an event never borrows simulator state.
///
/// Times are integer sim nanoseconds ([`Nanos`]); token counts are the
/// same `f64` group sizes the dispatch layer works in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TelemetryEvent {
    /// A request entered the system. `rr_home` is the round-robin home
    /// cell; `cell` is where it actually landed after any
    /// rehome-on-arrival handover.
    Arrive {
        req: usize,
        tokens: usize,
        rr_home: usize,
        cell: usize,
        t: Nanos,
    },
    /// The dispatcher ranked `candidates` replicas of `expert` and
    /// picked `device` (`None` when no replica was serviceable).
    DispatchDecision {
        cell: usize,
        expert: usize,
        tokens: f64,
        device: Option<usize>,
        candidates: usize,
        t: Nanos,
    },
    /// A token group was committed onto a local device queue: it
    /// enqueued at `enqueue` (dispatch time), starts service at
    /// `start` and finishes at `done`. Emitted only for placements
    /// that survive to the commit pass — never for ones rolled back
    /// by a queue-limit drop.
    GroupPlaced {
        req: usize,
        cell: usize,
        device: usize,
        expert: usize,
        tokens: f64,
        enqueue: Nanos,
        start: Nanos,
        done: Nanos,
    },
    /// A token group was shed (dropped tokens, request continues).
    /// A later rescue of the heaviest shed group re-places it, in
    /// which case the same group also appears as [`Self::GroupPlaced`].
    GroupShed {
        req: usize,
        cell: usize,
        expert: usize,
        tokens: f64,
        t: Nanos,
    },
    /// Cross-cell borrow staged on `cell` (serving) for `home`'s
    /// request; `barrier` is the Eq. 11 completion barrier including
    /// the return backhaul hop.
    BorrowStaged {
        req: usize,
        home: usize,
        cell: usize,
        device: usize,
        expert: usize,
        tokens: f64,
        t: Nanos,
        barrier: Nanos,
    },
    /// All `staged` borrows for the block were rolled back because the
    /// block itself was dropped.
    BorrowRolledBack {
        req: usize,
        home: usize,
        staged: usize,
        t: Nanos,
    },
    /// A staged borrow survived to commit: tokens left `home` at
    /// `sent`, landed on the serving `cell` at `landed`, computed over
    /// `start..done` and cleared the return barrier at `barrier`.
    BorrowCommitted {
        req: usize,
        home: usize,
        cell: usize,
        device: usize,
        expert: usize,
        tokens: f64,
        sent: Nanos,
        landed: Nanos,
        start: Nanos,
        done: Nanos,
        barrier: Nanos,
    },
    /// One MoE block of a request completed: dispatched at `start`,
    /// all its groups (and barriers) cleared at `end`.
    Block {
        req: usize,
        cell: usize,
        block: usize,
        start: Nanos,
        end: Nanos,
    },
    /// A request finished its last block.
    Completed {
        req: usize,
        cell: usize,
        t: Nanos,
        latency_ms: f64,
    },
    /// A request was dropped by the queue-limit admission gate.
    Dropped { req: usize, cell: usize, t: Nanos },
    /// A device was toggled on or off mid-run (failover experiments).
    DeviceOnline {
        cell: usize,
        device: usize,
        online: bool,
    },
    /// A control plane re-solved P3. `iterations`/`objective` are the
    /// solver's own [`crate::optim::SolveStats`]; `warm` says whether
    /// the solve was warm-started and `converged` whether it stopped
    /// before the iteration cap.
    ControlResolve {
        cell: usize,
        t: Nanos,
        iterations: usize,
        objective: f64,
        warm: bool,
        converged: bool,
    },
    /// A fault-plan crash took `device` offline at `t`.
    DeviceCrashed { cell: usize, device: usize, t: Nanos },
    /// A fault-plan recovery brought `device` back online at `t`.
    DeviceRecovered { cell: usize, device: usize, t: Nanos },
    /// `device`'s effective service-time multiplier changed (straggler
    /// episode and/or link dip); `mult` is the combined factor after the
    /// change, `1.0` meaning the episode ended.
    DeviceSlowdown {
        cell: usize,
        device: usize,
        mult: f64,
        t: Nanos,
    },
    /// The cell's backhaul multiplier changed (`0.0` = full outage, no
    /// cross-cell borrowing; `1.0` = restored).
    BackhaulFault { cell: usize, mult: f64, t: Nanos },
    /// A crash-lost token group was re-dispatched to a surviving replica
    /// `device`, finishing at `done`.
    Redispatched {
        req: usize,
        cell: usize,
        expert: usize,
        device: usize,
        tokens: f64,
        t: Nanos,
        done: Nanos,
    },
    /// Deadline pressure armed a hedged duplicate of a token group:
    /// `primary` holds the original placement, `device` the speculative
    /// twin. First finish wins; the loser's tokens count as waste.
    Hedged {
        req: usize,
        cell: usize,
        expert: usize,
        primary: usize,
        device: usize,
        tokens: f64,
        t: Nanos,
    },
    /// `device`'s battery hit zero at `t`: the energy layer crashes it
    /// through the ordinary fault path (a `DeviceCrashed` event follows
    /// immediately in the same stream).
    BatteryDepleted { cell: usize, device: usize, t: Nanos },
}

/// Per-cell state snapshot handed to [`Probe::on_sample`] on the
/// probe's requested cadence.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CellSample {
    /// Outstanding queued work in seconds (same quantity the handover
    /// layer ranks cells by).
    pub backlog_s: f64,
    /// Cumulative busy seconds summed over the cell's devices.
    pub busy_s: f64,
    /// Device count in the cell.
    pub devices: usize,
    /// Devices currently online.
    pub online_devices: usize,
    /// Expert replicas currently hosted on online devices.
    pub live_replicas: usize,
    /// Devices whose service-time multiplier is currently != 1.0
    /// (straggler episode or link dip in progress).
    pub degraded_devices: usize,
    /// Minimum remaining battery fraction across the cell's devices
    /// (1.0 when the energy model is off or batteries are unbounded).
    pub battery_min: f64,
}

/// An observer of the serving stack. Every method has a no-op default
/// body, so implementors opt into exactly the callbacks they need and
/// [`NullProbe`] monomorphizes to nothing.
///
/// The contract, enforced by `rust/tests/telemetry.rs`: probes receive
/// copies and return nothing the simulator reads — attaching any probe
/// leaves simulated outcomes bit-identical to running without one.
pub trait Probe {
    /// Sim-time sampling cadence for [`Self::on_sample`], or `None`
    /// (the default) to disable sampling entirely.
    #[inline]
    fn sample_cadence(&self) -> Option<Nanos> {
        None
    }

    /// Called once per structured event, in deterministic DES order.
    #[inline]
    fn on_event(&mut self, _event: &TelemetryEvent) {}

    /// Called with a per-cell snapshot at each cadence tick `t`
    /// (piecewise-constant sampling: the state is as of the last event
    /// at or before `t`).
    #[inline]
    fn on_sample(&mut self, _t: Nanos, _cells: &[CellSample]) {}

    /// `true` only for probes that provably observe nothing
    /// ([`NullProbe`] and compositions of it). The sharded DES branches
    /// on this to skip event recording entirely — a static fact about
    /// the type, so both branches monomorphize without the recorder on
    /// the null path.
    #[inline]
    fn is_null(&self) -> bool {
        false
    }
}

/// The default observer: observes nothing, costs nothing. With this
/// probe the generic `run_probed` path compiles to the identical
/// machine code the pre-telemetry `run` produced.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullProbe;

impl Probe for NullProbe {
    #[inline]
    fn is_null(&self) -> bool {
        true
    }
}

/// Probes compose as tuples: `(ChromeTracer, TimelineSampler)` drives
/// both from one run. Cadence is the finer of the two (sampling fires
/// for the pair; each member still only sees what it asked for via its
/// own default/overridden `on_sample`).
impl<A: Probe, B: Probe> Probe for (A, B) {
    #[inline]
    fn sample_cadence(&self) -> Option<Nanos> {
        match (self.0.sample_cadence(), self.1.sample_cadence()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, b) => b,
        }
    }

    #[inline]
    fn on_event(&mut self, event: &TelemetryEvent) {
        self.0.on_event(event);
        self.1.on_event(event);
    }

    #[inline]
    fn on_sample(&mut self, t: Nanos, cells: &[CellSample]) {
        self.0.on_sample(t, cells);
        self.1.on_sample(t, cells);
    }

    #[inline]
    fn is_null(&self) -> bool {
        self.0.is_null() && self.1.is_null()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        events: usize,
        samples: usize,
        cadence: Option<Nanos>,
    }

    impl Probe for Counter {
        fn sample_cadence(&self) -> Option<Nanos> {
            self.cadence
        }
        fn on_event(&mut self, _event: &TelemetryEvent) {
            self.events += 1;
        }
        fn on_sample(&mut self, _t: Nanos, _cells: &[CellSample]) {
            self.samples += 1;
        }
    }

    #[test]
    fn null_probe_has_no_cadence() {
        assert_eq!(NullProbe.sample_cadence(), None);
    }

    #[test]
    fn tuple_probe_forwards_to_both_and_takes_finer_cadence() {
        let a = Counter {
            events: 0,
            samples: 0,
            cadence: Some(500),
        };
        let b = Counter {
            events: 0,
            samples: 0,
            cadence: Some(200),
        };
        let mut pair = (a, b);
        assert_eq!(pair.sample_cadence(), Some(200));
        let ev = TelemetryEvent::Dropped {
            req: 0,
            cell: 0,
            t: 1,
        };
        pair.on_event(&ev);
        pair.on_sample(7, &[CellSample::default()]);
        assert_eq!(pair.0.events, 1);
        assert_eq!(pair.1.events, 1);
        assert_eq!(pair.0.samples, 1);
        assert_eq!(pair.1.samples, 1);
    }

    #[test]
    fn tuple_probe_cadence_with_nulls() {
        let c = Counter {
            events: 0,
            samples: 0,
            cadence: Some(9),
        };
        assert_eq!((NullProbe, NullProbe).sample_cadence(), None);
        assert_eq!((c, NullProbe).sample_cadence(), Some(9));
    }
}
