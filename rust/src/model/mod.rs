//! The serving model: orchestrates AOT artifacts through the WDMoE
//! deployment split.
//!
//! Per MoE block the coordinator executes exactly the paper's data flow
//! (Fig. 4): **attention at the BS** → **gate at the BS** → expert
//! selection (policy) → **expert FFNs on the devices** (simulated air
//! interface, real PJRT compute) → **combine at the BS** (Eq. (1)).
//! Simulated wireless latency (what the paper measures) and wall-clock
//! compute time (CPU PJRT, reported separately) never mix.

use crate::config::SystemConfig;
use crate::coordinator::router::{BatchEngine, BatchResult};
use crate::devices::Fleet;
use crate::latency::{block_latency, LatencyReport, TokenLatencies};
use crate::moe::selection::{SelectionContext, SelectionPolicy};
use crate::moe::{GateWeights, Selection};
use crate::optim::PerBlockLoad;
use crate::runtime::Runtime;
use crate::wireless::bandwidth::{AllocationInput, BandwidthAllocator};
use crate::wireless::ChannelSimulator;
use std::path::Path;
use std::time::Instant;

/// Cached per-block weight literals (built once at load).
struct BlockWeights {
    attn: [xla::Literal; 5],    // gamma, wq, wk, wv, wo
    gate: [xla::Literal; 2],    // gamma, wg
    experts: Vec<[xla::Literal; 3]>, // per expert: w1, w3, w2
    /// Stacked expert weights [n,m,mh]×2 + [n,mh,m] for the fused
    /// `experts_stacked` entry point (one PJRT call per block).
    experts_stacked: Option<[xla::Literal; 3]>,
}

/// Result of one forward pass.
pub struct ForwardOutcome {
    /// Row-major logits `[seq_len, vocab]`.
    pub logits: Vec<f32>,
    /// Simulated wireless latency (the paper's metric).
    pub report: LatencyReport,
    /// Final bandwidth allocation.
    pub bandwidth: Vec<f64>,
    /// Per-block selections.
    pub selections: Vec<Selection>,
    /// Wall-clock PJRT compute milliseconds.
    pub compute_ms: f64,
}

/// The PJRT-backed WDMoE model.
pub struct ServingModel {
    rt: Runtime,
    pub cfg: SystemConfig,
    channel: ChannelSimulator,
    fleet: Fleet,
    emb: xla::Literal,
    final_gamma: xla::Literal,
    blocks: Vec<BlockWeights>,
    /// Use the per-expert path and skip experts with no routed tokens.
    /// Default false: the fused `experts_stacked` call is faster on CPU
    /// PJRT (one launch, XLA-internal parallelism) even though it always
    /// computes all n experts; identical output because combine masks.
    pub skip_unrouted_experts: bool,
}

impl ServingModel {
    /// Load artifacts and bind them to a wireless scenario. The model
    /// dimensions of `cfg` are overwritten from the manifest so the
    /// latency model (`L_comm`, `L_comp`) matches what actually executes.
    pub fn load(artifacts_dir: &Path, mut cfg: SystemConfig) -> anyhow::Result<Self> {
        let rt = Runtime::load(artifacts_dir)?;
        let m = &rt.manifest.config;
        cfg.model.vocab = m.vocab;
        cfg.model.d_model = m.d_model;
        cfg.model.d_hidden = m.d_hidden;
        cfg.model.n_heads = m.n_heads;
        cfg.model.n_blocks = m.n_blocks;
        cfg.model.seq_len = m.seq_len;
        cfg.model.top_k = m.top_k;
        anyhow::ensure!(
            m.n_experts == cfg.devices.len(),
            "artifact has {} experts but config has {} devices",
            m.n_experts,
            cfg.devices.len()
        );
        cfg.model.n_experts = m.n_experts;
        cfg.validate()?;

        let emb = rt.weight_literal("emb")?;
        let final_gamma = rt.weight_literal("final.gamma")?;
        let mut blocks = Vec::with_capacity(m.n_blocks);
        for i in 0..m.n_blocks {
            let attn = [
                rt.weight_literal(&format!("blk{i}.attn.gamma"))?,
                rt.weight_literal(&format!("blk{i}.attn.wq"))?,
                rt.weight_literal(&format!("blk{i}.attn.wk"))?,
                rt.weight_literal(&format!("blk{i}.attn.wv"))?,
                rt.weight_literal(&format!("blk{i}.attn.wo"))?,
            ];
            let gate = [
                rt.weight_literal(&format!("blk{i}.moe.gamma"))?,
                rt.weight_literal(&format!("blk{i}.moe.wg"))?,
            ];
            let experts = (0..m.n_experts)
                .map(|e| {
                    Ok([
                        rt.weight_literal(&format!("blk{i}.expert{e}.w1"))?,
                        rt.weight_literal(&format!("blk{i}.expert{e}.w3"))?,
                        rt.weight_literal(&format!("blk{i}.expert{e}.w2"))?,
                    ])
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            // Stacked weights for the fused path (when the artifact set
            // includes it — older artifact dirs may not).
            let experts_stacked = if rt.manifest.artifacts.contains_key("experts_stacked") {
                let stack = |suffix: &str, a: usize, b: usize| -> anyhow::Result<xla::Literal> {
                    let mut flat = Vec::with_capacity(m.n_experts * a * b);
                    for e in 0..m.n_experts {
                        let (_, data) = rt.weights.get(&format!("blk{i}.expert{e}.{suffix}"))?;
                        flat.extend_from_slice(data);
                    }
                    Runtime::literal_f32(&flat, &[m.n_experts, a, b])
                };
                Some([
                    stack("w1", m.d_model, m.d_hidden)?,
                    stack("w3", m.d_model, m.d_hidden)?,
                    stack("w2", m.d_hidden, m.d_model)?,
                ])
            } else {
                None
            };
            blocks.push(BlockWeights { attn, gate, experts, experts_stacked });
        }
        let channel = ChannelSimulator::new(&cfg.channel, &cfg.devices, cfg.seed);
        let fleet = Fleet::new(&cfg.devices, cfg.seed);
        Ok(Self {
            rt,
            cfg,
            channel,
            fleet,
            emb,
            final_gamma,
            blocks,
            skip_unrouted_experts: true, // fused path measured slower (EXPERIMENTS.md §Perf)
        })
    }

    pub fn seq_len(&self) -> usize {
        self.cfg.model.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.cfg.model.vocab
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Pad (with 0) or truncate ids to the AOT sequence length.
    pub fn pad_ids(&self, ids: &[i32]) -> Vec<i32> {
        let j = self.seq_len();
        let mut v = ids.to_vec();
        v.truncate(j);
        v.resize(j, 0);
        v
    }

    /// One forward pass under a selection policy + bandwidth allocator.
    pub fn forward(
        &mut self,
        token_ids: &[i32],
        policy: &mut dyn SelectionPolicy,
        allocator: &dyn BandwidthAllocator,
    ) -> anyhow::Result<ForwardOutcome> {
        // Sanctioned wall-clock read: measures real PJRT compute time
        // for the latency report; never feeds back into simulated state.
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        let j = self.seq_len();
        let md = self.cfg.model.clone();
        let u = md.n_experts;
        let n_active = token_ids.len().min(j).max(1);

        // Wireless context (mean channel; see coordinator::sim for fading).
        let realization = self.channel.expected_realization();
        let l_comp = md.l_comp_flops(self.cfg.activation_eta);
        let l_comm = md.l_comm_bits(self.cfg.channel.quant_bits);
        let t_comp = self.fleet.t_comp_nominal(l_comp);
        let online = self.fleet.online_mask();
        let total_bw = self.cfg.channel.total_bandwidth_hz;
        let uniform_bw = vec![total_bw / u as f64; u];
        let empty: Vec<PerBlockLoad> = vec![];
        let input = AllocationInput {
            channel_cfg: &self.cfg.channel,
            realization: &realization,
            loads: &empty,
            t_comp_per_token: &t_comp,
            l_comm_bits: l_comm,
        };
        let links = input.links();
        let est = TokenLatencies::from_links(&links, &uniform_bw);

        // Embed.
        let ids = self.pad_ids(token_ids);
        let ids_l = Runtime::literal_i32(&ids, &[j])?;
        let mut x = self.rt.execute("embed", &[&ids_l, &self.emb])?;

        let mut selections: Vec<Selection> = Vec::with_capacity(md.n_blocks);
        let mut loads: Vec<PerBlockLoad> = Vec::with_capacity(md.n_blocks);

        for blk in &self.blocks {
            // Attention at the BS.
            let h = self.rt.execute(
                "attention",
                &[
                    &x,
                    &blk.attn[0],
                    &blk.attn[1],
                    &blk.attn[2],
                    &blk.attn[3],
                    &blk.attn[4],
                ],
            )?;

            // Gate at the BS.
            let g = self
                .rt
                .execute("gate", &[&h, &blk.gate[0], &blk.gate[1]])?;
            let gflat = g.to_vec::<f32>()?;
            // Only the real (unpadded) tokens participate in routing
            // decisions; padded tokens ride along with expert 0 at zero
            // weight (they are masked out of every latency count).
            let gate_w = GateWeights::from_flat(&gflat, j, u);
            let ctx = SelectionContext {
                latencies: &est,
                top_k: md.top_k,
                online: &online,
            };
            let mut sel = policy.select(&gate_w, &ctx);
            // Zero out padding rows so they don't count as traffic.
            for row in n_active..j {
                for k in 0..u {
                    sel.mask[row][k] = false;
                    sel.weights[row][k] = 0.0;
                }
            }

            // Expert FFNs on the devices. Fused path: all n experts in
            // one PJRT call (XLA parallelises internally; 1 roundtrip vs
            // n). The per-expert path remains for selective execution
            // (`skip_unrouted_experts`) and artifact sets without the
            // fused entry point.
            let counts = sel.tokens_per_device();
            let s_l = match (&blk.experts_stacked, self.skip_unrouted_experts) {
                (Some(st), false) => self.rt.execute(
                    "experts_stacked",
                    &[&h, &blk.gate[0], &st[0], &st[1], &st[2]],
                )?,
                _ => {
                    let mut stacked = vec![0.0f32; u * j * md.d_model];
                    for (e, ew) in blk.experts.iter().enumerate() {
                        if self.skip_unrouted_experts && counts[e] == 0.0 {
                            continue; // masked to zero in combine anyway
                        }
                        let y = self.rt.execute(
                            "expert_normed",
                            &[&h, &blk.gate[0], &ew[0], &ew[1], &ew[2]],
                        )?;
                        let yv = y.to_vec::<f32>()?;
                        stacked[e * j * md.d_model..(e + 1) * j * md.d_model]
                            .copy_from_slice(&yv);
                    }
                    Runtime::literal_f32(&stacked, &[u, j, md.d_model])?
                }
            };

            // Combine at the BS (padding rows keep mask 0 → residual only).
            let w_l = Runtime::literal_f32(&sel.weights_flat_f32(), &[j, u])?;
            let m_l = Runtime::literal_f32(&sel.mask_flat_f32(), &[j, u])?;
            x = self.rt.execute("combine", &[&h, &w_l, &m_l, &s_l])?;

            loads.push(PerBlockLoad { tokens: counts });
            selections.push(sel);
            self.channel.advance_block();
        }

        // LM head.
        let logits_l = self
            .rt
            .execute("lm_head", &[&x, &self.final_gamma, &self.emb])?;
        let logits = logits_l.to_vec::<f32>()?;

        // Per-block bandwidth allocation + latency accounting (paper
        // Eqs. (9)–(11); Fig. 4's dynamic re-allocation each block).
        let mut report = LatencyReport::default();
        let mut bandwidth = vec![0.0; u];
        for load in &loads {
            let block_loads = [load.clone()];
            let input = AllocationInput {
                channel_cfg: &self.cfg.channel,
                realization: &realization,
                loads: &block_loads,
                t_comp_per_token: &t_comp,
                l_comm_bits: l_comm,
            };
            let bw = allocator.allocate(&input, total_bw);
            let final_lat = TokenLatencies::from_links(&links, &bw);
            report.push(block_latency(&final_lat, &load.tokens));
            for k in 0..u {
                if load.tokens[k] > 0.0 {
                    policy.observe(k, final_lat.per_token[k]);
                }
                bandwidth[k] += bw[k] / loads.len().max(1) as f64;
            }
        }

        Ok(ForwardOutcome {
            logits,
            report,
            bandwidth,
            selections,
            compute_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Argmax over the vocab at one sequence position.
    pub fn argmax_at(&self, logits: &[f32], pos: usize) -> i32 {
        let v = self.vocab();
        let row = &logits[pos * v..(pos + 1) * v];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

/// A [`BatchEngine`] binding a model to a fixed policy + allocator so the
/// router can drive it.
pub struct ServingEngine {
    pub model: ServingModel,
    pub policy: Box<dyn SelectionPolicy>,
    pub allocator: Box<dyn BandwidthAllocator>,
}

impl BatchEngine for ServingEngine {
    fn run_batch(&mut self, token_ids: &[i32], prompt_lens: &[usize]) -> anyhow::Result<BatchResult> {
        let out = self
            .model
            .forward(token_ids, self.policy.as_mut(), self.allocator.as_ref())?;
        // Next-token prediction at each prompt's final position.
        let mut next = Vec::with_capacity(prompt_lens.len());
        let mut off = 0usize;
        for &l in prompt_lens {
            let pos = (off + l).min(self.model.seq_len()) - 1;
            next.push(self.model.argmax_at(&out.logits, pos));
            off += l;
        }
        Ok(BatchResult {
            next_tokens: next,
            report: out.report,
            compute_ms: out.compute_ms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, SystemConfig};
    use crate::moe::selection::make_policy;
    use crate::wireless::bandwidth::{OptimalAllocator, UniformAllocator};
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn model() -> Option<ServingModel> {
        let dir = artifacts_dir()?;
        Some(ServingModel::load(&dir, SystemConfig::artifact_serving()).unwrap())
    }

    fn ids(n: usize, seed: u64) -> Vec<i32> {
        (0..n).map(|i| ((i as u64 * 2654435761 + seed * 97) % 2048) as i32).collect()
    }

    #[test]
    fn forward_produces_finite_logits_and_latency() {
        let Some(mut m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut policy = make_policy(PolicyKind::Wdmoe, &m.cfg.policy, 8, 0);
        let out = m
            .forward(&ids(100, 1), policy.as_mut(), &OptimalAllocator::default())
            .unwrap();
        assert_eq!(out.logits.len(), m.seq_len() * m.vocab());
        assert!(out.logits.iter().all(|f| f.is_finite()));
        assert!(out.report.total_waiting() > 0.0);
        assert_eq!(out.selections.len(), m.cfg.model.n_blocks);
        assert_eq!(out.bandwidth.len(), 8);
    }

    #[test]
    fn skip_unrouted_experts_is_output_invariant() {
        let Some(mut m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let toks = ids(64, 2);
        m.skip_unrouted_experts = true;
        let mut p1 = make_policy(PolicyKind::VanillaTopK, &m.cfg.policy, 8, 0);
        let a = m.forward(&toks, p1.as_mut(), &UniformAllocator).unwrap();
        m.skip_unrouted_experts = false;
        let mut p2 = make_policy(PolicyKind::VanillaTopK, &m.cfg.policy, 8, 0);
        let b = m.forward(&toks, p2.as_mut(), &UniformAllocator).unwrap();
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert!((x - y).abs() < 1e-4, "skip optimisation changed output");
        }
    }

    #[test]
    fn policies_agree_on_argmax_mostly() {
        // The paper's robustness premise, measured on the real model:
        // WDMoE selection vs vanilla top-2 should agree on most argmax
        // next-token predictions.
        let Some(mut m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let toks = ids(200, 3);
        let mut pv = make_policy(PolicyKind::VanillaTopK, &m.cfg.policy, 8, 0);
        let base = m.forward(&toks, pv.as_mut(), &UniformAllocator).unwrap();
        let mut pw = make_policy(PolicyKind::Wdmoe, &m.cfg.policy, 8, 0);
        let wd = m.forward(&toks, pw.as_mut(), &OptimalAllocator::default()).unwrap();
        let agree = (0..200)
            .filter(|&p| m.argmax_at(&base.logits, p) == m.argmax_at(&wd.logits, p))
            .count();
        // Random-init logits are flat over 2048 classes, so argmax is a
        // pessimistic bound (trained models would be near 100%); also
        // check the distributional shift directly via logit cosine.
        assert!(
            agree >= 90,
            "argmax agreement too low: {agree}/200 (routing robustness)"
        );
        let cos: f64 = (0..200)
            .map(|p| {
                let v = m.vocab();
                let a = &base.logits[p * v..(p + 1) * v];
                let b = &wd.logits[p * v..(p + 1) * v];
                let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
                let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                dot / (na * nb)
            })
            .sum::<f64>()
            / 200.0;
        assert!(cos > 0.95, "logit cosine too low: {cos:.4}");
    }

    #[test]
    fn wdmoe_latency_below_vanilla_on_real_gates() {
        let Some(mut m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let toks = ids(256, 4);
        let mut pv = make_policy(PolicyKind::VanillaTopK, &m.cfg.policy, 8, 0);
        let base = m.forward(&toks, pv.as_mut(), &UniformAllocator).unwrap();
        let mut pw = make_policy(PolicyKind::Wdmoe, &m.cfg.policy, 8, 0);
        let wd = m.forward(&toks, pw.as_mut(), &OptimalAllocator::default()).unwrap();
        assert!(
            wd.report.total_waiting() < base.report.total_waiting(),
            "WDMoE {} should beat vanilla {}",
            wd.report.total_waiting(),
            base.report.total_waiting()
        );
    }

    #[test]
    fn pad_ids_handles_short_and_long() {
        let Some(m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.pad_ids(&[1, 2]).len(), m.seq_len());
        assert_eq!(m.pad_ids(&vec![1; 10_000]).len(), m.seq_len());
    }
}
