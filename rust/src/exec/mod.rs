//! # `exec` — deterministic parallel execution of independent work items
//!
//! Large rate × control-plane × seed sweeps are embarrassingly parallel:
//! every point is a pure function of `(config, index)` with its own
//! deterministically derived RNG seed. This module runs such points on a
//! `std::thread::scope` worker pool (no external dependencies — the
//! offline registry has none) and returns results **in canonical index
//! order**, whatever order workers finished in. A sweep therefore
//! produces byte-identical tables at any thread count; `threads == 1`
//! (or a single item) short-circuits to a plain serial loop on the
//! calling thread.
//!
//! Workers claim indices from a shared atomic counter (work stealing by
//! construction: a worker stuck on a slow point never blocks the others)
//! and deposit each result into its index's slot. The pool is scoped, so
//! borrowed inputs (`&ClusterConfig`, rate slices) flow into workers
//! without `Arc` or cloning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a thread-count knob: `0` means one worker per available core.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Evaluate `f(0), …, f(n-1)` on up to `threads` workers (0 = auto) and
/// return the results in index order.
///
/// `f` must be a pure function of its index for parallel runs to equal
/// serial ones — derive any per-point randomness from the index, never
/// from shared mutable state. Panics in `f` propagate after the scope
/// joins, exactly like the serial loop.
pub fn map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = resolve_threads(threads).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                // detlint: allow(panic) lock poisoning means another worker already panicked; propagate
                *slots[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                // detlint: allow(panic) lock poisoning means a worker already panicked; propagate
                .expect("result slot poisoned")
                // detlint: allow(panic) the atomic counter hands every index to exactly one worker
                .expect("every index claimed exactly once")
        })
        .collect()
}

/// Fallible variant of [`map_indexed`]: evaluate every point, then
/// return the first error in *index* order (not completion order), so a
/// failing sweep reports the same point at any thread count.
pub fn try_map_indexed<T, F>(n: usize, threads: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> anyhow::Result<T> + Sync,
{
    map_indexed(n, threads, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_index_order() {
        // Staggered sleeps force out-of-order completion.
        let out = map_indexed(8, 4, |i| {
            std::thread::sleep(std::time::Duration::from_millis((8 - i as u64) * 2));
            i * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let f = |i: usize| (i as f64).sqrt() * 3.0 + i as f64;
        let serial = map_indexed(33, 1, f);
        for threads in [2, 3, 8, 64] {
            assert_eq!(map_indexed(33, threads, f), serial);
        }
    }

    #[test]
    fn every_index_evaluated_exactly_once() {
        let calls = AtomicUsize::new(0);
        let out = map_indexed(100, 7, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
        assert!(out.iter().enumerate().all(|(i, &v)| i == v));
    }

    #[test]
    fn handles_empty_and_single_item() {
        assert_eq!(map_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(map_indexed(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn non_clone_results_supported() {
        // Results only need Send, not Clone.
        struct Big(Vec<u8>);
        let out = map_indexed(5, 2, |i| Big(vec![i as u8; 3]));
        assert_eq!(out.len(), 5);
        assert_eq!(out[4].0, vec![4u8; 3]);
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn try_map_indexed_reports_first_error_by_index() {
        let ok = try_map_indexed(5, 2, |i| Ok(i * 2)).unwrap();
        assert_eq!(ok, vec![0, 2, 4, 6, 8]);
        for threads in [1, 2, 8] {
            let err = try_map_indexed(8, threads, |i| {
                if i >= 3 {
                    anyhow::bail!("boom at {i}")
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "boom at 3", "threads={threads}");
        }
    }
}
