//! Per-cell energy accounting: battery state, joule debits and
//! depletion-driven churn for the cluster DES.
//!
//! A [`CellEnergy`] is compiled from the validated
//! [`crate::config::EnergyConfig`] at cell construction: per-device
//! compute and radio joules/token (class multipliers applied round-robin),
//! battery capacity, idle draw and recharge length. The DES debits it at
//! every committed token group — compute cost plus radio cost scaled by
//! the device's *current* bandwidth share relative to the cell's uniform
//! split (a thin slice means longer airtime) — and drains depletions into
//! the existing fault machinery as deterministic
//! [`crate::cluster::faults::FaultAction::Crash`] events.
//!
//! Determinism contract: all state is cell-local, debits happen at
//! identical structural points in the serial and sharded engines with
//! identical arguments, and depletions drain in FIFO order — so energy-on
//! runs are byte-identical at any thread count. When the config is empty
//! the engine monomorphizes this module away entirely (`ENERGY = false`)
//! and stays bit-equal to the pre-energy engine.
//!
//! Accounting conventions (documented simplifications):
//! * `spent_j` bills the full cost of served work even past depletion —
//!   the group was already committed when the battery hit zero — which is
//!   what makes the conservation property exact:
//!   `sum(spent) == sum(per-token cost × served tokens)` when `idle_w = 0`.
//! * Idle draw accrues over sim time regardless of online state and is
//!   settled lazily: at each debit of the device, and once at teardown up
//!   to the last-work instant.

use super::dispatch::EnergyScore;
use super::event::{secs_from_nanos, Nanos};
use crate::config::EnergyConfig;

/// Sentinel for "no depletion yet" in the first/last instants.
const NO_DEPLETION: Nanos = 0;

/// Energy state of one cell's device fleet.
#[derive(Debug, Clone)]
pub struct CellEnergy {
    /// False when the config is empty: every hot call is branch-gated on
    /// this, and the monomorphized `ENERGY = false` engine never looks.
    pub enabled: bool,
    /// Dispatch energy weight (0 = pure latency even when enabled).
    pub weight: f64,
    /// Reference bandwidth (the cell's uniform split at construction):
    /// radio cost scales by `ref_bw / bw[k]`.
    ref_bw: f64,
    /// Compute joules per token, per device (class-scaled).
    compute_j: Vec<f64>,
    /// Radio (TX + RX) joules per token at the uniform share, per device.
    radio_j: Vec<f64>,
    /// Battery capacity per device, joules (0 = mains).
    capacity_j: Vec<f64>,
    /// Remaining battery per device, joules.
    battery_j: Vec<f64>,
    /// Total joules billed per device (keeps accruing past depletion).
    spent_j: Vec<f64>,
    /// Instant idle draw was last settled to, per device.
    idle_from: Vec<Nanos>,
    /// Battery currently at zero (cleared by a recharge episode).
    depleted: Vec<bool>,
    /// Battery hit zero at least once this run.
    ever_depleted: Vec<bool>,
    /// Idle draw, watts.
    idle_w: f64,
    /// Recharge episode length (0 = depletion is permanent).
    recharge_ns: Nanos,
    /// First/last depletion instants ([`NO_DEPLETION`] = none yet).
    first_depletion: Nanos,
    last_depletion: Nanos,
    /// FIFO of freshly depleted devices awaiting their Crash (drained by
    /// the engines at fixed structural points); `pending_head` is the
    /// read cursor so popping never shifts the buffer.
    pending: Vec<usize>,
    pending_head: usize,
    /// Dispatch-score caches refreshed per block from the live bandwidth
    /// split (see [`Self::refresh_scores`]).
    cost_j: Vec<f64>,
    frac: Vec<f64>,
}

impl CellEnergy {
    /// Compile the config for a cell of `n_dev` devices whose initial
    /// bandwidth split is `bw` (the uniform reference is its mean).
    pub fn new(cfg: &EnergyConfig, weight: f64, n_dev: usize, bw: &[f64]) -> Self {
        let ref_bw = if n_dev > 0 {
            bw.iter().sum::<f64>() / n_dev as f64
        } else {
            0.0
        };
        let class = |k: usize| -> (f64, f64, f64) {
            if cfg.classes.is_empty() {
                (1.0, 1.0, 1.0)
            } else {
                let c = &cfg.classes[k % cfg.classes.len()];
                (c.compute_mult, c.radio_mult, c.battery_mult)
            }
        };
        let mut compute_j = Vec::with_capacity(n_dev);
        let mut radio_j = Vec::with_capacity(n_dev);
        let mut capacity_j = Vec::with_capacity(n_dev);
        for k in 0..n_dev {
            let (cm, rm, bm) = class(k);
            compute_j.push(cfg.compute_j_per_token * cm);
            radio_j.push((cfg.tx_j_per_token + cfg.rx_j_per_token) * rm);
            capacity_j.push(cfg.battery_j * bm);
        }
        CellEnergy {
            enabled: !cfg.is_empty(),
            weight,
            ref_bw,
            compute_j,
            radio_j,
            battery_j: capacity_j.clone(),
            capacity_j,
            spent_j: vec![0.0; n_dev],
            idle_from: vec![0; n_dev],
            depleted: vec![false; n_dev],
            ever_depleted: vec![false; n_dev],
            idle_w: cfg.idle_w,
            recharge_ns: super::event::nanos_from_secs(cfg.recharge_s),
            first_depletion: NO_DEPLETION,
            last_depletion: NO_DEPLETION,
            pending: Vec::with_capacity(n_dev),
            pending_head: 0,
            cost_j: vec![0.0; n_dev],
            frac: vec![1.0; n_dev],
        }
    }

    /// A disabled instance (no devices): the `ENERGY = false` engines
    /// still carry the field, they just never touch it.
    pub fn disabled() -> Self {
        Self::new(&EnergyConfig::default(), 0.0, 0, &[])
    }

    /// Restore the just-built state (`ClusterSim::reset` contract).
    pub fn reset(&mut self) {
        self.battery_j.copy_from_slice(&self.capacity_j);
        for v in &mut self.spent_j {
            *v = 0.0;
        }
        for v in &mut self.idle_from {
            *v = 0;
        }
        for v in &mut self.depleted {
            *v = false;
        }
        for v in &mut self.ever_depleted {
            *v = false;
        }
        self.first_depletion = NO_DEPLETION;
        self.last_depletion = NO_DEPLETION;
        self.pending.clear();
        self.pending_head = 0;
        for v in &mut self.cost_j {
            *v = 0.0;
        }
        for v in &mut self.frac {
            *v = 1.0;
        }
    }

    /// Bill `e` joules to device `k` at instant `now`: always lands in
    /// `spent_j`; drains the battery until it pins at zero, at which
    /// point the device joins the pending-crash FIFO exactly once.
    #[inline]
    fn spend(&mut self, k: usize, e: f64, now: Nanos) {
        self.spent_j[k] += e;
        if self.capacity_j[k] > 0.0 && !self.depleted[k] {
            self.battery_j[k] -= e;
            if self.battery_j[k] <= 0.0 {
                self.battery_j[k] = 0.0;
                self.depleted[k] = true;
                self.ever_depleted[k] = true;
                if self.first_depletion == NO_DEPLETION {
                    self.first_depletion = now;
                }
                self.last_depletion = self.last_depletion.max(now);
                self.pending.push(k);
            }
        }
    }

    /// Settle device `k`'s idle draw up to `now`.
    #[inline]
    fn settle_idle_device(&mut self, k: usize, now: Nanos) {
        if self.idle_w > 0.0 && now > self.idle_from[k] {
            let e = self.idle_w * secs_from_nanos(now - self.idle_from[k]);
            self.idle_from[k] = now;
            self.spend(k, e, now);
        }
    }

    /// Debit one committed token group: `tokens` tokens served by device
    /// `k` under the live bandwidth split `bw`. Radio cost scales with
    /// `ref_bw / bw[k]` — a device starved of spectrum pays more airtime
    /// energy per token; non-positive or non-finite shares fall back to
    /// the uniform reference. Hot path: allocation-free.
    #[inline]
    pub fn debit(&mut self, k: usize, tokens: f64, bw: &[f64], now: Nanos) {
        self.settle_idle_device(k, now);
        let b = bw[k];
        let r = if b > 0.0 && b.is_finite() { self.ref_bw / b } else { 1.0 };
        let e = tokens * (self.compute_j[k] + self.radio_j[k] * r);
        self.spend(k, e, now);
    }

    /// Refresh the dispatch-score caches from the live bandwidth split:
    /// `cost_j[k]` = marginal joules/token on `k`, `frac[k]` = remaining
    /// battery fraction (1.0 for mains). Called once per dispatched block
    /// when energy-aware dispatch is armed. Hot path: allocation-free.
    #[inline]
    pub fn refresh_scores(&mut self, bw: &[f64]) {
        for k in 0..self.cost_j.len() {
            let b = bw[k];
            let r = if b > 0.0 && b.is_finite() { self.ref_bw / b } else { 1.0 };
            self.cost_j[k] = self.compute_j[k] + self.radio_j[k] * r;
            self.frac[k] = if self.capacity_j[k] > 0.0 {
                self.battery_j[k] / self.capacity_j[k]
            } else {
                1.0
            };
        }
    }

    /// The dispatcher's borrowed view of the caches (see
    /// [`EnergyScore`]); `EnergyScore::OFF`-equivalent when `weight` is 0.
    #[inline]
    pub fn score(&self) -> EnergyScore<'_> {
        EnergyScore {
            weight: self.weight,
            cost_j: &self.cost_j,
            frac: &self.frac,
        }
    }

    /// Pop the next freshly depleted device (FIFO — the order batteries
    /// actually died in, which both engines replay identically).
    #[inline]
    pub fn pop_depleted(&mut self) -> Option<usize> {
        if self.pending_head < self.pending.len() {
            let k = self.pending[self.pending_head];
            self.pending_head += 1;
            Some(k)
        } else {
            self.pending.clear();
            self.pending_head = 0;
            None
        }
    }

    /// Recharge episode length in sim nanoseconds (0 = permanent death).
    pub fn recharge_ns(&self) -> Nanos {
        self.recharge_ns
    }

    /// Complete a recharge episode for device `k`: battery back to full,
    /// idle clock restarted. Returns false when the device was not
    /// depleted (stale event — e.g. reset in between).
    pub fn recharge(&mut self, k: usize, now: Nanos) -> bool {
        if self.depleted[k] {
            self.depleted[k] = false;
            self.battery_j[k] = self.capacity_j[k];
            self.idle_from[k] = now;
            true
        } else {
            false
        }
    }

    /// True when device `k` is battery-dead: the fault layer's `Recover`
    /// must not resurrect it (only a recharge episode clears the flag).
    #[inline]
    pub fn blocks_recover(&self, k: usize) -> bool {
        self.enabled && self.depleted[k]
    }

    /// Settle every device's idle draw up to `end` (teardown; both
    /// engines call it with the same last-work instant, in cell order).
    pub fn settle_idle(&mut self, end: Nanos) {
        for k in 0..self.idle_from.len() {
            self.settle_idle_device(k, end);
        }
    }

    /// Total joules billed to the cell (sum over devices in index order).
    pub fn spent_total(&self) -> f64 {
        self.spent_j.iter().sum()
    }

    /// Devices whose battery hit zero at least once this run.
    pub fn depleted_count(&self) -> usize {
        self.ever_depleted.iter().filter(|&&d| d).count()
    }

    /// First/last battery-depletion instants (0 = none).
    pub fn first_depletion(&self) -> Nanos {
        self.first_depletion
    }

    pub fn last_depletion(&self) -> Nanos {
        self.last_depletion
    }

    /// Minimum remaining battery fraction across the cell's devices
    /// (1.0 when disabled or mains-powered) — the timeline's
    /// `battery_min` column.
    pub fn battery_min_frac(&self) -> f64 {
        if !self.enabled {
            return 1.0;
        }
        let mut min = 1.0f64;
        for k in 0..self.capacity_j.len() {
            if self.capacity_j[k] > 0.0 {
                min = min.min(self.battery_j[k] / self.capacity_j[k]);
            }
        }
        min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_base() -> EnergyConfig {
        let mut e = EnergyConfig::default();
        e.compute_j_per_token = 0.1;
        e.tx_j_per_token = 0.02;
        e.rx_j_per_token = 0.01;
        e
    }

    #[test]
    fn debit_is_cost_times_tokens_at_uniform_split() {
        let bw = [10e6, 10e6];
        let mut ce = CellEnergy::new(&cfg_base(), 0.0, 2, &bw);
        assert!(ce.enabled);
        ce.debit(0, 100.0, &bw, 1_000);
        // compute 0.1 + radio (0.02 + 0.01) * (ref/bw = 1) = 0.13 J/token
        assert!((ce.spent_total() - 13.0).abs() < 1e-9, "{}", ce.spent_total());
        assert_eq!(ce.depleted_count(), 0);
    }

    #[test]
    fn radio_cost_scales_with_bandwidth_share() {
        // Device 0 holds a quarter of the uniform share: radio pays 4x.
        let bw = [5e6, 35e6];
        let mut ce = CellEnergy::new(&cfg_base(), 0.0, 2, &bw);
        ce.debit(0, 10.0, &bw, 0);
        let ref_bw = 20e6;
        let want = 10.0 * (0.1 + 0.03 * (ref_bw / 5e6));
        assert!((ce.spent_total() - want).abs() < 1e-9);
        // Zero / non-finite shares fall back to the reference (mult 1).
        let dead_bw = [0.0, 40e6];
        ce.debit(0, 10.0, &dead_bw, 0);
        assert!((ce.spent_total() - want - 10.0 * 0.13).abs() < 1e-9);
    }

    #[test]
    fn depletion_fires_once_and_is_fifo() {
        let mut cfg = cfg_base();
        cfg.battery_j = 1.0;
        let bw = [1.0, 1.0, 1.0];
        let mut ce = CellEnergy::new(&cfg, 0.0, 3, &bw);
        ce.debit(2, 100.0, &bw, 5); // 13 J ≫ 1 J battery → depleted at t=5
        ce.debit(0, 100.0, &bw, 7);
        ce.debit(2, 100.0, &bw, 9); // already dead: billed, no re-push
        assert_eq!(ce.pop_depleted(), Some(2));
        assert_eq!(ce.pop_depleted(), Some(0));
        assert_eq!(ce.pop_depleted(), None);
        assert_eq!(ce.depleted_count(), 2);
        assert_eq!(ce.first_depletion(), 5);
        assert_eq!(ce.last_depletion(), 7);
        // Conservation: the full cost is billed even past depletion.
        assert!((ce.spent_total() - 3.0 * 13.0).abs() < 1e-9);
        assert!(ce.blocks_recover(2));
        assert!(!ce.blocks_recover(1));
    }

    #[test]
    fn recharge_restores_battery() {
        let mut cfg = cfg_base();
        cfg.battery_j = 1.0;
        cfg.recharge_s = 2.0;
        let bw = [1.0];
        let mut ce = CellEnergy::new(&cfg, 0.0, 1, &bw);
        assert_eq!(ce.recharge_ns(), 2_000_000_000);
        ce.debit(0, 100.0, &bw, 3);
        assert_eq!(ce.pop_depleted(), Some(0));
        assert!(ce.blocks_recover(0));
        assert!(ce.recharge(0, 10));
        assert!(!ce.blocks_recover(0));
        assert!(!ce.recharge(0, 11), "recharge on a live device is stale");
        assert_eq!(ce.battery_min_frac(), 1.0);
    }

    #[test]
    fn idle_draw_settles_lazily() {
        let mut cfg = cfg_base();
        cfg.idle_w = 2.0;
        let bw = [1.0];
        let mut ce = CellEnergy::new(&cfg, 0.0, 1, &bw);
        ce.settle_idle(1_500_000_000); // 1.5 s × 2 W = 3 J
        assert!((ce.spent_total() - 3.0).abs() < 1e-9);
        // Settling again to the same instant adds nothing.
        ce.settle_idle(1_500_000_000);
        assert!((ce.spent_total() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn classes_scale_costs_and_capacity() {
        let mut cfg = cfg_base();
        cfg.battery_j = 10.0;
        cfg.classes = EnergyConfig::class_preset("mixed").unwrap();
        let bw = [1.0; 4];
        let mut ce = CellEnergy::new(&cfg, 0.0, 4, &bw);
        // devices 0,2 = jetson (1.0x compute, 2x battery); 1,3 = phone
        // (2.5x compute, 1.5x radio, 1x battery).
        ce.debit(0, 10.0, &bw, 0);
        let jetson = 10.0 * (0.1 + 0.03);
        assert!((ce.spent_total() - jetson).abs() < 1e-9);
        ce.debit(1, 10.0, &bw, 0);
        let phone = 10.0 * (0.1 * 2.5 + 0.03 * 1.5);
        assert!((ce.spent_total() - jetson - phone).abs() < 1e-9);
        ce.refresh_scores(&bw);
        let s = ce.score();
        assert!(s.cost_j[1] > s.cost_j[0]);
        // phone battery (10 J) drains faster than jetson's (20 J)
        assert!(s.frac[1] < s.frac[0]);
    }

    #[test]
    fn battery_min_frac_tracks_worst_device() {
        let mut cfg = cfg_base();
        cfg.battery_j = 13.0;
        let bw = [1.0, 1.0];
        let mut ce = CellEnergy::new(&cfg, 0.0, 2, &bw);
        assert_eq!(ce.battery_min_frac(), 1.0);
        ce.debit(1, 50.0, &bw, 0); // 6.5 of 13 J
        assert!((ce.battery_min_frac() - 0.5).abs() < 1e-9);
        let off = CellEnergy::new(&EnergyConfig::default(), 0.0, 2, &bw);
        assert_eq!(off.battery_min_frac(), 1.0);
        assert!(!off.enabled);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let mut cfg = cfg_base();
        cfg.battery_j = 1.0;
        let bw = [1.0, 1.0];
        let mut ce = CellEnergy::new(&cfg, 0.5, 2, &bw);
        ce.debit(0, 100.0, &bw, 5);
        ce.refresh_scores(&bw);
        assert_eq!(ce.depleted_count(), 1);
        ce.reset();
        assert_eq!(ce.depleted_count(), 0);
        assert_eq!(ce.spent_total(), 0.0);
        assert_eq!(ce.first_depletion(), 0);
        assert_eq!(ce.pop_depleted(), None);
        assert_eq!(ce.battery_min_frac(), 1.0);
        assert_eq!(ce.weight, 0.5);
    }
}
