//! # `shard` — the sharded cluster DES: per-cell event queues with
//! conservative time-window sync
//!
//! [`ClusterSim::run_probed`] drives every cell from one global event
//! heap; its pop order is `(time, cell lane, seq)` — exactly the k-way
//! merge of per-cell event streams. This module exploits that: each
//! cell becomes a [`CellShard`] owning its *own* [`EventQueue`], local
//! request table, counters and sample log, and the shards advance
//! concurrently on the [`crate::exec`] scoped worker pool. Because the
//! serial order is a merge of independent per-cell streams, replaying
//! the shard-local logs in canonical `(time, cell, seq)` order at the
//! end rebuilds the serial observable sequence *by construction* —
//! outcomes, latency records, telemetry event streams and samples are
//! byte-identical to the serial engine, not merely statistically equal.
//!
//! ## Conservative lookahead
//!
//! Shards may only run ahead of each other as far as no cross-cell
//! interaction can reach them. The minimum inter-cell backhaul latency
//! ([`crate::config::ClusterConfig::min_backhaul_s_per_token`], per-pair
//! under a backhaul matrix) bounds how fast work can cross a cell
//! boundary, so it is the natural conservative sync window. Under
//! [`HandoverPolicy::None`] cells never interact at all — the lookahead
//! is infinite and the whole run is a single window per shard. The
//! interacting policies (`RehomeOnArrival`, `BorrowExpert`) read remote
//! cell state at *zero* latency (re-homing inspects live neighbor
//! backlog at the arrival instant), which gives them zero usable
//! lookahead — those runs fall back to the serial engine rather than
//! risk divergence. [`ClusterSim::set_sync_window_s`] forces a finite
//! window so tests exercise the window/barrier machinery; any positive
//! window yields identical output, smaller ones just synchronize more.
//!
//! ## Determinism contract
//!
//! * `run_sharded(arrivals, threads)` equals `run(arrivals)` bit-for-bit
//!   on every outcome field, at every thread count, for every config —
//!   enforced by `rust/tests/cluster.rs`.
//! * With a probe attached, the replayed event/sample streams are
//!   identical to the serial probe callbacks, so Chrome traces and
//!   timeline CSVs are byte-identical too.
//! * `threads == 1` (or one cell, or an interacting handover policy)
//!   *is* the serial engine — the entry point short-circuits to
//!   [`ClusterSim::run_probed`].
//!
//! Cross-shard effects (latency records, probe events, samples, shed
//! accounting) travel through per-shard ordered logs — the inter-shard
//! mailbox — drained on the coordinating thread in canonical order.
//! Floating-point accumulators that the serial loop updates in global
//! event order (steady-state latency, shed tokens) are *replayed* in
//! that order rather than summed per shard, so rounding is identical.
//!
//! ## Recorder monomorphization
//!
//! The shard loop is generic over a [`Recorder`] — [`NullProbe`] for
//! telemetry-off runs and [`EventLog`] when a real probe is attached —
//! selected once via [`Probe::is_null`]. The null recorder's empty
//! inlined methods monomorphize away, so "sharded, telemetry off"
//! carries no event-buffering cost, mirroring the serial engine's
//! `NullProbe` hot path.

use super::dispatch::Dispatcher;
use super::event::{nanos_from_secs, secs_from_nanos, EventQueue, Nanos};
use super::faults::{
    apply_action, resolve_lost_group, CellFaults, FaultAction, FaultEvent, InflightGroup,
    LossResolution,
};
use super::handover::HandoverCoordinator;
use super::sim::{
    cell_backlog_s, control_tick_at, sample_cell, start_block_at, Cell, ClusterOutcome,
    ClusterSim, Event, ReqState, SimParams,
};
use crate::config::HandoverPolicy;
use crate::exec;
use crate::metrics::SteadyState;
use crate::telemetry::{CellSample, NullProbe, Probe, TelemetryEvent};
use crate::util::clock::VirtualClock;
use crate::workload::Arrival;
use std::sync::Mutex;

/// Per-shard event sink: every probe event a shard emits is recorded
/// (with enough structure to replay it in canonical order later) or
/// provably discarded. Runs are the mailbox unit: all events emitted
/// while processing one popped DES event share the pop's timestamp, and
/// the drain interleaves whole runs with due samples exactly as the
/// serial loop would.
trait Recorder: Probe + Default + Send {
    /// Close the run for the pop at `at` (no-op when it emitted nothing).
    fn mark(&mut self, at: Nanos);
    /// Recorded `(pop time, events in run)` pairs, in shard-local order.
    fn runs(&self) -> &[(Nanos, u32)] {
        &[]
    }
    /// All recorded events, concatenated in run order.
    fn events(&self) -> &[TelemetryEvent] {
        &[]
    }
}

/// Telemetry off: record nothing, cost nothing.
impl Recorder for NullProbe {
    #[inline]
    fn mark(&mut self, _at: Nanos) {}
}

/// Telemetry on: buffer every event with its run boundary for the
/// canonical-order replay at the window drain.
#[derive(Default)]
struct EventLog {
    events: Vec<TelemetryEvent>,
    runs: Vec<(Nanos, u32)>,
    pending: u32,
}

impl Probe for EventLog {
    #[inline]
    fn on_event(&mut self, event: &TelemetryEvent) {
        self.events.push(*event);
        self.pending += 1;
    }
}

impl Recorder for EventLog {
    fn mark(&mut self, at: Nanos) {
        if self.pending > 0 {
            self.runs.push((at, self.pending));
            self.pending = 0;
        }
    }
    fn runs(&self) -> &[(Nanos, u32)] {
        &self.runs
    }
    fn events(&self) -> &[TelemetryEvent] {
        &self.events
    }
}

/// One cell's independent slice of the DES: its cell state, event
/// queue, the requests homed to it, and ordered logs of everything the
/// serial loop would have observed globally.
struct CellShard {
    ci: usize,
    n_cells: usize,
    params: SimParams,
    dispatcher: Dispatcher,
    /// Shard-local coordinator clone (policy is always
    /// [`HandoverPolicy::None`] here, so it never reads neighbors).
    handover: HandoverCoordinator,
    cell: Cell,
    queue: EventQueue<Event>,
    /// Requests homed to this cell; global request `i` lives at local
    /// index `i / n_cells` (arrivals are dealt round-robin).
    states: Vec<ReqState>,
    outstanding: usize,
    cadence: Option<Nanos>,
    next_sample: Nanos,
    /// This cell's sample rows, one per global cadence tick, recorded
    /// with the state the serial sampler would have seen.
    samples: Vec<CellSample>,
    /// `(completion time, latency ms)` in shard-local completion order.
    completions: Vec<(Nanos, f64)>,
    /// `(event time, shed tokens)` per block that shed, so the global
    /// f64 accumulation replays in serial order (addition order matters
    /// for bit-identity).
    sheds: Vec<(Nanos, f64)>,
    /// `(event time, wasted tokens)` per hedge / crash loss, replayed in
    /// serial order for the same bit-identity reason as `sheds`.
    wastes: Vec<(Nanos, f64)>,
    /// This cell's compiled fault lane (empty without a plan).
    lane: Vec<FaultEvent>,
    /// Fault runtime: lane cursor, live multipliers, offline accounting.
    rt: CellFaults,
    /// Scratch for the groups one crash strands (reused per fault pop).
    lost: Vec<InflightGroup>,
    slo_missed: usize,
    retries: usize,
    hedges: usize,
    arrived: usize,
    completed: usize,
    dropped: usize,
    arrived_tokens: u64,
    completed_tokens: u64,
    dropped_tokens: u64,
    handovers: usize,
    borrowed_groups: usize,
    borrowed_tokens: f64,
    events: usize,
    last_work_ns: Nanos,
    /// Last pop of *any* kind (control ticks included) — the global max
    /// bounds which trailing samples the serial loop would have fired.
    last_pop_ns: Nanos,
}

impl CellShard {
    fn new(
        ci: usize,
        n_cells: usize,
        mut cell: Cell,
        params: SimParams,
        dispatcher: Dispatcher,
        handover: HandoverCoordinator,
        cadence: Option<Nanos>,
        lane: Vec<FaultEvent>,
    ) -> Self {
        let rt = CellFaults::new(cell.dev.len());
        // Mirror of the serial fault arming: fresh multipliers and an
        // empty in-flight ledger at run start — armed by a compiled
        // lane *or* battery churn, matching the serial `FAULTS` gate.
        if params.faults {
            for m in &mut cell.dev.service_mult {
                *m = 1.0;
            }
            cell.inflight.clear();
        }
        Self {
            ci,
            n_cells,
            params,
            dispatcher,
            handover,
            cell,
            queue: EventQueue::new(VirtualClock::new()),
            states: Vec::new(),
            outstanding: 0,
            cadence,
            next_sample: cadence.unwrap_or(Nanos::MAX),
            samples: Vec::new(),
            completions: Vec::new(),
            sheds: Vec::new(),
            wastes: Vec::new(),
            lane,
            rt,
            lost: Vec::new(),
            slo_missed: 0,
            retries: 0,
            hedges: 0,
            arrived: 0,
            completed: 0,
            dropped: 0,
            arrived_tokens: 0,
            completed_tokens: 0,
            dropped_tokens: 0,
            handovers: 0,
            borrowed_groups: 0,
            borrowed_tokens: 0.0,
            events: 0,
            last_work_ns: 0,
            last_pop_ns: 0,
        }
    }

    /// Home global request `i` here (round-robin deal, in `i` order, so
    /// shard-local scheduling order matches the serial per-cell order).
    fn push_arrival(&mut self, i: usize, a: &Arrival) {
        debug_assert_eq!(i % self.n_cells, self.ci);
        let st = ReqState {
            tokens: a.tokens.max(1),
            cell: self.ci,
            arrived: nanos_from_secs(a.time_s),
            next_block: 0,
            handed_over: false,
            barrier: 0,
            dropped: false,
            retries: 0,
        };
        self.queue.schedule_at(st.arrived, Event::Arrive(i));
        self.states.push(st);
        self.outstanding += 1;
    }

    /// Mirror of the serial loop's initial control tick (scheduled after
    /// all arrivals, matching the serial per-cell seq order).
    fn schedule_control_tick(&mut self) {
        if let Some(e) = self.cell.plane.epoch_s() {
            self.queue
                .schedule_at(nanos_from_secs(e), Event::ControlTick(self.ci));
        }
    }

    /// Mirror of the serial loop's fault-lane arming: the first compiled
    /// event, scheduled *after* arrivals and the control tick so
    /// equal-time pops resolve in the serial seq order.
    fn schedule_fault(&mut self) {
        if let Some(ev) = self.lane.first() {
            self.queue.schedule_at(ev.at, Event::Fault(self.ci));
        }
    }

    /// Pop and process every event strictly before `window_end`.
    ///
    /// With a finite window, `record_idle` also records the cell's
    /// sample rows for every cadence tick up to the window edge — the
    /// cell is quiescent past its last pop, but a *later* window may
    /// mutate it, so rows must be captured before the barrier. With the
    /// infinite window the final post-drain state serves instead.
    fn advance<R: Recorder>(&mut self, rec: &mut R, window_end: Nanos, record_idle: bool) {
        while let Some(t) = self.queue.next_time() {
            if t >= window_end {
                break;
            }
            // detlint: allow(panic) next_time() returned Some, so a pop must succeed
            let (now, ev) = self.queue.pop().expect("peeked event present");
            while self.next_sample <= now {
                let row = sample_cell(&self.cell, self.next_sample);
                self.samples.push(row);
                self.next_sample = self
                    .next_sample
                    // detlint: allow(panic) next_sample is finite only when a cadence was set
                    .saturating_add(self.cadence.expect("a due sample implies a cadence"));
            }
            self.events += 1;
            self.last_pop_ns = now;
            self.step(ev, now, rec);
            rec.mark(now);
        }
        if let (true, Some(c)) = (record_idle, self.cadence) {
            while self.next_sample < window_end {
                let row = sample_cell(&self.cell, self.next_sample);
                self.samples.push(row);
                self.next_sample = self.next_sample.saturating_add(c);
            }
        }
    }

    /// Shard-local mirror of the serial engine's depletion drain: each
    /// freshly dead battery becomes a deterministic `Crash` through the
    /// exact fault path (ledger sweep, re-dispatch / drop / shed), plus
    /// an optional recharge episode. Runs at the same structural points
    /// as the serial loop; this shard never borrows, so only its own
    /// cell can hold pending depletions.
    fn drain_depletions<R: Recorder>(&mut self, now: Nanos, rec: &mut R) {
        while let Some(k) = self.cell.energy.pop_depleted() {
            rec.on_event(&TelemetryEvent::BatteryDepleted {
                cell: self.ci,
                device: k,
                t: now,
            });
            let mut lost = std::mem::take(&mut self.lost);
            lost.clear();
            apply_action(
                FaultAction::Crash { device: k },
                self.ci,
                now,
                &mut self.cell,
                &mut self.rt,
                &mut self.handover,
                &mut lost,
                rec,
            );
            if self.cell.energy.recharge_ns() > 0 {
                let done = now.saturating_add(self.cell.energy.recharge_ns());
                self.queue.schedule_at(done, Event::Recharge(self.ci, k));
            }
            for g in &lost {
                debug_assert_eq!(g.req % self.n_cells, self.ci);
                let st = &mut self.states[g.req / self.n_cells];
                if st.dropped {
                    continue;
                }
                match resolve_lost_group(
                    g,
                    st,
                    self.ci,
                    now,
                    &mut self.cell,
                    &self.dispatcher,
                    &self.params,
                    rec,
                ) {
                    LossResolution::Covered => {}
                    LossResolution::Redispatched { waste } => {
                        self.retries += 1;
                        if waste > 0.0 {
                            self.wastes.push((now, waste));
                        }
                    }
                    LossResolution::Dropped { waste } => {
                        if waste > 0.0 {
                            self.wastes.push((now, waste));
                        }
                        self.dropped += 1;
                        self.dropped_tokens += st.tokens as u64;
                        self.outstanding -= 1;
                        if self.params.deadline_s > 0.0 {
                            self.slo_missed += 1;
                        }
                    }
                    LossResolution::Shed { tokens, waste } => {
                        self.sheds.push((now, tokens));
                        if waste > 0.0 {
                            self.wastes.push((now, waste));
                        }
                    }
                }
            }
            self.lost = lost;
        }
    }

    /// One DES event — the shard-local mirror of the serial match arms.
    /// Under [`HandoverPolicy::None`] an arrival's re-home is the
    /// identity and block dispatch never reads neighbor cells, so empty
    /// neighbor slices are passed to [`start_block_at`].
    fn step<R: Recorder>(&mut self, ev: Event, now: Nanos, rec: &mut R) {
        let i = match ev {
            Event::ControlTick(ci) => {
                debug_assert_eq!(ci, self.ci);
                if self.outstanding > 0 {
                    control_tick_at(&mut self.cell, self.ci, now, rec);
                    if let Some(e) = self.cell.plane.epoch_s() {
                        self.queue
                            .schedule_in(nanos_from_secs(e), Event::ControlTick(self.ci));
                    }
                }
                return;
            }
            Event::Fault(ci) => {
                debug_assert_eq!(ci, self.ci);
                // Shard-local mirror of the serial Fault arm: apply,
                // re-arm the lane, resolve stranded groups. Fault pops
                // never advance `last_work_ns`.
                let fev = self.lane[self.rt.cursor];
                self.rt.cursor += 1;
                if let Some(next) = self.lane.get(self.rt.cursor) {
                    self.queue.schedule_at(next.at, Event::Fault(self.ci));
                }
                let mut lost = std::mem::take(&mut self.lost);
                lost.clear();
                apply_action(
                    fev.action,
                    self.ci,
                    now,
                    &mut self.cell,
                    &mut self.rt,
                    &mut self.handover,
                    &mut lost,
                    rec,
                );
                for g in &lost {
                    debug_assert_eq!(g.req % self.n_cells, self.ci);
                    let st = &mut self.states[g.req / self.n_cells];
                    if st.dropped {
                        continue;
                    }
                    match resolve_lost_group(
                        g,
                        st,
                        self.ci,
                        now,
                        &mut self.cell,
                        &self.dispatcher,
                        &self.params,
                        rec,
                    ) {
                        LossResolution::Covered => {}
                        LossResolution::Redispatched { waste } => {
                            self.retries += 1;
                            if waste > 0.0 {
                                self.wastes.push((now, waste));
                            }
                        }
                        LossResolution::Dropped { waste } => {
                            if waste > 0.0 {
                                self.wastes.push((now, waste));
                            }
                            self.dropped += 1;
                            self.dropped_tokens += st.tokens as u64;
                            self.outstanding -= 1;
                            if self.params.deadline_s > 0.0 {
                                self.slo_missed += 1;
                            }
                        }
                        LossResolution::Shed { tokens, waste } => {
                            self.sheds.push((now, tokens));
                            if waste > 0.0 {
                                self.wastes.push((now, waste));
                            }
                        }
                    }
                }
                self.lost = lost;
                if self.params.energy {
                    // A crash re-dispatch above debits the surviving
                    // replica: drain any battery it finished off.
                    self.drain_depletions(now, rec);
                }
                return;
            }
            Event::Recharge(ci, k) => {
                debug_assert_eq!(ci, self.ci);
                // Shard-local mirror of the serial Recharge arm: the
                // energy layer clears the depletion, then the ordinary
                // fault `Recover` path brings the device back online.
                // Recharge pops never advance `last_work_ns`.
                if self.params.energy && self.cell.energy.recharge(k, now) {
                    let mut lost = std::mem::take(&mut self.lost);
                    lost.clear();
                    apply_action(
                        FaultAction::Recover { device: k },
                        self.ci,
                        now,
                        &mut self.cell,
                        &mut self.rt,
                        &mut self.handover,
                        &mut lost,
                        rec,
                    );
                    self.lost = lost;
                }
                return;
            }
            Event::Arrive(i) => {
                let st = &self.states[i / self.n_cells];
                self.arrived += 1;
                self.arrived_tokens += st.tokens as u64;
                self.last_work_ns = now;
                rec.on_event(&TelemetryEvent::Arrive {
                    req: i,
                    tokens: st.tokens,
                    rr_home: self.ci,
                    cell: self.ci,
                    t: now,
                });
                i
            }
            Event::BlockDone(i) => {
                let st = &mut self.states[i / self.n_cells];
                if self.params.faults {
                    // Tombstone / barrier chase — the serial gates,
                    // runtime-checked here (the shard loop is not
                    // monomorphized over the fault flag).
                    if st.dropped {
                        return;
                    }
                    if st.barrier > now {
                        self.queue.schedule_at(st.barrier, Event::BlockDone(i));
                        return;
                    }
                }
                self.last_work_ns = now;
                st.next_block += 1;
                if st.next_block >= self.params.n_blocks {
                    self.completed += 1;
                    self.completed_tokens += st.tokens as u64;
                    self.outstanding -= 1;
                    let lat_ms = secs_from_nanos(now - st.arrived) * 1e3;
                    self.completions.push((now, lat_ms));
                    if self.params.deadline_s > 0.0 && lat_ms > self.params.deadline_s * 1e3 {
                        self.slo_missed += 1;
                    }
                    rec.on_event(&TelemetryEvent::Completed {
                        req: i,
                        cell: self.ci,
                        t: now,
                        latency_ms: lat_ms,
                    });
                    return;
                }
                i
            }
        };
        if self.params.backlog_delta_s > 0.0 {
            let cell = &self.cell;
            if cell.plane.epoch_s().is_some()
                && (cell_backlog_s(cell, now) - cell.last_solve_backlog_s).abs()
                    > self.params.backlog_delta_s
            {
                control_tick_at(&mut self.cell, self.ci, now, rec);
            }
        }
        let li = i / self.n_cells;
        let r = start_block_at(
            &self.params,
            &self.dispatcher,
            &mut self.handover,
            &mut self.cell,
            &mut [],
            &mut [],
            &self.states[li],
            i,
            now,
            rec,
        );
        if r.shed_tokens > 0.0 {
            // Adding 0.0 is exact, so zero-shed blocks need no log entry.
            self.sheds.push((now, r.shed_tokens));
        }
        if r.wasted_tokens > 0.0 {
            self.wastes.push((now, r.wasted_tokens));
        }
        self.hedges += r.hedges;
        self.borrowed_groups += r.borrowed_groups;
        // detlint: allow(float-order) shard-local accumulator; BorrowExpert runs serially, so cross-shard order never arises
        self.borrowed_tokens += r.borrowed_tokens;
        if r.borrowed_groups > 0 && !self.states[li].handed_over {
            self.states[li].handed_over = true;
            self.handovers += 1;
        }
        match r.end {
            Some(block_end) => {
                rec.on_event(&TelemetryEvent::Block {
                    req: i,
                    cell: self.ci,
                    block: self.states[li].next_block,
                    start: now,
                    end: block_end,
                });
                self.queue.schedule_at(block_end, Event::BlockDone(i));
                if self.params.faults {
                    self.states[li].barrier = block_end;
                }
            }
            None => {
                self.dropped += 1;
                self.dropped_tokens += self.states[li].tokens as u64;
                self.outstanding -= 1;
                if self.params.deadline_s > 0.0 {
                    self.slo_missed += 1;
                }
                rec.on_event(&TelemetryEvent::Dropped {
                    req: i,
                    cell: self.ci,
                    t: now,
                });
            }
        }
        if self.params.faults && self.params.energy {
            // Same structural point as the serial engine's post-block
            // drain: batteries this block's debits finished off crash
            // now, before any later event.
            self.drain_depletions(now, rec);
        }
    }
}

/// Deliver one sample tick: assemble the per-cell rows (recorded shard
/// rows where present; a shard that went quiet before `t` — infinite
/// window only — is read from its final, already-correct state).
fn deliver_sample<P: Probe, R>(
    shards: &[(CellShard, R)],
    probe: &mut P,
    t: Nanos,
    idx: usize,
    rows: &mut Vec<CellSample>,
) {
    rows.clear();
    for (sh, _) in shards {
        rows.push(match sh.samples.get(idx) {
            Some(&row) => row,
            None => sample_cell(&sh.cell, t),
        });
    }
    probe.on_sample(t, rows);
}

/// K-way merge of per-shard `(time, value)` logs in canonical
/// `(time, cell)` order — ties resolve lowest cell first, preserving
/// shard-local order within a cell, i.e. the serial pop order.
fn merge_in_order<R, T: Copy>(
    shards: &[(CellShard, R)],
    get: impl Fn(&CellShard) -> &[(Nanos, T)],
    mut emit: impl FnMut(T),
) {
    let mut cur = vec![0usize; shards.len()];
    loop {
        let mut best: Option<(Nanos, usize)> = None;
        for (ci, (sh, _)) in shards.iter().enumerate() {
            if let Some(&(at, _)) = get(sh).get(cur[ci]) {
                let better = match best {
                    None => true,
                    Some((bat, _)) => at < bat,
                };
                if better {
                    best = Some((at, ci));
                }
            }
        }
        let Some((_, ci)) = best else { break };
        let (_, v) = get(&shards[ci].0)[cur[ci]];
        cur[ci] += 1;
        emit(v);
    }
}

impl ClusterSim {
    /// Sharded counterpart of [`ClusterSim::run`]: per-cell shards on up
    /// to `threads` workers (0 = one per core), byte-identical outcome.
    pub fn run_sharded(&mut self, arrivals: &[Arrival], threads: usize) -> ClusterOutcome {
        self.run_sharded_probed(arrivals, threads, &mut NullProbe)
    }

    /// Sharded counterpart of [`ClusterSim::run_probed`]. The probe
    /// observes the replayed canonical event/sample streams — identical
    /// callbacks, in identical order, to the serial engine.
    ///
    /// Falls back to the serial loop when sharding cannot help or would
    /// require zero-lookahead cross-cell reads: a single cell, a single
    /// worker, or an interacting handover policy (re-homing and borrow
    /// both inspect live neighbor state at the event instant).
    pub fn run_sharded_probed<P: Probe>(
        &mut self,
        arrivals: &[Arrival],
        threads: usize,
        probe: &mut P,
    ) -> ClusterOutcome {
        let n_cells = self.cells.len();
        let workers = exec::resolve_threads(threads).min(n_cells.max(1));
        if n_cells <= 1 || workers <= 1 || self.handover.policy() != HandoverPolicy::None {
            // Silent for the structural cases (one cell / one worker —
            // sharding simply cannot help), but a user who asked for
            // threads *and* an interacting handover policy should learn
            // why the run is serial.
            if n_cells > 1 && workers > 1 {
                eprintln!(
                    "repro: handover policy '{}' reads neighbor state with zero lookahead; \
                     running the serial engine instead of {} threads (output is identical)",
                    self.handover.policy().as_str(),
                    workers
                );
            }
            return self.run_probed(arrivals, probe);
        }
        if probe.is_null() {
            self.run_sharded_inner::<P, NullProbe>(arrivals, threads, probe)
        } else {
            self.run_sharded_inner::<P, EventLog>(arrivals, threads, probe)
        }
    }

    fn run_sharded_inner<P: Probe, R: Recorder>(
        &mut self,
        arrivals: &[Arrival],
        threads: usize,
        probe: &mut P,
    ) -> ClusterOutcome {
        let n_cells = self.cells.len();
        let cadence = probe.sample_cadence().map(|c| c.max(1));
        // Conservative sync window. Under `HandoverPolicy::None` (the
        // only policy that reaches here) cells are fully independent:
        // the lookahead is unbounded and the run is one window. A
        // `set_sync_window_s` override exercises the finite-window
        // barrier machinery; output is identical for any positive value.
        let window = self
            .sync_window_s
            .map(nanos_from_secs)
            .filter(|&w| w > 0)
            .unwrap_or(Nanos::MAX);
        let finite = window != Nanos::MAX;

        let cells = std::mem::take(&mut self.cells);
        let mut shards: Vec<CellShard> = cells
            .into_iter()
            .enumerate()
            .map(|(ci, cell)| {
                CellShard::new(
                    ci,
                    n_cells,
                    cell,
                    self.params,
                    self.dispatcher,
                    self.handover.clone(),
                    cadence,
                    self.fault_lanes[ci].clone(),
                )
            })
            .collect();
        for (i, a) in arrivals.iter().enumerate() {
            shards[i % n_cells].push_arrival(i, a);
        }
        for sh in &mut shards {
            sh.schedule_control_tick();
        }
        // Fault lanes arm last, matching the serial setup seq order.
        for sh in &mut shards {
            sh.schedule_fault();
        }

        // Window barrier loop: every shard advances to the window edge
        // in parallel, the coordinator re-arms, until all queues drain.
        // Slots hand each worker exclusive ownership of its shard (and
        // recorder) without moving them across the scope boundary.
        let slots: Vec<Mutex<Option<(CellShard, R)>>> = shards
            .into_iter()
            .map(|s| Mutex::new(Some((s, R::default()))))
            .collect();
        let mut window_end = window;
        loop {
            exec::map_indexed(n_cells, threads, |ci| {
                // detlint: allow(panic) lock poisoning means a worker already panicked; propagate
                let mut slot = slots[ci].lock().expect("shard slot poisoned");
                // detlint: allow(panic) slots are filled above and never vacated mid-run
                let (shard, rec) = slot.as_mut().expect("shard present");
                shard.advance(rec, window_end, finite);
            });
            let drained = slots.iter().all(|s| {
                s.lock()
                    // detlint: allow(panic) lock poisoning means a worker already panicked; propagate
                    .expect("shard slot poisoned")
                    .as_ref()
                    // detlint: allow(panic) slots are filled above and never vacated mid-run
                    .expect("shard present")
                    .0
                    .queue
                    .is_empty()
            });
            if drained {
                break;
            }
            window_end = window_end.saturating_add(window);
        }
        let shards: Vec<(CellShard, R)> = slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    // detlint: allow(panic) lock poisoning means a worker already panicked; propagate
                    .expect("shard slot poisoned")
                    // detlint: allow(panic) slots are filled above and never vacated mid-run
                    .expect("shard present")
            })
            .collect();

        // ---- Drain the mailboxes in canonical (time, cell, seq) order.
        // The serial loop fires a sample tick at `s` on the first pop at
        // or after `s`, so the last tick fired is bounded by the last
        // pop anywhere (control ticks included).
        let t_pop_max = shards
            .iter()
            .map(|(sh, _)| sh.last_pop_ns)
            .max()
            .unwrap_or(0);
        let mut next_sample = cadence.unwrap_or(Nanos::MAX);
        let mut sample_idx = 0usize;
        let mut rows: Vec<CellSample> = Vec::with_capacity(n_cells);
        let mut run_cur = vec![0usize; n_cells];
        let mut ev_cur = vec![0usize; n_cells];
        loop {
            let mut best: Option<(Nanos, usize)> = None;
            for (ci, (_, rec)) in shards.iter().enumerate() {
                if let Some(&(at, _)) = rec.runs().get(run_cur[ci]) {
                    let better = match best {
                        None => true,
                        Some((bat, _)) => at < bat,
                    };
                    if better {
                        best = Some((at, ci));
                    }
                }
            }
            let Some((at, ci)) = best else { break };
            while next_sample <= at {
                deliver_sample(&shards, probe, next_sample, sample_idx, &mut rows);
                sample_idx += 1;
                next_sample = next_sample
                    // detlint: allow(panic) next_sample is finite only when a cadence was set
                    .saturating_add(cadence.expect("a due sample implies a cadence"));
            }
            let (_, count) = shards[ci].1.runs()[run_cur[ci]];
            run_cur[ci] += 1;
            let start = ev_cur[ci];
            ev_cur[ci] = start + count as usize;
            for e in &shards[ci].1.events()[start..start + count as usize] {
                probe.on_event(e);
            }
        }
        // Trailing ticks past the last recorded run but within the pop
        // horizon (the serial loop fires them off event-less pops).
        while next_sample <= t_pop_max {
            deliver_sample(&shards, probe, next_sample, sample_idx, &mut rows);
            sample_idx += 1;
            next_sample = next_sample
                // detlint: allow(panic) next_sample is finite only when a cadence was set
                .saturating_add(cadence.expect("a due sample implies a cadence"));
        }

        // Latency and shed-token accumulators replay in serial order so
        // floating-point rounding is bit-identical, not just close.
        let mut latency_ms = SteadyState::with_capacity(self.params.warmup_frac, arrivals.len());
        merge_in_order(&shards, |sh| &sh.completions, |lat| latency_ms.record(lat));
        let mut shed_tokens = 0.0f64;
        merge_in_order(&shards, |sh| &sh.sheds, |s| shed_tokens += s);
        let mut wasted_tokens = 0.0f64;
        merge_in_order(&shards, |sh| &sh.wastes, |w| wasted_tokens += w);

        let mut arrived = 0usize;
        let mut completed = 0usize;
        let mut dropped = 0usize;
        let mut arrived_tokens = 0u64;
        let mut completed_tokens = 0u64;
        let mut dropped_tokens = 0u64;
        let mut handovers = 0usize;
        let mut borrowed_groups = 0usize;
        let mut borrowed_tokens = 0.0f64;
        let mut slo_missed = 0usize;
        let mut retries = 0usize;
        let mut hedges = 0usize;
        let mut events = 0usize;
        let mut last_work_ns: Nanos = 0;
        for (sh, _) in &shards {
            arrived += sh.arrived;
            completed += sh.completed;
            dropped += sh.dropped;
            arrived_tokens += sh.arrived_tokens;
            completed_tokens += sh.completed_tokens;
            dropped_tokens += sh.dropped_tokens;
            handovers += sh.handovers;
            borrowed_groups += sh.borrowed_groups;
            borrowed_tokens += sh.borrowed_tokens;
            slo_missed += sh.slo_missed;
            retries += sh.retries;
            hedges += sh.hedges;
            events += sh.events;
            last_work_ns = last_work_ns.max(sh.last_work_ns);
        }
        // Offline device-seconds: closed intervals from each shard's
        // runtime, plus still-open outages clamped to the *global* last
        // work instant (the same clamp the serial loop applies). Integer
        // sums are order-free, so per-shard accumulation is exact.
        let mut offline_ns: u64 = 0;
        if self.params.faults {
            // Armed by a compiled lane or battery churn — a depleted
            // device is offline the same way a crashed one is.
            for (sh, _) in &shards {
                offline_ns += sh.rt.offline_ns;
                for (k, &on) in sh.cell.dev.online.iter().enumerate() {
                    if !on {
                        offline_ns += last_work_ns.saturating_sub(sh.rt.offline_since[k]);
                    }
                }
            }
        }

        self.cells = shards.into_iter().map(|(sh, _)| sh.cell).collect();

        // Energy teardown: identical to the serial engine — settle idle
        // draw to the same global last-work instant, then total joules
        // in cell index order so the f64 sum is byte-stable.
        let mut energy_j = 0.0f64;
        let mut energy_cells: Vec<f64> = Vec::new();
        let mut depleted_cells: Vec<usize> = Vec::new();
        let mut first_depletion: Nanos = 0;
        let mut last_depletion: Nanos = 0;
        if self.params.energy {
            for cell in &mut self.cells {
                cell.energy.settle_idle(last_work_ns);
                let spent = cell.energy.spent_total();
                energy_j += spent;
                energy_cells.push(spent);
                depleted_cells.push(cell.energy.depleted_count());
                let f = cell.energy.first_depletion();
                if f != 0 && (first_depletion == 0 || f < first_depletion) {
                    first_depletion = f;
                }
                last_depletion = last_depletion.max(cell.energy.last_depletion());
            }
        }

        let makespan_s = secs_from_nanos(last_work_ns);
        let utilization = self
            .cells
            .iter()
            .map(|c| c.dev.busy.iter().map(|u| u.fraction(makespan_s)).collect())
            .collect();
        let control = self.cells.iter().map(|c| c.plane.stats()).collect();
        let mut solver = crate::control::SolverIntrospection::default();
        for c in &self.cells {
            solver.absorb(&c.plane.solver_stats());
        }
        ClusterOutcome {
            arrived,
            completed,
            dropped,
            arrived_tokens,
            completed_tokens,
            dropped_tokens,
            shed_tokens,
            handovers,
            borrowed_groups,
            borrowed_tokens,
            in_flight: arrived - completed - dropped,
            events,
            makespan_s,
            latency_ms,
            utilization,
            control,
            solver,
            slo_missed,
            retries,
            hedges,
            wasted_tokens,
            offline_device_s: secs_from_nanos(offline_ns),
            energy_j,
            energy_cells,
            depleted_cells,
            first_depletion,
            last_depletion,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ControlKind};
    use crate::workload::{ArrivalProcess, Benchmark};

    fn cfg(n_cells: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::edge_default().with_n_cells(n_cells);
        cfg.model.n_blocks = 4; // keep tests fast
        cfg
    }

    fn arrivals(n: usize, rate: f64, seed: u64) -> Vec<Arrival> {
        ArrivalProcess::Poisson { rate_rps: rate }.generate(n, Benchmark::Piqa, seed)
    }

    fn assert_outcomes_identical(a: &ClusterOutcome, b: &ClusterOutcome) {
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.arrived_tokens, b.arrived_tokens);
        assert_eq!(a.completed_tokens, b.completed_tokens);
        assert_eq!(a.dropped_tokens, b.dropped_tokens);
        assert_eq!(a.shed_tokens, b.shed_tokens);
        assert_eq!(a.handovers, b.handovers);
        assert_eq!(a.borrowed_groups, b.borrowed_groups);
        assert_eq!(a.borrowed_tokens, b.borrowed_tokens);
        assert_eq!(a.in_flight, b.in_flight);
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency_ms.steady_values(), b.latency_ms.steady_values());
        assert_eq!(a.utilization, b.utilization);
        assert_eq!(a.control, b.control);
        assert_eq!(a.solver, b.solver);
        assert_eq!(a.slo_missed, b.slo_missed);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.hedges, b.hedges);
        assert_eq!(a.wasted_tokens, b.wasted_tokens);
        assert_eq!(a.offline_device_s, b.offline_device_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.energy_cells, b.energy_cells);
        assert_eq!(a.depleted_cells, b.depleted_cells);
        assert_eq!(a.first_depletion, b.first_depletion);
        assert_eq!(a.last_depletion, b.last_depletion);
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let arr = arrivals(48, 12.0, 7);
        let mut serial = ClusterSim::new(&cfg(4)).unwrap();
        let base = serial.run(&arr);
        for threads in [2, 4] {
            let mut sim = ClusterSim::new(&cfg(4)).unwrap();
            let out = sim.run_sharded(&arr, threads);
            assert_outcomes_identical(&base, &out);
        }
    }

    #[test]
    fn sharded_matches_serial_with_fault_plan() {
        use crate::config::{FaultKind, ScheduledFault};
        let mut c = cfg(4);
        c.faults.mttf_s = 6.0;
        c.faults.mttr_s = 1.5;
        c.faults.straggler_mtbf_s = 4.0;
        c.faults.straggler_duration_s = 2.0;
        c.faults.horizon_s = 20.0;
        c.faults.scheduled.push(ScheduledFault {
            at_s: 0.5,
            cell: 1,
            device: None,
            kind: FaultKind::Crash,
            duration_s: 1.0,
            mult: 1.0,
        });
        c.deadline_s = 2.0;
        c.hedge = true;
        let arr = arrivals(48, 14.0, 9);
        let mut serial = ClusterSim::new(&c).unwrap();
        let base = serial.run(&arr);
        for threads in [2, 4] {
            let mut sim = ClusterSim::new(&c).unwrap();
            let out = sim.run_sharded(&arr, threads);
            assert_outcomes_identical(&base, &out);
        }
    }

    #[test]
    fn sharded_matches_serial_with_energy_and_battery_churn() {
        let mut c = cfg(4);
        c.cache_capacity = 2;
        c.dispatch = crate::config::DispatchKind::LoadAware;
        c.energy.compute_j_per_token = 0.5;
        c.energy.tx_j_per_token = 0.05;
        c.energy.rx_j_per_token = 0.02;
        c.energy.idle_w = 0.5;
        c.energy.battery_j = 100.0;
        c.energy.recharge_s = 0.5;
        c.energy.classes = crate::config::EnergyConfig::class_preset("mixed").unwrap();
        c.energy_weight = 0.5;
        let arr = arrivals(48, 14.0, 21);
        let mut serial = ClusterSim::new(&c).unwrap();
        let base = serial.run(&arr);
        assert!(base.energy_j > 0.0, "energy model never billed");
        for threads in [2, 4] {
            let mut sim = ClusterSim::new(&c).unwrap();
            let out = sim.run_sharded(&arr, threads);
            assert_outcomes_identical(&base, &out);
        }
    }

    #[test]
    fn sharded_energy_off_matches_serial_pre_energy_shape() {
        // Accounting-only energy (no battery) must not arm the fault
        // machinery: events and outcomes stay identical across engines.
        let mut c = cfg(3);
        c.energy.compute_j_per_token = 1e-3;
        let arr = arrivals(30, 9.0, 5);
        let mut serial = ClusterSim::new(&c).unwrap();
        let base = serial.run(&arr);
        assert!(base.energy_j > 0.0);
        assert_eq!(base.depleted_devices(), 0);
        let mut sim = ClusterSim::new(&c).unwrap();
        let out = sim.run_sharded(&arr, 3);
        assert_outcomes_identical(&base, &out);
    }

    #[test]
    fn sharded_matches_serial_with_adaptive_control() {
        let mut c = cfg(4);
        c.control = ControlKind::Adaptive;
        let arr = arrivals(40, 16.0, 11);
        let mut serial = ClusterSim::new(&c).unwrap();
        let base = serial.run(&arr);
        let mut sim = ClusterSim::new(&c).unwrap();
        let out = sim.run_sharded(&arr, 4);
        assert_outcomes_identical(&base, &out);
    }

    #[test]
    fn finite_sync_window_changes_nothing() {
        let mut c = cfg(3);
        c.control = ControlKind::Adaptive;
        let arr = arrivals(30, 9.0, 5);
        let mut serial = ClusterSim::new(&c).unwrap();
        let base = serial.run(&arr);
        for window_s in [0.01, 0.2, 5.0] {
            let mut sim = ClusterSim::new(&c).unwrap();
            sim.set_sync_window_s(Some(window_s));
            let out = sim.run_sharded(&arr, 3);
            assert_outcomes_identical(&base, &out);
        }
    }

    #[test]
    fn single_cell_and_single_thread_fall_back_to_serial() {
        let arr = arrivals(20, 4.0, 1);
        let mut one_cell = ClusterSim::new(&cfg(1)).unwrap();
        let a = one_cell.run_sharded(&arr, 4);
        let mut serial = ClusterSim::new(&cfg(1)).unwrap();
        assert_outcomes_identical(&serial.run(&arr), &a);

        let mut one_thread = ClusterSim::new(&cfg(4)).unwrap();
        let b = one_thread.run_sharded(&arr, 1);
        let mut serial4 = ClusterSim::new(&cfg(4)).unwrap();
        assert_outcomes_identical(&serial4.run(&arr), &b);
    }

    #[test]
    fn interacting_handover_policies_fall_back_to_serial() {
        for policy in [HandoverPolicy::RehomeOnArrival, HandoverPolicy::BorrowExpert] {
            let mut c = cfg(3);
            c.handover = policy;
            let arr = arrivals(24, 8.0, 2);
            let mut serial = ClusterSim::new(&c).unwrap();
            let base = serial.run(&arr);
            let mut sim = ClusterSim::new(&c).unwrap();
            let out = sim.run_sharded(&arr, 3);
            assert_outcomes_identical(&base, &out);
        }
    }

    #[test]
    fn probe_streams_replay_in_serial_order() {
        #[derive(Default)]
        struct Trail {
            log: Vec<String>,
        }
        impl Probe for Trail {
            fn sample_cadence(&self) -> Option<Nanos> {
                Some(5_000_000) // 5 ms of sim time
            }
            fn on_event(&mut self, event: &TelemetryEvent) {
                self.log.push(format!("{event:?}"));
            }
            fn on_sample(&mut self, t: Nanos, cells: &[CellSample]) {
                self.log.push(format!("sample@{t}:{cells:?}"));
            }
        }

        let mut c = cfg(4);
        c.control = ControlKind::Adaptive;
        let arr = arrivals(32, 20.0, 13);

        let mut serial = ClusterSim::new(&c).unwrap();
        let mut base_probe = Trail::default();
        let base = serial.run_probed(&arr, &mut base_probe);

        let mut sim = ClusterSim::new(&c).unwrap();
        let mut probe = Trail::default();
        let out = sim.run_sharded_probed(&arr, 4, &mut probe);

        assert_outcomes_identical(&base, &out);
        assert_eq!(base_probe.log.len(), probe.log.len());
        assert_eq!(base_probe.log, probe.log);
    }

    #[test]
    fn reset_after_sharded_run_restores_fresh_behaviour() {
        let arr = arrivals(24, 8.0, 3);
        let mut sim = ClusterSim::new(&cfg(2)).unwrap();
        let a = sim.run_sharded(&arr, 2);
        sim.reset().unwrap();
        let b = sim.run_sharded(&arr, 2);
        assert_outcomes_identical(&a, &b);
    }
}
