//! The multi-cell discrete-event serving simulator.
//!
//! Requests arrive open-loop (Poisson or trace replay), are assigned to a
//! cell round-robin, and walk the model's `I` MoE blocks one by one. Per
//! block the cell's gate draws weights, the configured selection policy
//! picks experts (Algorithm 1 / top-k / …), and the dispatcher routes
//! each selected expert's token group to one of its replicas. Token
//! groups join that device's FIFO queue; the block completes when its
//! last group finishes (the Eq. (11) attention barrier), at which point
//! the next block starts. Queueing delay, utilization and tail latency
//! all *emerge* from contention between in-flight requests — nothing is
//! assumed.

use super::dispatch::Dispatcher;
use super::event::{nanos_from_secs, secs_from_nanos, EventQueue, Nanos};
use super::placement::Placement;
use crate::config::ClusterConfig;
use crate::devices::Fleet;
use crate::latency::TokenLatencies;
use crate::metrics::{SteadyState, Summary, Table, Utilization};
use crate::moe::selection::{make_policy, SelectionContext, SelectionPolicy};
use crate::moe::GateWeights;
use crate::optim::PerBlockLoad;
use crate::util::clock::VirtualClock;
use crate::wireless::bandwidth::AllocationInput;
use crate::wireless::ChannelSimulator;
use crate::workload::{ArrivalProcess, Benchmark, WorkloadGen};

/// One cell's runtime state: fleet, placement, policy and FIFO queues.
struct Cell {
    /// Per-device service seconds per token (comm + comp, Eq. (8)) under
    /// the cell's uniform bandwidth share.
    t_per_token: Vec<f64>,
    placement: Placement,
    policy: Box<dyn SelectionPolicy>,
    gates: WorkloadGen,
    /// Instant each device's FIFO queue drains.
    busy_until: Vec<Nanos>,
    busy: Vec<Utilization>,
    online: Vec<bool>,
}

enum Event {
    Arrive(usize),
    BlockDone(usize),
}

struct ReqState {
    tokens: usize,
    cell: usize,
    arrived: Nanos,
    next_block: usize,
}

/// Result of one simulation run (all arrivals drained).
#[derive(Debug)]
pub struct ClusterOutcome {
    pub arrived: usize,
    pub completed: usize,
    pub arrived_tokens: u64,
    pub completed_tokens: u64,
    /// Requests still in flight when the event queue drained (0 by
    /// construction for finite arrival streams — the conservation law).
    pub in_flight: usize,
    /// Virtual time of the last event.
    pub makespan_s: f64,
    /// End-to-end request latency (ms), recorded in completion order.
    pub latency_ms: SteadyState,
    /// `utilization[cell][device]` — busy fraction of the makespan.
    pub utilization: Vec<Vec<f64>>,
}

impl ClusterOutcome {
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }

    /// Steady-state latency summary (warm-up discarded).
    pub fn steady_latency(&self) -> Summary {
        self.latency_ms.steady()
    }

    pub fn p50_ms(&self) -> f64 {
        self.steady_latency().percentile(50.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.steady_latency().percentile(95.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.steady_latency().percentile(99.0)
    }

    /// All per-device utilizations, cells concatenated.
    pub fn flat_utilization(&self) -> Vec<f64> {
        self.utilization.iter().flatten().copied().collect()
    }
}

/// The simulator. Build fresh per run: [`ClusterSim::run`] consumes the
/// arrival stream once and leaves queues drained.
pub struct ClusterSim {
    cfg: ClusterConfig,
    cells: Vec<Cell>,
    dispatcher: Dispatcher,
}

impl ClusterSim {
    pub fn new(cfg: ClusterConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let n_experts = cfg.model.n_experts;
        let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
        let mut cells = Vec::with_capacity(cfg.cells.len());
        for (ci, cell_cfg) in cfg.cells.iter().enumerate() {
            let n_dev = cell_cfg.n_devices();
            let chan = ChannelSimulator::new(
                &cell_cfg.channel,
                &cell_cfg.devices,
                cfg.seed.wrapping_add(ci as u64),
            );
            let realization = chan.expected_realization();
            let fleet = Fleet::new(&cell_cfg.devices, cfg.seed);
            let t_comp = fleet.t_comp_nominal(l_comp);
            let dummy_loads: Vec<PerBlockLoad> = vec![];
            let input = AllocationInput {
                channel_cfg: &cell_cfg.channel,
                realization: &realization,
                loads: &dummy_loads,
                t_comp_per_token: &t_comp,
                l_comm_bits: cfg.model.l_comm_bits(cell_cfg.channel.quant_bits),
            };
            let share = cell_cfg.channel.total_bandwidth_hz / n_dev as f64;
            let t_per_token: Vec<f64> =
                input.links().iter().map(|l| l.t_per_token(share)).collect();
            let placement = if cfg.cache_capacity == 1 {
                Placement::home(n_experts, n_dev, 1)
            } else {
                // Popularity bias shifts per block, so the static
                // optimizer assumes uniform expert load and balances on
                // device speed.
                let uniform_load = vec![1.0; n_experts];
                Placement::optimize(n_experts, &t_per_token, &uniform_load, cfg.cache_capacity)
            };
            placement.validate()?;
            cells.push(Cell {
                t_per_token,
                placement,
                policy: make_policy(
                    cfg.policy.selection,
                    &cfg.policy,
                    n_experts,
                    cfg.seed.wrapping_add(ci as u64),
                ),
                gates: WorkloadGen::new(
                    cfg.seed.wrapping_add(0xce11).wrapping_add(ci as u64),
                    cfg.model.vocab,
                ),
                busy_until: vec![0; n_dev],
                busy: vec![Utilization::default(); n_dev],
                online: vec![true; n_dev],
            });
        }
        let dispatcher = Dispatcher::new(cfg.dispatch);
        Ok(Self {
            cfg,
            cells,
            dispatcher,
        })
    }

    /// Expert placement of one cell (inspection / tests).
    pub fn placement(&self, cell: usize) -> &Placement {
        &self.cells[cell].placement
    }

    /// Per-device service seconds per token in one cell.
    pub fn t_per_token(&self, cell: usize) -> &[f64] {
        &self.cells[cell].t_per_token
    }

    /// Failure injection: mark a device (un)available for future
    /// dispatches. Work already queued on it still completes.
    pub fn set_device_online(&mut self, cell: usize, device: usize, online: bool) {
        self.cells[cell].online[device] = online;
    }

    /// Run the arrival stream to drain and report.
    pub fn run(&mut self, arrivals: &[crate::workload::Arrival]) -> ClusterOutcome {
        let n_blocks = self.cfg.model.n_blocks;
        let n_cells = self.cells.len();
        let clock = VirtualClock::new();
        let mut queue: EventQueue<Event> = EventQueue::new(clock.clone());
        let mut states: Vec<ReqState> = arrivals
            .iter()
            .enumerate()
            .map(|(i, a)| ReqState {
                tokens: a.tokens.max(1),
                cell: i % n_cells,
                arrived: nanos_from_secs(a.time_s),
                next_block: 0,
            })
            .collect();
        for (i, st) in states.iter().enumerate() {
            queue.schedule_at(st.arrived, Event::Arrive(i));
        }

        let mut arrived = 0usize;
        let mut completed = 0usize;
        let mut arrived_tokens = 0u64;
        let mut completed_tokens = 0u64;
        let mut latency_ms = SteadyState::new(self.cfg.warmup_frac);

        while let Some((now, ev)) = queue.pop() {
            let i = match ev {
                Event::Arrive(i) => {
                    arrived += 1;
                    arrived_tokens += states[i].tokens as u64;
                    i
                }
                Event::BlockDone(i) => {
                    states[i].next_block += 1;
                    if states[i].next_block >= n_blocks {
                        completed += 1;
                        completed_tokens += states[i].tokens as u64;
                        latency_ms.record(secs_from_nanos(now - states[i].arrived) * 1e3);
                        continue;
                    }
                    i
                }
            };
            let block_end = self.start_block(&states[i], now);
            queue.schedule_at(block_end, Event::BlockDone(i));
        }

        let makespan_s = secs_from_nanos(clock.nanos());
        let utilization = self
            .cells
            .iter()
            .map(|c| c.busy.iter().map(|u| u.fraction(makespan_s)).collect())
            .collect();
        ClusterOutcome {
            arrived,
            completed,
            arrived_tokens,
            completed_tokens,
            in_flight: arrived - completed,
            makespan_s,
            latency_ms,
            utilization,
        }
    }

    /// Dispatch one block of one request; returns the block's completion
    /// instant (the Eq. (11) barrier over its token groups).
    fn start_block(&mut self, st: &ReqState, now: Nanos) -> Nanos {
        let n_experts = self.cfg.model.n_experts;
        let cell = &mut self.cells[st.cell];
        let gate = GateWeights::new(cell.gates.synthetic_gate_weights_biased(
            st.tokens,
            n_experts,
            self.cfg.gate_sharpness,
            self.cfg.gate_bias,
        ));
        // Per-expert latency estimate (best online replica) and liveness.
        let mut est = vec![f64::INFINITY; n_experts];
        let mut online = vec![false; n_experts];
        for e in 0..n_experts {
            for &k in cell.placement.replicas(e) {
                if cell.online[k] {
                    online[e] = true;
                    if cell.t_per_token[k] < est[e] {
                        est[e] = cell.t_per_token[k];
                    }
                }
            }
        }
        let lat = TokenLatencies { per_token: est };
        let ctx = SelectionContext {
            latencies: &lat,
            top_k: self.cfg.model.top_k,
            online: &online,
        };
        let sel = cell.policy.select(&gate, &ctx);
        let counts = sel.tokens_per_device();

        let mut block_end = now;
        for (e, &q) in counts.iter().enumerate() {
            if q <= 0.0 {
                continue;
            }
            let Some(k) = self.dispatcher.choose(
                cell.placement.replicas(e),
                q,
                now,
                &cell.busy_until,
                &cell.t_per_token,
                &cell.online,
            ) else {
                continue; // no online replica: tokens dropped by selection
            };
            let service_s = q * cell.t_per_token[k];
            let start = cell.busy_until[k].max(now);
            let done = start.saturating_add(nanos_from_secs(service_s));
            cell.busy_until[k] = done;
            cell.busy[k].add_busy(service_s);
            cell.policy.observe(e, cell.t_per_token[k]);
            if done > block_end {
                block_end = done;
            }
        }
        block_end
    }
}

/// One point of an arrival-rate sweep.
pub struct SweepPoint {
    pub rate_rps: f64,
    pub outcome: ClusterOutcome,
}

/// Sweep output: per-rate outcomes plus rendered tables (the `repro
/// cluster` CSVs).
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
    pub summary: Table,
    pub utilization: Table,
}

/// Sweep Poisson arrival rate over a fresh simulator per point and
/// tabulate throughput, steady-state latency percentiles and per-device
/// utilization.
pub fn arrival_rate_sweep(
    cfg: &ClusterConfig,
    rates_rps: &[f64],
    requests: usize,
    bench: Benchmark,
    seed: u64,
) -> anyhow::Result<SweepResult> {
    cfg.validate()?;
    anyhow::ensure!(requests > 0, "need at least one request");
    let mut summary = Table::new(
        &format!("Cluster arrival-rate sweep — {}", bench.name()),
        &[
            "rate_rps",
            "throughput_rps",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "mean_ms",
            "util_mean",
            "util_max",
        ],
    );
    summary.precision = 3;
    let dev_names: Vec<String> = cfg
        .cells
        .iter()
        .flat_map(|c| c.devices.iter().map(|d| d.name.clone()))
        .collect();
    let dev_cols: Vec<&str> = dev_names.iter().map(String::as_str).collect();
    let mut util_t = Table::new("Cluster per-device utilization", &dev_cols);
    util_t.precision = 3;

    let mut points = Vec::with_capacity(rates_rps.len());
    for (ri, &rate) in rates_rps.iter().enumerate() {
        let mut sim = ClusterSim::new(cfg.clone())?;
        let arrivals = ArrivalProcess::Poisson { rate_rps: rate }.generate(
            requests,
            bench,
            seed.wrapping_add(ri as u64 * 7919),
        );
        let out = sim.run(&arrivals);
        let s = out.steady_latency();
        let util = out.flat_utilization();
        let util_mean = util.iter().sum::<f64>() / util.len().max(1) as f64;
        let util_max = util.iter().cloned().fold(0.0f64, f64::max);
        summary.row(
            &format!("rate={rate}"),
            vec![
                rate,
                out.throughput_rps(),
                s.percentile(50.0),
                s.percentile(95.0),
                s.percentile(99.0),
                s.mean(),
                util_mean,
                util_max,
            ],
        );
        util_t.row(&format!("rate={rate}"), util);
        points.push(SweepPoint {
            rate_rps: rate,
            outcome: out,
        });
    }
    Ok(SweepResult {
        points,
        summary,
        utilization: util_t,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, DispatchKind};

    fn small_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::single_cell();
        cfg.model.n_blocks = 8; // keep tests fast
        cfg
    }

    fn run_with(cfg: ClusterConfig, rate: f64, n: usize, seed: u64) -> ClusterOutcome {
        let mut sim = ClusterSim::new(cfg).unwrap();
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: rate }.generate(n, Benchmark::Piqa, seed);
        sim.run(&arrivals)
    }

    #[test]
    fn drains_and_conserves_requests_and_tokens() {
        let out = run_with(small_cfg(), 1.0, 40, 0);
        assert_eq!(out.arrived, 40);
        assert_eq!(out.completed, 40);
        assert_eq!(out.in_flight, 0);
        assert_eq!(out.arrived_tokens, out.completed_tokens);
        assert!(out.makespan_s > 0.0);
        assert!(out.throughput_rps() > 0.0);
        assert_eq!(out.latency_ms.total_count(), 40);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(small_cfg(), 2.0, 30, 3);
        let b = run_with(small_cfg(), 2.0, 30, 3);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency_ms.steady_values(), b.latency_ms.steady_values());
        assert_eq!(a.utilization, b.utilization);
    }

    #[test]
    fn latency_grows_with_load() {
        // At 0.2 rps requests never overlap; at 20 rps the inter-arrival
        // gap is far below the per-request service time, so queues must
        // form and p95 latency must rise clearly.
        let lo = run_with(small_cfg(), 0.2, 60, 1);
        let hi = run_with(small_cfg(), 20.0, 60, 1);
        assert!(
            hi.steady_latency().percentile(95.0) > lo.steady_latency().percentile(95.0),
            "p95 {} <= {}",
            hi.steady_latency().percentile(95.0),
            lo.steady_latency().percentile(95.0)
        );
    }

    #[test]
    fn utilization_bounded_and_nonzero() {
        let out = run_with(small_cfg(), 2.0, 40, 2);
        let util = out.flat_utilization();
        assert!(!util.is_empty());
        for &u in &util {
            assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
        }
        assert!(util.iter().any(|&u| u > 0.0));
    }

    #[test]
    fn multi_cell_spreads_requests() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 4;
        let mut sim = ClusterSim::new(cfg).unwrap();
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 2.0 }.generate(30, Benchmark::Piqa, 0);
        let out = sim.run(&arrivals);
        assert_eq!(out.completed, 30);
        assert_eq!(out.utilization.len(), 2);
        // both cells did work
        for cell_util in &out.utilization {
            assert!(cell_util.iter().any(|&u| u > 0.0), "idle cell");
        }
    }

    #[test]
    fn offline_device_work_reroutes_to_replicas() {
        let mut cfg = small_cfg();
        cfg.cache_capacity = 2;
        cfg.dispatch = DispatchKind::LoadAware;
        let mut sim = ClusterSim::new(cfg).unwrap();
        // Find a device hosting a replicated expert and kill it.
        sim.set_device_online(0, 7, false);
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 1.0 }.generate(20, Benchmark::Piqa, 4);
        let out = sim.run(&arrivals);
        assert_eq!(out.completed, 20);
        assert_eq!(out.utilization[0][7], 0.0, "offline device served work");
    }

    #[test]
    fn sweep_emits_consistent_tables() {
        let cfg = small_cfg();
        let r = arrival_rate_sweep(&cfg, &[0.5, 2.0], 24, Benchmark::Piqa, 0).unwrap();
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.summary.rows.len(), 2);
        assert_eq!(r.utilization.rows.len(), 2);
        assert_eq!(r.utilization.columns.len(), 8);
        for p in &r.points {
            assert_eq!(p.outcome.completed, 24);
        }
    }
}
