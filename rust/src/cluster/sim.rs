//! The multi-cell discrete-event serving simulator.
//!
//! Requests arrive open-loop (Poisson or trace replay), are assigned to a
//! cell round-robin (or by live load under
//! [`crate::config::HandoverPolicy::RehomeOnArrival`]), and walk the
//! model's `I` MoE blocks one by one. Per
//! block the cell's gate draws weights, the configured selection policy
//! picks experts (Algorithm 1 / top-k / …), and the dispatcher routes
//! each selected expert's token group to one of its replicas. Token
//! groups join that device's FIFO queue; the block completes when its
//! last group finishes (the Eq. (11) attention barrier), at which point
//! the next block starts. Queueing delay, utilization and tail latency
//! all *emerge* from contention between in-flight requests — nothing is
//! assumed.
//!
//! Each cell's bandwidth allocation, service-time vector and expert
//! placement are owned by its [`ControlPlane`]
//! ([`crate::config::ControlKind`]): the static planes freeze them at
//! construction, while the adaptive plane re-solves P3 from observed
//! per-device demand on an epoch cadence (`ControlTick` events) and
//! re-balances expert replicas from observed per-expert token counts.
//! Service times are read through the plane at every dispatch — never
//! cached — so a mid-run re-allocation immediately redirects the
//! load-aware dispatcher.
//!
//! Admission control: with `queue_limit_s > 0`, a dispatch finding *every*
//! replica of an expert beyond the backlog bound triggers the configured
//! [`crate::config::DropPolicy`] — reject the whole request, or shed just
//! that expert's token group (never all of a block's groups) — so
//! overload degrades goodput and shed rate measurably instead of growing
//! queues without bound.
//!
//! Inter-cell handover: the [`crate::cluster::handover`] layer sits
//! above the per-cell dispatcher. Under
//! [`crate::config::HandoverPolicy::BorrowExpert`], a dispatch that
//! finds every *local* replica of an expert over the bound (or
//! unserviceable) routes that token group to the least-loaded neighbor
//! cell's replica, paying a per-token backhaul latency each way; the
//! group is tracked through the same Eq. (11) barrier, and a
//! `DropRequest` rejection rolls staged borrows back so no partial work
//! survives in any cell. With `HandoverPolicy::None` behaviour is
//! unchanged from the pre-handover simulator, and the output is
//! byte-identical to a run where handover never triggers.
//!
//! ## Hot-path discipline
//!
//! The event loop is allocation-free per event: every per-block vector
//! (expert latency estimates, liveness, token counts, tentative queue
//! state, admitted placements, replica candidates) and the per-tick
//! demand vector live in per-cell scratch reused across events, and the
//! control plane's epoch re-solve runs through its own
//! [`crate::optim::SolverWorkspace`]. Construction borrows the
//! [`ClusterConfig`] — sweeps never clone the config per point — and
//! [`ClusterSim::reset`] restores the just-built state so one simulator
//! can serve many runs.
//!
//! ## Telemetry
//!
//! [`ClusterSim::run_probed`] is the one true event loop; `run` is the
//! same loop with [`crate::telemetry::NullProbe`], whose empty inlined
//! callbacks monomorphize away — so "telemetry off" *is* the
//! pre-telemetry hot path, and the byte-identity of its outputs is
//! structural rather than maintained by hand. A real
//! [`crate::telemetry::Probe`] receives arrivals, dispatch decisions,
//! placements (queue enter / service start / finish), sheds, borrow
//! staging/commit/rollback, drops, device toggles and control re-solves
//! (with their solver cost), plus per-cell state snapshots on a
//! sim-time cadence. Probes observe and never perturb: nothing a probe
//! returns feeds back into the simulation.

use super::dispatch::Dispatcher;
use super::energy::CellEnergy;
use super::event::{nanos_from_secs, secs_from_nanos, EventQueue, Nanos};
use super::faults::{
    self, apply_action, resolve_lost_group, CellFaults, FaultAction, FaultEvent, InflightGroup,
    LossResolution,
};
use super::handover::{HandoverCell, HandoverCoordinator};
use super::placement::Placement;
use crate::config::{ClusterConfig, ControlKind, DropPolicy, EnergyConfig, PolicyConfig};
use crate::control::{
    make_plane, CellLoad, ControlOptions, ControlPlane, LinkState, SolverIntrospection,
};
use crate::devices::Fleet;
use crate::latency::TokenLatencies;
use crate::metrics::{ControlStats, SteadyState, Summary, Utilization};
use crate::moe::selection::{make_policy, SelectScratch, SelectionContext, SelectionPolicy};
use crate::moe::{GateWeights, Selection};
use crate::telemetry::{CellSample, NullProbe, Probe, TelemetryEvent};
use crate::util::clock::VirtualClock;
use crate::wireless::ChannelSimulator;
use crate::workload::WorkloadGen;

/// Per-device hot state of one cell, struct-of-arrays: every array is
/// indexed by device, so the event loop's innermost scans (queue
/// instants for dispatch, availability masks, token accounting) each
/// walk one dense array instead of striding across per-device structs.
pub(super) struct DeviceState {
    /// Instant each device's FIFO queue drains.
    pub(super) busy_until: Vec<Nanos>,
    pub(super) busy: Vec<Utilization>,
    pub(super) online: Vec<bool>,
    /// Tokens dispatched per device since the last control epoch.
    pub(super) served_tokens: Vec<f64>,
    /// Tentative queue instants while a block is placed (pass 1).
    pub(super) scratch_busy: Vec<Nanos>,
    /// Live service-time multiplier per device from the fault plan
    /// (straggler episodes × link dips). Always 1.0 without a plan, and
    /// `q · t_k · 1.0` is bit-exact `q · t_k` — the zero-fault dispatch
    /// arithmetic is unchanged.
    pub(super) service_mult: Vec<f64>,
}

impl DeviceState {
    fn new(n_dev: usize) -> Self {
        Self {
            busy_until: vec![0; n_dev],
            busy: vec![Utilization::default(); n_dev],
            online: vec![true; n_dev],
            served_tokens: vec![0.0; n_dev],
            scratch_busy: vec![0; n_dev],
            service_mult: vec![1.0; n_dev],
        }
    }

    pub(super) fn len(&self) -> usize {
        self.busy_until.len()
    }

    /// Total committed busy seconds across devices.
    pub(super) fn busy_seconds(&self) -> f64 {
        self.busy.iter().map(|u| u.busy_seconds()).sum()
    }

    pub(super) fn online_count(&self) -> usize {
        self.online.iter().filter(|&&on| on).count()
    }
}

/// One cell's runtime state: control plane, policy and FIFO queues.
pub(super) struct Cell {
    /// Owns (bandwidth, t_per_token, placement); service times are read
    /// through it on every dispatch so re-allocations take effect
    /// immediately.
    pub(super) plane: Box<dyn ControlPlane>,
    pub(super) policy: Box<dyn SelectionPolicy>,
    pub(super) gates: WorkloadGen,
    /// Per-device hot state (struct-of-arrays).
    pub(super) dev: DeviceState,
    /// Tokens dispatched per expert since the last control epoch.
    pub(super) expert_tokens: Vec<f64>,
    /// Reusable per-block staging state (no per-block allocation):
    /// per-expert latency estimate fed to the selection policy, expert
    /// liveness, the selection's per-expert token counts, the admitted
    /// `(expert, device, tokens, service seconds)` placements, and the
    /// under-queue-bound replica candidates.
    pub(super) est: TokenLatencies,
    pub(super) expert_online: Vec<bool>,
    pub(super) counts: Vec<f64>,
    pub(super) placed: Vec<PlacedGroup>,
    pub(super) cand: Vec<usize>,
    /// Reusable per-tick demand vector (backlog → tokens).
    pub(super) demand: Vec<f64>,
    /// Reusable gate-weight matrix for the block being started; refilled
    /// in place each block by the workload generator.
    pub(super) gate: GateWeights,
    /// Reusable selection scratch written by `select_into` each block.
    pub(super) sel: Selection,
    /// Row-buffer pools backing `gate`/`sel` reshapes: shrinking a block
    /// parks excess rows here instead of freeing them, so the per-block
    /// path stops allocating once the high-water token count is seen.
    pub(super) gate_spare: Vec<Vec<f64>>,
    pub(super) gate_offsets: Vec<f64>,
    pub(super) sel_scratch: SelectScratch,
    /// Total queued seconds at the last control solve — the reference
    /// the backlog-delta trigger measures drift against.
    pub(super) last_solve_backlog_s: f64,
    /// Committed-but-unfinished token groups, tracked only when the run
    /// has a non-empty fault plan: a device crash sweeps this ledger for
    /// the groups it loses (re-dispatch / drop / shed).
    pub(super) inflight: Vec<InflightGroup>,
    /// Per-device energy state (battery, joule debits, depletion FIFO);
    /// `enabled` is false — and every energy call branch-gated away —
    /// when the config is empty.
    pub(super) energy: CellEnergy,
}

/// One admitted local placement of a block, staged in pass 1 and
/// committed (accounting + telemetry) in pass 2. Carrying the service
/// window means the commit pass — and only the commit pass — can emit
/// `GroupPlaced`, so rolled-back placements never reach a probe.
#[derive(Debug, Clone, Copy)]
struct PlacedGroup {
    expert: usize,
    device: usize,
    tokens: f64,
    service_s: f64,
    /// Service start (queue drained to this group).
    start: Nanos,
    /// Service finish (device-local, before any barrier).
    done: Nanos,
    /// Speculative duplicate placed by hedged dispatch: contributes busy
    /// time and a `GroupPlaced` event but not demand signals (its twin
    /// already counted).
    hedge: bool,
    /// The twin's finish instant when this group is half of a hedged
    /// pair — carried into the in-flight ledger so a crash of either
    /// twin is covered by the survivor.
    cover: Option<Nanos>,
}

/// Total queued seconds across a cell's devices at `now` — the signal
/// the backlog-delta trigger compares against the last solve (offline
/// devices keep their committed backlog; it still has to drain).
pub(super) fn cell_backlog_s(cell: &Cell, now: Nanos) -> f64 {
    cell.dev
        .busy_until
        .iter()
        .map(|&b| secs_from_nanos(b.saturating_sub(now)))
        .sum()
}

/// One cell's [`CellSample`] snapshot at virtual time `now` — shared by
/// the serial sampler ([`ClusterSim::run_probed`]) and the sharded
/// engine's per-shard recorders so both observe identical rows.
pub(super) fn sample_cell(cell: &Cell, now: Nanos) -> CellSample {
    let placement = cell.plane.placement();
    let n_experts = cell.expert_tokens.len();
    let mut live_replicas = 0usize;
    for e in 0..n_experts {
        live_replicas += placement
            .replicas(e)
            .iter()
            .filter(|&&k| cell.dev.online[k])
            .count();
    }
    CellSample {
        backlog_s: cell_backlog_s(cell, now),
        busy_s: cell.dev.busy_seconds(),
        devices: cell.dev.len(),
        online_devices: cell.dev.online_count(),
        live_replicas,
        degraded_devices: cell
            .dev
            .service_mult
            .iter()
            .filter(|&&m| m != 1.0)
            .count(),
        battery_min: cell.energy.battery_min_frac(),
    }
}

/// What the cluster-level handover layer may read and (for staged
/// borrows) write on a cell. Accounting mirrors a local placement
/// commit, so the serving cell's control plane sees borrowed demand.
impl HandoverCell for Cell {
    fn replicas(&self, expert: usize) -> &[usize] {
        self.plane.placement().replicas(expert)
    }
    fn busy_until(&self) -> &[Nanos] {
        &self.dev.busy_until
    }
    fn set_busy_until(&mut self, device: usize, at: Nanos) {
        self.dev.busy_until[device] = at;
    }
    fn t_per_token(&self) -> &[f64] {
        self.plane.t_per_token()
    }
    fn online(&self) -> &[bool] {
        &self.dev.online
    }
    fn commit_remote(&mut self, device: usize, expert: usize, tokens: f64, service_s: f64) {
        self.dev.busy[device].add_busy(service_s);
        self.dev.served_tokens[device] += tokens;
        self.expert_tokens[expert] += tokens;
    }
}

pub(super) enum Event {
    Arrive(usize),
    BlockDone(usize),
    /// Epoch boundary for one cell's adaptive control plane.
    ControlTick(usize),
    /// Next compiled fault-plan event on this cell's lane.
    Fault(usize),
    /// A battery recharge episode of `(cell, device)` completes: the
    /// battery refills and the device recovers (scheduled at depletion
    /// when `recharge_s > 0`).
    Recharge(usize, usize),
}

pub(super) struct ReqState {
    pub(super) tokens: usize,
    pub(super) cell: usize,
    pub(super) arrived: Nanos,
    pub(super) next_block: usize,
    /// The request experienced a handover action (re-home or borrow) —
    /// each request counts at most once toward the handover rate.
    pub(super) handed_over: bool,
    /// Latest completion instant of the current block after fault
    /// recovery moved work (re-dispatch, hedge cover). A `BlockDone`
    /// popping before the barrier reschedules itself to it.
    pub(super) barrier: Nanos,
    /// The request was dropped by crash recovery; its pending
    /// `BlockDone` is a tombstone to skip.
    pub(super) dropped: bool,
    /// Re-dispatches consumed from the per-request retry budget.
    pub(super) retries: u32,
}

/// Outcome of dispatching one block.
pub(super) struct BlockResult {
    /// Completion instant, or `None` when admission control rejected the
    /// request.
    pub(super) end: Option<Nanos>,
    /// Token groups shed by [`DropPolicy::ShedTokens`] in this block.
    pub(super) shed_tokens: f64,
    /// Expert groups served by a neighbor cell in this block.
    pub(super) borrowed_groups: usize,
    /// Tokens those borrowed groups carried.
    pub(super) borrowed_tokens: f64,
    /// Tokens of hedged duplicates placed in this block (the loser of
    /// each pair is waste by construction, billed at dispatch).
    pub(super) wasted_tokens: f64,
    /// Hedged duplicates placed in this block.
    pub(super) hedges: usize,
}

/// Result of one simulation run (all arrivals drained).
#[derive(Debug)]
pub struct ClusterOutcome {
    pub arrived: usize,
    pub completed: usize,
    /// Requests rejected by admission control ([`DropPolicy::DropRequest`]).
    pub dropped: usize,
    pub arrived_tokens: u64,
    pub completed_tokens: u64,
    /// Tokens of rejected requests.
    pub dropped_tokens: u64,
    /// Expert token groups shed by [`DropPolicy::ShedTokens`] (requests
    /// continue degraded; not counted in `dropped`).
    pub shed_tokens: f64,
    /// Requests whose service crossed a cell boundary at least once
    /// (load-aware re-home at arrival, or a borrowed expert group).
    pub handovers: usize,
    /// Expert token groups served by a neighbor cell under
    /// [`crate::config::HandoverPolicy::BorrowExpert`].
    pub borrowed_groups: usize,
    /// Tokens those borrowed groups carried.
    pub borrowed_tokens: f64,
    /// Requests still in flight when the event queue drained (0 by
    /// construction for finite arrival streams — the conservation law).
    pub in_flight: usize,
    /// Discrete events processed (arrivals + block completions + control
    /// ticks) — the numerator of the DES-throughput benchmark.
    pub events: usize,
    /// Virtual time of the last event.
    pub makespan_s: f64,
    /// End-to-end request latency (ms), recorded in completion order.
    pub latency_ms: SteadyState,
    /// `utilization[cell][device]` — busy fraction of the makespan.
    pub utilization: Vec<Vec<f64>>,
    /// Per-cell control-plane activity (re-solves, placement updates,
    /// allocation churn).
    pub control: Vec<ControlStats>,
    /// P3 solver cost aggregated over every plane solve of the run
    /// (pre-solves, epoch/failover re-solves): the
    /// [`crate::optim::SolveStats`] the re-solve path used to drop.
    pub solver: SolverIntrospection,
    /// Requests that missed the configured deadline (completed late, or
    /// dropped/rejected while a deadline was set). 0 when `deadline_s`
    /// is 0 (SLO accounting off).
    pub slo_missed: usize,
    /// Token groups re-dispatched to a surviving replica after a crash.
    pub retries: usize,
    /// Hedged duplicates placed (speculative second dispatches).
    pub hedges: usize,
    /// Tokens of discarded work: service lost to crashes after it had
    /// started, plus every hedged duplicate (the losing twin of each
    /// pair is waste by construction).
    pub wasted_tokens: f64,
    /// Device-seconds spent offline, summed over devices — the numerator
    /// of `1 - availability`.
    pub offline_device_s: f64,
    /// Total joules billed across the fleet (compute + radio + idle
    /// draw). 0 when the energy model is off.
    pub energy_j: f64,
    /// Per-cell joule totals, cell index order (empty when the energy
    /// model is off).
    pub energy_cells: Vec<f64>,
    /// Per-cell count of devices whose battery hit zero at least once
    /// (empty when the energy model is off).
    pub depleted_cells: Vec<usize>,
    /// Instant of the first battery depletion (0 = none).
    pub first_depletion: Nanos,
    /// Instant of the last battery depletion (0 = none).
    pub last_depletion: Nanos,
}

impl ClusterOutcome {
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }

    /// Useful work delivered: tokens of completed requests per second.
    /// Excludes dropped requests; groups shed by
    /// [`DropPolicy::ShedTokens`] are *not* subtracted here (the request
    /// still completes, degraded) — shed volume is reported separately
    /// via [`Self::shed_tokens`].
    pub fn goodput_tps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed_tokens as f64 / self.makespan_s
        }
    }

    /// Expert-group tokens shed per second by
    /// [`DropPolicy::ShedTokens`] — the degraded-quality counterpart of
    /// [`Self::drop_rate`], so shedding never hides overload in reports.
    pub fn shed_tps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.shed_tokens / self.makespan_s
        }
    }

    /// Fraction of arrivals rejected by admission control.
    pub fn drop_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.dropped as f64 / self.arrived as f64
        }
    }

    /// Fraction of arrivals whose service crossed a cell boundary — a
    /// load-aware re-home at arrival or at least one borrowed expert
    /// group. 0 by construction under
    /// [`crate::config::HandoverPolicy::None`].
    pub fn handover_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.handovers as f64 / self.arrived as f64
        }
    }

    /// Control-plane counters aggregated over cells.
    pub fn control_total(&self) -> ControlStats {
        let mut total = ControlStats::default();
        for c in &self.control {
            total.absorb(c);
        }
        total
    }

    /// Mean P3 solver iterations per solve over the whole run (0 when
    /// nothing was solved — static-uniform planes).
    pub fn solver_iters_mean(&self) -> f64 {
        self.solver.iters_mean()
    }

    /// Largest single-solve iteration count of the run.
    pub fn solver_iters_max(&self) -> f64 {
        self.solver.iterations_max as f64
    }

    /// Steady-state latency summary (warm-up discarded).
    pub fn steady_latency(&self) -> Summary {
        self.latency_ms.steady()
    }

    pub fn p50_ms(&self) -> f64 {
        self.steady_latency().percentile(50.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.steady_latency().percentile(95.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.steady_latency().percentile(99.0)
    }

    /// All per-device utilizations, cells concatenated.
    pub fn flat_utilization(&self) -> Vec<f64> {
        self.utilization.iter().flatten().copied().collect()
    }

    /// Fraction of arrivals that missed the deadline (0 when SLO
    /// accounting is off or nothing arrived).
    pub fn slo_miss_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.slo_missed as f64 / self.arrived as f64
        }
    }

    /// Hedged duplicates per arrival — the overhead knob of hedged
    /// dispatch (each hedge burns one duplicate group of tokens).
    pub fn hedge_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.hedges as f64 / self.arrived as f64
        }
    }

    /// Joules billed per completed token (0 when the energy model is
    /// off or nothing completed).
    pub fn joules_per_token(&self) -> f64 {
        if self.completed_tokens == 0 {
            0.0
        } else {
            self.energy_j / self.completed_tokens as f64
        }
    }

    /// Devices whose battery hit zero at least once, fleet-wide.
    pub fn depleted_devices(&self) -> usize {
        self.depleted_cells.iter().sum()
    }

    /// Fleet lifetime: seconds until the first battery depletion, or
    /// the full makespan when no battery died — the survival horizon
    /// energy-aware dispatch tries to extend.
    pub fn fleet_lifetime_s(&self) -> f64 {
        if self.first_depletion == 0 {
            self.makespan_s
        } else {
            secs_from_nanos(self.first_depletion)
        }
    }

    /// Instant of the first battery depletion in seconds (0 = none).
    pub fn first_depletion_s(&self) -> f64 {
        secs_from_nanos(self.first_depletion)
    }

    /// Instant of the last battery depletion in seconds (0 = none).
    pub fn last_depletion_s(&self) -> f64 {
        secs_from_nanos(self.last_depletion)
    }

    /// Mean fraction of device-time the fleet was online over the run:
    /// `1 - offline_device_s / (n_devices · makespan)`. 1.0 for an empty
    /// fault plan or a zero-length run.
    pub fn availability(&self) -> f64 {
        let n_dev: usize = self.utilization.iter().map(|c| c.len()).sum();
        if self.makespan_s <= 0.0 || n_dev == 0 {
            1.0
        } else {
            (1.0 - self.offline_device_s / (n_dev as f64 * self.makespan_s)).clamp(0.0, 1.0)
        }
    }
}

/// The scalar knobs the event loop reads per event, copied out of the
/// borrowed [`ClusterConfig`] at construction so sweeps never clone the
/// full config (cell/device lists stay with the caller).
#[derive(Debug, Clone, Copy)]
pub(super) struct SimParams {
    pub(super) n_blocks: usize,
    pub(super) n_experts: usize,
    pub(super) top_k: usize,
    pub(super) vocab: usize,
    pub(super) queue_limit_s: f64,
    pub(super) drop_policy: DropPolicy,
    /// Backlog drift (queued seconds) since the last solve that triggers
    /// an immediate adaptive re-solve between epoch ticks (0 = off).
    pub(super) backlog_delta_s: f64,
    pub(super) warmup_frac: f64,
    pub(super) gate_sharpness: f64,
    pub(super) gate_bias: f64,
    pub(super) seed: u64,
    /// Per-request completion deadline in seconds (0 = SLO accounting
    /// and hedged dispatch off).
    pub(super) deadline_s: f64,
    /// Hedge a block whose predicted finish would bust the deadline.
    pub(super) hedge: bool,
    /// Crash re-dispatch budget per request before the drop policy.
    pub(super) max_retries: u32,
    /// Crash machinery is armed: the compiled fault plan is non-empty
    /// *or* battery depletion can emit crashes — gates the in-flight
    /// ledger bookkeeping that only crash recovery reads.
    pub(super) faults: bool,
    /// The energy config is non-empty — selects the `ENERGY = true`
    /// monomorphization (accounting, depletion drains, teardown totals).
    pub(super) energy: bool,
}

/// The simulator. Construction borrows the config; [`ClusterSim::run`]
/// consumes one arrival stream and leaves queues drained —
/// [`ClusterSim::reset`] restores the just-built state for the next run.
pub struct ClusterSim {
    pub(super) params: SimParams,
    policy_cfg: PolicyConfig,
    control: ControlKind,
    copts: ControlOptions,
    cache_capacity: usize,
    pub(super) dispatcher: Dispatcher,
    /// Cluster-level dispatch layer: arrival re-homing and cross-cell
    /// expert borrowing (reused scratch, no hot-path allocation).
    pub(super) handover: HandoverCoordinator,
    /// Frozen per-cell link contexts — the rebuild template for
    /// [`Self::reset`].
    states: Vec<LinkState>,
    pub(super) cells: Vec<Cell>,
    /// Explicit conservative sync-window override for the sharded engine
    /// (seconds). `None` lets [`crate::cluster::shard`] pick the natural
    /// bound for the configured handover policy.
    pub(super) sync_window_s: Option<f64>,
    /// Compiled fault plan, one sorted event lane per cell (empty lanes
    /// for an empty plan — the run dispatches to the zero-fault path).
    pub(super) fault_lanes: Vec<Vec<FaultEvent>>,
    /// Per-cell fault runtime (lane cursor, live multipliers, offline
    /// accounting), rebuilt with the cells.
    pub(super) cell_faults: Vec<CellFaults>,
    /// Energy model compiled per cell at (re)construction.
    energy_cfg: EnergyConfig,
    /// Effective dispatch/control energy weight: forced to 0 when the
    /// config is empty so `cell.energy.score()` is always `OFF`-shaped.
    energy_weight: f64,
}

impl ClusterSim {
    pub fn new(cfg: &ClusterConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let fault_lanes = faults::compile(cfg);
        let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
        let mut states = Vec::with_capacity(cfg.cells.len());
        for (ci, cell_cfg) in cfg.cells.iter().enumerate() {
            let chan = ChannelSimulator::new(
                &cell_cfg.channel,
                &cell_cfg.devices,
                cfg.seed.wrapping_add(ci as u64),
            );
            let realization = chan.expected_realization();
            let fleet = Fleet::new(&cell_cfg.devices, cfg.seed);
            let t_comp = fleet.t_comp_nominal(l_comp);
            states.push(LinkState::new(
                &cell_cfg.channel,
                &realization,
                &t_comp,
                cfg.model.l_comm_bits(cell_cfg.channel.quant_bits),
            ));
        }
        let mut sim = Self {
            params: SimParams {
                n_blocks: cfg.model.n_blocks,
                n_experts: cfg.model.n_experts,
                top_k: cfg.model.top_k,
                vocab: cfg.model.vocab,
                queue_limit_s: cfg.queue_limit_s,
                drop_policy: cfg.drop_policy,
                backlog_delta_s: cfg.control_backlog_delta_s,
                warmup_frac: cfg.warmup_frac,
                gate_sharpness: cfg.gate_sharpness,
                gate_bias: cfg.gate_bias,
                seed: cfg.seed,
                deadline_s: cfg.deadline_s,
                hedge: cfg.hedge,
                max_retries: cfg.max_retries,
                faults: fault_lanes.iter().any(|l| !l.is_empty()) || cfg.energy.churn_possible(),
                energy: !cfg.energy.is_empty(),
            },
            policy_cfg: cfg.policy.clone(),
            control: cfg.control,
            copts: ControlOptions {
                epoch_s: cfg.control_epoch_s,
                hysteresis: cfg.control_hysteresis,
                solver: Default::default(),
            },
            cache_capacity: cfg.cache_capacity,
            dispatcher: Dispatcher::new(cfg.dispatch),
            handover: HandoverCoordinator::new(cfg.handover, cfg.backhaul_s_per_token)
                .with_backhaul_matrix(cfg.backhaul_matrix.clone()),
            states,
            cells: Vec::new(),
            sync_window_s: None,
            fault_lanes,
            cell_faults: Vec::new(),
            energy_cfg: cfg.energy.clone(),
            energy_weight: if cfg.energy.is_empty() {
                0.0
            } else {
                cfg.energy_weight
            },
        };
        sim.build_cells()?;
        Ok(sim)
    }

    /// (Re)construct every cell from the stored link contexts and seeds.
    fn build_cells(&mut self) -> anyhow::Result<()> {
        let n_experts = self.params.n_experts;
        self.handover.reset();
        self.cells.clear();
        for (ci, state) in self.states.iter().enumerate() {
            let n_dev = state.n_devices();
            let plane = make_plane(
                self.control,
                state.clone(),
                n_experts,
                self.cache_capacity,
                self.copts.clone(),
            );
            plane.placement().validate()?;
            // The uniform reference share is read off the plane's initial
            // split; the effective weight is 0 whenever the config is
            // empty, so `score()` always degrades to the integer path.
            let energy =
                CellEnergy::new(&self.energy_cfg, self.energy_weight, n_dev, plane.bandwidth());
            self.cells.push(Cell {
                plane,
                policy: make_policy(
                    self.policy_cfg.selection,
                    &self.policy_cfg,
                    n_experts,
                    self.params.seed.wrapping_add(ci as u64),
                ),
                gates: WorkloadGen::new(
                    self.params.seed.wrapping_add(0xce11).wrapping_add(ci as u64),
                    self.params.vocab,
                ),
                dev: DeviceState::new(n_dev),
                expert_tokens: vec![0.0; n_experts],
                est: TokenLatencies {
                    per_token: Vec::with_capacity(n_experts),
                },
                expert_online: Vec::with_capacity(n_experts),
                counts: Vec::with_capacity(n_experts),
                placed: Vec::with_capacity(n_experts),
                cand: Vec::with_capacity(n_dev),
                demand: Vec::with_capacity(n_dev),
                gate: GateWeights { weights: Vec::new() },
                sel: Selection::empty(),
                gate_spare: Vec::new(),
                gate_offsets: Vec::new(),
                sel_scratch: SelectScratch::default(),
                last_solve_backlog_s: 0.0,
                inflight: Vec::new(),
                energy,
            });
        }
        self.cell_faults = self
            .cells
            .iter()
            .map(|c| CellFaults::new(c.dev.len()))
            .collect();
        Ok(())
    }

    /// Restore the just-constructed state (fresh planes, policies, gate
    /// streams, empty queues) without touching the config. A reset
    /// simulator behaves identically to a newly built one on the same
    /// config, so sweeps and benches can reuse one instance across runs.
    pub fn reset(&mut self) -> anyhow::Result<()> {
        self.build_cells()
    }

    /// Override the sharded engine's conservative sync window (seconds;
    /// `None` restores the policy-derived default). Any positive window
    /// yields byte-identical output — smaller windows just synchronize
    /// more often — so this knob exists for tests that exercise the
    /// finite-window machinery and for experiments on sync overhead.
    pub fn set_sync_window_s(&mut self, window_s: Option<f64>) {
        if let Some(w) = window_s {
            assert!(w.is_finite() && w > 0.0, "sync window must be positive");
        }
        self.sync_window_s = window_s;
    }

    /// Expert placement of one cell (inspection / tests).
    pub fn placement(&self, cell: usize) -> &Placement {
        self.cells[cell].plane.placement()
    }

    /// Per-device service seconds per token in one cell, under the
    /// cell's *current* bandwidth allocation.
    pub fn t_per_token(&self, cell: usize) -> &[f64] {
        self.cells[cell].plane.t_per_token()
    }

    /// Current bandwidth split of one cell (Hz).
    pub fn bandwidth(&self, cell: usize) -> &[f64] {
        self.cells[cell].plane.bandwidth()
    }

    /// Control-plane counters of one cell.
    pub fn control_stats(&self, cell: usize) -> ControlStats {
        self.cells[cell].plane.stats()
    }

    /// Live backlog summary of one cell at virtual time `now_s` — the
    /// same signal the handover layer reads when re-homing arrivals or
    /// ranking neighbor cells for a borrow (inspection / tests).
    pub fn cell_load(&self, cell: usize, now_s: f64) -> CellLoad {
        let c = &self.cells[cell];
        CellLoad::observe(nanos_from_secs(now_s), &c.dev.busy_until, &c.dev.online)
    }

    /// Force a control epoch now with an explicit demand signal
    /// (tests / tooling; the DES feeds observed backlog automatically).
    pub fn control_epoch(
        &mut self,
        cell: usize,
        demand_tokens: &[f64],
        expert_tokens: &[f64],
    ) -> bool {
        self.cells[cell].plane.on_epoch(demand_tokens, expert_tokens)
    }

    /// Failure injection: mark a device (un)available for future
    /// dispatches. Work already queued on it still completes. Adaptive
    /// planes re-solve the allocation for the survivors immediately.
    pub fn set_device_online(&mut self, cell: usize, device: usize, online: bool) {
        self.set_device_online_probed(cell, device, online, &mut NullProbe);
    }

    /// [`Self::set_device_online`] with a telemetry probe: an effective
    /// toggle emits [`TelemetryEvent::DeviceOnline`] (idempotent no-ops
    /// emit nothing, mirroring the re-solve suppression).
    pub fn set_device_online_probed<P: Probe>(
        &mut self,
        cell: usize,
        device: usize,
        online: bool,
        probe: &mut P,
    ) {
        let c = &mut self.cells[cell];
        if c.dev.online[device] == online {
            return; // idempotent: a no-op change must not trigger a re-solve
        }
        c.dev.online[device] = online;
        probe.on_event(&TelemetryEvent::DeviceOnline {
            cell,
            device,
            online,
        });
        // Split borrow: the plane reads the mask it does not own.
        c.plane.on_topology_change(&c.dev.online);
    }

    /// Per-cell state snapshot for [`Probe::on_sample`], written into
    /// the caller's reused buffer.
    fn snapshot_cells(&self, now: Nanos, out: &mut Vec<CellSample>) {
        out.clear();
        for c in &self.cells {
            out.push(sample_cell(c, now));
        }
    }

    /// Run the arrival stream to drain and report.
    ///
    /// Delegates to [`Self::run_probed`] with [`NullProbe`]; the no-op
    /// callbacks inline to nothing, so this *is* the pre-telemetry hot
    /// path.
    pub fn run(&mut self, arrivals: &[crate::workload::Arrival]) -> ClusterOutcome {
        self.run_probed(arrivals, &mut NullProbe)
    }

    /// Run the arrival stream with a telemetry [`Probe`] observing the
    /// event stream (and, if the probe requests a cadence, per-cell
    /// snapshots). Probes observe and never perturb: the returned
    /// outcome is bit-equal to [`Self::run`] on the same stream.
    pub fn run_probed<P: Probe>(
        &mut self,
        arrivals: &[crate::workload::Arrival],
        probe: &mut P,
    ) -> ClusterOutcome {
        // An empty fault plan / energy config monomorphizes to the exact
        // pre-fault / pre-energy hot path: `FAULTS = false` compiles the
        // ledger/barrier bookkeeping away and `ENERGY = false` the joule
        // accounting, the same discipline as `NullProbe` for telemetry.
        // (`faults` is also armed by a battery that can deplete — a
        // depletion is a crash and needs the same recovery machinery.)
        match (self.params.faults, self.params.energy) {
            (false, false) => self.run_inner::<P, false, false>(arrivals, probe),
            (true, false) => self.run_inner::<P, true, false>(arrivals, probe),
            (false, true) => self.run_inner::<P, false, true>(arrivals, probe),
            (true, true) => self.run_inner::<P, true, true>(arrivals, probe),
        }
    }

    fn run_inner<P: Probe, const FAULTS: bool, const ENERGY: bool>(
        &mut self,
        arrivals: &[crate::workload::Arrival],
        probe: &mut P,
    ) -> ClusterOutcome {
        let n_blocks = self.params.n_blocks;
        let n_cells = self.cells.len();
        let mut queue: EventQueue<Event> = EventQueue::new(VirtualClock::new());
        let mut states: Vec<ReqState> = arrivals
            .iter()
            .enumerate()
            .map(|(i, a)| ReqState {
                tokens: a.tokens.max(1),
                cell: i % n_cells,
                arrived: nanos_from_secs(a.time_s),
                next_block: 0,
                handed_over: false,
                barrier: 0,
                dropped: false,
                retries: 0,
            })
            // detlint: allow(hotpath-alloc) one-time setup: per-request state built before the event loop
            .collect();
        // Events are scheduled on the owning cell's lane: simultaneous
        // events across cells fire in cell order, which makes the serial
        // pop order the canonical k-way merge of per-cell streams by
        // `(time, cell, seq)` — the order the sharded engine reproduces.
        for (i, st) in states.iter().enumerate() {
            queue.schedule_at_in_lane(st.arrived, st.cell as u32, Event::Arrive(i));
        }
        // Adaptive cells tick on their epoch cadence while the cell has
        // requests outstanding; ticks stop rescheduling once every
        // request homed there has completed or been dropped, so finite
        // streams still drain. The count is per cell (a re-home at
        // arrival moves it), so an idle cell's plane stops re-solving
        // while its neighbors still serve.
        // detlint: allow(hotpath-alloc) one-time setup: per-cell counters sized before the event loop
        let mut outstanding = vec![0usize; n_cells];
        for st in &states {
            outstanding[st.cell] += 1;
        }
        for ci in 0..n_cells {
            if let Some(e) = self.cells[ci].plane.epoch_s() {
                queue.schedule_at_in_lane(nanos_from_secs(e), ci as u32, Event::ControlTick(ci));
            }
        }
        // Fault lanes arm last at setup, so an equal-time fault resolves
        // after arrivals/ticks — the order the sharded engine reproduces.
        if FAULTS {
            for ci in 0..n_cells {
                let n_dev = self.cells[ci].dev.len();
                self.cell_faults[ci] = CellFaults::new(n_dev);
                for m in &mut self.cells[ci].dev.service_mult {
                    *m = 1.0;
                }
                self.cells[ci].inflight.clear();
                if let Some(ev) = self.fault_lanes[ci].first() {
                    queue.schedule_at_in_lane(ev.at, ci as u32, Event::Fault(ci));
                }
            }
        }
        // detlint: allow(hotpath-alloc) capacity-0 construction; grows only on the first fault, then reused
        let mut lost: Vec<InflightGroup> = Vec::new();

        let mut arrived = 0usize;
        let mut completed = 0usize;
        let mut dropped = 0usize;
        let mut arrived_tokens = 0u64;
        let mut completed_tokens = 0u64;
        let mut dropped_tokens = 0u64;
        let mut shed_tokens = 0.0f64;
        let mut handovers = 0usize;
        let mut borrowed_groups = 0usize;
        let mut borrowed_tokens = 0.0f64;
        let mut slo_missed = 0usize;
        let mut retries = 0usize;
        let mut hedges = 0usize;
        let mut wasted_tokens = 0.0f64;
        let mut events = 0usize;
        let mut latency_ms = SteadyState::with_capacity(self.params.warmup_frac, arrivals.len());
        // Makespan is the last *work* event: a control tick pending when
        // the final request completes must not pad the horizon (it would
        // bias throughput/utilization against adaptive planes).
        let mut last_work_ns: Nanos = 0;
        // Sim-time sampling: piecewise-constant on the event sequence —
        // ticks due at or before the popped event's time observe the
        // state as of the previous event. Without a cadence (NullProbe)
        // the next tick sits at Nanos::MAX and the check never fires.
        let cadence = probe.sample_cadence().map(|c| c.max(1));
        let mut next_sample = cadence.unwrap_or(Nanos::MAX);
        // detlint: allow(hotpath-alloc) capacity-0 construction; grows only under a sampling probe, then reused
        let mut samples: Vec<CellSample> = Vec::new();

        // Drain one cell's freshly depleted batteries: each becomes a
        // deterministic `Crash` through the exact fault path (ledger
        // sweep, re-dispatch / drop / shed, barrier chase), plus an
        // optional recharge episode. FIFO over the order batteries died;
        // a re-dispatch may deplete the *next* battery, which the same
        // loop then drains — both engines run this at identical
        // structural points, so the cascade order is canonical.
        macro_rules! drain_depletions {
            ($ci:expr, $now:expr) => {{
                let ci: usize = $ci;
                let at: Nanos = $now;
                while let Some(k) = self.cells[ci].energy.pop_depleted() {
                    probe.on_event(&TelemetryEvent::BatteryDepleted {
                        cell: ci,
                        device: k,
                        t: at,
                    });
                    lost.clear();
                    apply_action(
                        FaultAction::Crash { device: k },
                        ci,
                        at,
                        &mut self.cells[ci],
                        &mut self.cell_faults[ci],
                        &mut self.handover,
                        &mut lost,
                        probe,
                    );
                    if self.cells[ci].energy.recharge_ns() > 0 {
                        let done = at.saturating_add(self.cells[ci].energy.recharge_ns());
                        queue.schedule_at_in_lane(done, ci as u32, Event::Recharge(ci, k));
                    }
                    for g in &lost {
                        let st = &mut states[g.req];
                        if st.dropped {
                            continue;
                        }
                        match resolve_lost_group(
                            g,
                            st,
                            ci,
                            at,
                            &mut self.cells[ci],
                            &self.dispatcher,
                            &self.params,
                            probe,
                        ) {
                            LossResolution::Covered => {}
                            LossResolution::Redispatched { waste } => {
                                retries += 1;
                                wasted_tokens += waste;
                            }
                            LossResolution::Dropped { waste } => {
                                wasted_tokens += waste;
                                dropped += 1;
                                dropped_tokens += st.tokens as u64;
                                outstanding[st.cell] -= 1;
                                if self.params.deadline_s > 0.0 {
                                    slo_missed += 1;
                                }
                            }
                            LossResolution::Shed { tokens, waste } => {
                                shed_tokens += tokens;
                                wasted_tokens += waste;
                            }
                        }
                    }
                }
            }};
        }

        while let Some((now, ev)) = queue.pop() {
            while next_sample <= now {
                self.snapshot_cells(next_sample, &mut samples);
                probe.on_sample(next_sample, &samples);
                next_sample = next_sample
                    // detlint: allow(panic) next_sample is finite only when a cadence was set
                    .saturating_add(cadence.expect("a due sample implies a cadence"));
            }
            events += 1;
            let i = match ev {
                Event::ControlTick(ci) => {
                    // A tick popping after the cell's last request
                    // completed must neither re-solve (it would inflate
                    // the resolves/churn columns with work that can't
                    // matter) nor reschedule.
                    if outstanding[ci] > 0 {
                        self.control_tick_probed(ci, now, probe);
                        if let Some(e) = self.cells[ci].plane.epoch_s() {
                            queue.schedule_in_lane(
                                nanos_from_secs(e),
                                ci as u32,
                                Event::ControlTick(ci),
                            );
                        }
                    }
                    continue;
                }
                Event::Fault(ci) => {
                    // Apply the lane's next compiled event, arm the one
                    // after it, then resolve any in-service groups the
                    // action stranded (crash recovery). Fault pops count
                    // in `events` but never advance `last_work_ns`.
                    let fev = self.fault_lanes[ci][self.cell_faults[ci].cursor];
                    self.cell_faults[ci].cursor += 1;
                    if let Some(next) = self.fault_lanes[ci].get(self.cell_faults[ci].cursor) {
                        queue.schedule_at_in_lane(next.at, ci as u32, Event::Fault(ci));
                    }
                    lost.clear();
                    apply_action(
                        fev.action,
                        ci,
                        now,
                        &mut self.cells[ci],
                        &mut self.cell_faults[ci],
                        &mut self.handover,
                        &mut lost,
                        probe,
                    );
                    for g in &lost {
                        let st = &mut states[g.req];
                        if st.dropped {
                            continue;
                        }
                        match resolve_lost_group(
                            g,
                            st,
                            ci,
                            now,
                            &mut self.cells[ci],
                            &self.dispatcher,
                            &self.params,
                            probe,
                        ) {
                            LossResolution::Covered => {}
                            LossResolution::Redispatched { waste } => {
                                retries += 1;
                                wasted_tokens += waste;
                            }
                            LossResolution::Dropped { waste } => {
                                wasted_tokens += waste;
                                dropped += 1;
                                dropped_tokens += st.tokens as u64;
                                outstanding[st.cell] -= 1;
                                if self.params.deadline_s > 0.0 {
                                    slo_missed += 1;
                                }
                            }
                            LossResolution::Shed { tokens, waste } => {
                                shed_tokens += tokens;
                                wasted_tokens += waste;
                            }
                        }
                    }
                    if ENERGY {
                        // A crash re-dispatch above debits the surviving
                        // replica: drain any battery it finished off.
                        drain_depletions!(ci, now);
                    }
                    continue;
                }
                Event::Recharge(ci, k) => {
                    // A recharge episode completes: the energy layer
                    // clears the depletion (so it no longer blocks
                    // recovery), then the ordinary fault `Recover` path
                    // brings the device back online and re-solves.
                    // Stale pops (reset in between) recharge nothing.
                    // Recharge pops count in `events` but never advance
                    // `last_work_ns`.
                    if ENERGY && self.cells[ci].energy.recharge(k, now) {
                        lost.clear();
                        apply_action(
                            FaultAction::Recover { device: k },
                            ci,
                            now,
                            &mut self.cells[ci],
                            &mut self.cell_faults[ci],
                            &mut self.handover,
                            &mut lost,
                            probe,
                        );
                    }
                    continue;
                }
                Event::Arrive(i) => {
                    arrived += 1;
                    arrived_tokens += states[i].tokens as u64;
                    last_work_ns = now;
                    // The final cell choice happens *now*, not at stream
                    // build time: load-aware re-homing must read the
                    // live backlog. `states[i].cell` holds the
                    // round-robin home assigned at build time; under
                    // `HandoverPolicy::None` rehome returns it as is.
                    let rr_home = states[i].cell;
                    let chosen = self.handover.rehome(rr_home, now, &self.cells);
                    states[i].cell = chosen;
                    if chosen != rr_home {
                        states[i].handed_over = true;
                        handovers += 1;
                        outstanding[rr_home] -= 1;
                        outstanding[chosen] += 1;
                    }
                    probe.on_event(&TelemetryEvent::Arrive {
                        req: i,
                        tokens: states[i].tokens,
                        rr_home,
                        cell: chosen,
                        t: now,
                    });
                    i
                }
                Event::BlockDone(i) => {
                    if FAULTS {
                        // Tombstone: the request was dropped by crash
                        // recovery after this completion was scheduled.
                        if states[i].dropped {
                            continue;
                        }
                        // Recovery moved part of this block later —
                        // chase the barrier (reschedule-on-pop; the
                        // queue has no removal).
                        if states[i].barrier > now {
                            queue.schedule_at_in_lane(
                                states[i].barrier,
                                states[i].cell as u32,
                                Event::BlockDone(i),
                            );
                            continue;
                        }
                    }
                    last_work_ns = now;
                    states[i].next_block += 1;
                    if states[i].next_block >= n_blocks {
                        completed += 1;
                        completed_tokens += states[i].tokens as u64;
                        outstanding[states[i].cell] -= 1;
                        let lat_ms = secs_from_nanos(now - states[i].arrived) * 1e3;
                        latency_ms.record(lat_ms);
                        if self.params.deadline_s > 0.0 && lat_ms > self.params.deadline_s * 1e3 {
                            slo_missed += 1;
                        }
                        probe.on_event(&TelemetryEvent::Completed {
                            req: i,
                            cell: states[i].cell,
                            t: now,
                            latency_ms: lat_ms,
                        });
                        continue;
                    }
                    i
                }
            };
            // Backlog-delta trigger: between epoch ticks, an adaptive
            // cell whose total queued seconds drifted past the
            // threshold since its last solve re-solves *now*, before
            // this block is dispatched (0 disables; static planes have
            // no epoch and never trigger).
            if self.params.backlog_delta_s > 0.0 {
                let ci = states[i].cell;
                let cell = &self.cells[ci];
                if cell.plane.epoch_s().is_some()
                    && (cell_backlog_s(cell, now) - cell.last_solve_backlog_s).abs()
                        > self.params.backlog_delta_s
                {
                    self.control_tick_probed(ci, now, probe);
                }
            }
            let r = self.start_block(&states[i], i, now, probe);
            shed_tokens += r.shed_tokens;
            borrowed_groups += r.borrowed_groups;
            borrowed_tokens += r.borrowed_tokens;
            wasted_tokens += r.wasted_tokens;
            hedges += r.hedges;
            if r.borrowed_groups > 0 && !states[i].handed_over {
                states[i].handed_over = true;
                handovers += 1;
            }
            match r.end {
                Some(block_end) => {
                    probe.on_event(&TelemetryEvent::Block {
                        req: i,
                        cell: states[i].cell,
                        block: states[i].next_block,
                        start: now,
                        end: block_end,
                    });
                    queue.schedule_at_in_lane(
                        block_end,
                        states[i].cell as u32,
                        Event::BlockDone(i),
                    );
                    if FAULTS {
                        states[i].barrier = block_end;
                    }
                }
                None => {
                    dropped += 1;
                    dropped_tokens += states[i].tokens as u64;
                    outstanding[states[i].cell] -= 1;
                    if self.params.deadline_s > 0.0 {
                        slo_missed += 1;
                    }
                    probe.on_event(&TelemetryEvent::Dropped {
                        req: i,
                        cell: states[i].cell,
                        t: now,
                    });
                }
            }
            if FAULTS && ENERGY {
                // Batteries this block's debits finished off crash *now*,
                // before any later event, in cell index order — a
                // borrowed group may have drained a neighbor's battery.
                // (The sharded engine never borrows; it drains its own
                // cell at the same structural point.)
                for ci in 0..n_cells {
                    drain_depletions!(ci, now);
                }
            }
        }

        // Offline device-seconds: closed outage intervals accumulated at
        // recovery, plus still-open outages clamped to the makespan.
        // Integer-nanosecond sums are order-free, so the serial and
        // sharded engines agree bit-for-bit.
        let mut offline_ns: u64 = 0;
        if FAULTS {
            for (ci, rt) in self.cell_faults.iter().enumerate() {
                offline_ns += rt.offline_ns;
                for (k, &on) in self.cells[ci].dev.online.iter().enumerate() {
                    if !on {
                        offline_ns += last_work_ns.saturating_sub(rt.offline_since[k]);
                    }
                }
            }
        }

        // Energy teardown: settle idle draw to the same last-work
        // instant in both engines, then total joules in cell index
        // order (f64 sums stay byte-stable because the order is fixed).
        let mut energy_j = 0.0f64;
        // detlint: allow(hotpath-alloc) one-time teardown: outcome assembly after the loop drains
        let mut energy_cells: Vec<f64> = Vec::new();
        // detlint: allow(hotpath-alloc) one-time teardown: outcome assembly after the loop drains
        let mut depleted_cells: Vec<usize> = Vec::new();
        let mut first_depletion: Nanos = 0;
        let mut last_depletion: Nanos = 0;
        if ENERGY {
            for cell in &mut self.cells {
                cell.energy.settle_idle(last_work_ns);
                let spent = cell.energy.spent_total();
                energy_j += spent;
                energy_cells.push(spent);
                depleted_cells.push(cell.energy.depleted_count());
                let f = cell.energy.first_depletion();
                if f != 0 && (first_depletion == 0 || f < first_depletion) {
                    first_depletion = f;
                }
                last_depletion = last_depletion.max(cell.energy.last_depletion());
            }
        }

        let makespan_s = secs_from_nanos(last_work_ns);
        // Teardown: the event loop has drained; these collects build the
        // returned outcome, not per-event state.
        let utilization = self
            .cells
            .iter()
            // detlint: allow(hotpath-alloc) one-time teardown: outcome assembly after the loop drains
            .map(|c| c.dev.busy.iter().map(|u| u.fraction(makespan_s)).collect())
            // detlint: allow(hotpath-alloc) one-time teardown: outcome assembly after the loop drains
            .collect();
        // detlint: allow(hotpath-alloc) one-time teardown: outcome assembly after the loop drains
        let control = self.cells.iter().map(|c| c.plane.stats()).collect();
        let mut solver = SolverIntrospection::default();
        for c in &self.cells {
            solver.absorb(&c.plane.solver_stats());
        }
        ClusterOutcome {
            arrived,
            completed,
            dropped,
            arrived_tokens,
            completed_tokens,
            dropped_tokens,
            shed_tokens,
            handovers,
            borrowed_groups,
            borrowed_tokens,
            in_flight: arrived - completed - dropped,
            events,
            makespan_s,
            latency_ms,
            utilization,
            control,
            solver,
            slo_missed,
            retries,
            hedges,
            wasted_tokens,
            offline_device_s: secs_from_nanos(offline_ns),
            energy_j,
            energy_cells,
            depleted_cells,
            first_depletion,
            last_depletion,
        }
    }

    /// Epoch boundary for one cell: convert queue backlog to a token
    /// demand vector (in the cell's reused scratch) and hand it — with
    /// the per-expert counts since the last tick — to the control plane.
    ///
    /// A [`TelemetryEvent::ControlResolve`] fires only when the plane
    /// actually solved (its [`SolverIntrospection::solves`] counter
    /// advanced) — hysteresis-suppressed epochs and static planes stay
    /// silent.
    fn control_tick_probed<P: Probe>(&mut self, ci: usize, now: Nanos, probe: &mut P) {
        control_tick_at(&mut self.cells[ci], ci, now, probe);
    }

    /// Dispatch one block of one request; returns the block's completion
    /// instant (the Eq. (11) barrier over its token groups — local *and*
    /// borrowed), or a drop marker when admission control rejects the
    /// request.
    fn start_block<P: Probe>(
        &mut self,
        st: &ReqState,
        req: usize,
        now: Nanos,
        probe: &mut P,
    ) -> BlockResult {
        // Split borrow around the home cell: `left`/`right` are the
        // neighbor cells the handover layer may stage borrows into while
        // the home cell stays mutably held.
        let (left, rest) = self.cells.split_at_mut(st.cell);
        // detlint: allow(panic) st.cell < cells.len() by construction, so rest is non-empty
        let (cell, right) = rest.split_first_mut().expect("valid home cell index");
        start_block_at(
            &self.params,
            &self.dispatcher,
            &mut self.handover,
            cell,
            left,
            right,
            st,
            req,
            now,
            probe,
        )
    }
}

/// Epoch boundary for one cell: convert queue backlog to a token demand
/// vector (in the cell's reused scratch) and hand it — with the
/// per-expert counts since the last tick — to the control plane. Shared
/// by the serial loop and the sharded engine (a control tick touches
/// only its own cell, so a shard runs it without synchronization).
///
/// A [`TelemetryEvent::ControlResolve`] fires only when the plane
/// actually solved (its [`SolverIntrospection::solves`] counter
/// advanced) — hysteresis-suppressed epochs and static planes stay
/// silent.
pub(super) fn control_tick_at<P: Probe>(cell: &mut Cell, ci: usize, now: Nanos, probe: &mut P) {
    let solves_before = cell.plane.solver_stats().solves;
    let n_dev = cell.dev.len();
    cell.demand.clear();
    cell.demand.resize(n_dev, 0.0);
    let mut backlog_total_s = 0.0;
    {
        let t = cell.plane.t_per_token();
        for k in 0..n_dev {
            let backlog_s = secs_from_nanos(cell.dev.busy_until[k].saturating_sub(now));
            backlog_total_s += backlog_s;
            let backlog_tokens = if t[k].is_finite() && t[k] > 0.0 {
                backlog_s / t[k]
            } else {
                0.0
            };
            // Demand proxy: the larger of current backlog and the
            // epoch's dispatches. Tokens routed this epoch that are
            // still queued appear in both signals, so summing would
            // double-count momentarily backlogged devices and make
            // the re-solve overshoot; the max never double-counts,
            // and recent dispatches keep a device's share alive even
            // when its queue happens to be drained.
            cell.demand[k] = backlog_tokens.max(cell.dev.served_tokens[k]);
        }
    }
    // Energy-aware control: scale the demand the P3 re-solve sees away
    // from drained batteries — a device at fraction `f` keeps
    // `1 - w·(1-f)` of its demand (floored so a dying device never
    // reads as zero and starves the solver of its real load). Weight 0
    // (or energy off) leaves the vector untouched bit-for-bit.
    if cell.energy.enabled && cell.energy.weight > 0.0 {
        cell.energy.refresh_scores(cell.plane.bandwidth());
        let w = cell.energy.weight.min(1.0);
        let s = cell.energy.score();
        for k in 0..n_dev {
            cell.demand[k] *= (1.0 - w * (1.0 - s.frac[k])).max(0.05);
        }
    }
    cell.plane.on_epoch(&cell.demand, &cell.expert_tokens);
    // The drift reference resets on every solve attempt (even one
    // hysteresis suppressed), so the trigger measures *new* drift
    // rather than re-firing on the same backlog every block.
    cell.last_solve_backlog_s = backlog_total_s;
    for v in &mut cell.dev.served_tokens {
        *v = 0.0;
    }
    for v in &mut cell.expert_tokens {
        *v = 0.0;
    }
    let after = cell.plane.solver_stats();
    if after.solves > solves_before {
        probe.on_event(&TelemetryEvent::ControlResolve {
            cell: ci,
            t: now,
            iterations: after.last_iterations,
            objective: after.last_objective,
            warm: after.last_warm,
            converged: after.last_converged,
        });
    }
}

/// Dispatch one block of one request against its home `cell`; returns
/// the block's completion instant (the Eq. (11) barrier over its token
/// groups — local *and* borrowed), or a drop marker when admission
/// control rejects the request.
///
/// Free function shared by [`ClusterSim::run_probed`] (which passes the
/// split borrow around the home cell) and the sharded engine (which
/// passes empty neighbor slices: under
/// [`crate::config::HandoverPolicy::None`] — the only policy the shards
/// parallelize — the handover layer never reads them).
#[allow(clippy::too_many_arguments)]
pub(super) fn start_block_at<P: Probe>(
    params: &SimParams,
    dispatcher: &Dispatcher,
    handover: &mut HandoverCoordinator,
    cell: &mut Cell,
    left: &mut [Cell],
    right: &mut [Cell],
    st: &ReqState,
    req: usize,
    now: Nanos,
    probe: &mut P,
) -> BlockResult {
    let n_experts = params.n_experts;
    let queue_limit_s = params.queue_limit_s;
    let drop_policy = params.drop_policy;
    let top_k = params.top_k;
    let gate_sharpness = params.gate_sharpness;
    let gate_bias = params.gate_bias;
    // Draw this block's gate weights into the cell's reusable matrix —
    // same RNG stream and arithmetic as the allocating variant, but the
    // row buffers recycle through the spare pool.
    cell.gates.synthetic_gate_weights_biased_into(
        st.tokens,
        n_experts,
        gate_sharpness,
        gate_bias,
        &mut cell.gate.weights,
        &mut cell.gate_spare,
        &mut cell.gate_offsets,
    );
    // Energy-aware dispatch: refresh the per-device joules/token and
    // battery-fraction caches from the live bandwidth split once per
    // block (weight 0 — or energy off — never reads them; the choosers
    // then take the exact integer path).
    if cell.energy.enabled && cell.energy.weight > 0.0 {
        cell.energy.refresh_scores(cell.plane.bandwidth());
    }
    // Service times and placement come from the control plane *now*:
    // an epoch re-solve between blocks redirects this dispatch.
    let t_per_token = cell.plane.t_per_token();
    let placement = cell.plane.placement();
    // Per-expert latency estimate (best online replica) and liveness,
    // in the cell's reused scratch.
    cell.est.per_token.clear();
    cell.est.per_token.resize(n_experts, f64::INFINITY);
    cell.expert_online.clear();
    cell.expert_online.resize(n_experts, false);
    for e in 0..n_experts {
        for &k in placement.replicas(e) {
            if cell.dev.online[k] {
                cell.expert_online[e] = true;
                if t_per_token[k] < cell.est.per_token[e] {
                    cell.est.per_token[e] = t_per_token[k];
                }
            }
        }
    }
    let ctx = SelectionContext {
        latencies: &cell.est,
        top_k,
        online: &cell.expert_online,
    };
    cell.policy
        .select_into(&cell.gate, &ctx, &mut cell.sel, &mut cell.sel_scratch);
    cell.sel.tokens_per_device_into(&mut cell.counts);

    let mut block_end = now;
    let mut shed = 0.0f64;
    let mut wasted = 0.0f64;
    let mut hedges = 0usize;
    // Heaviest shed group, kept so a block can never shed everything
    // (every token needs at least one expert — constraint (16) — and
    // a zero-work block would fake perfect latency under overload).
    let mut best_shed: Option<(usize, f64)> = None;
    // Pass 1: place every group against the cell's scratch copy of
    // the queue state (reused across blocks — no allocation). A
    // DropRequest rejection must leave *no* partial work behind,
    // whichever expert index trips the bound.
    cell.dev.scratch_busy.copy_from_slice(&cell.dev.busy_until);
    cell.placed.clear();
    for e in 0..n_experts {
        let q = cell.counts[e];
        if q <= 0.0 {
            continue;
        }
        // Admission control: the drop policy applies only when every
        // replica of the expert sits beyond the queue bound — an
        // under-bound replica is preferred even if it finishes later.
        let k = if queue_limit_s > 0.0 {
            // Cheap serviceability check (no predicted-completion
            // scan): distinguishes "no replica at all" (selection
            // drop) from "all replicas over the bound" (drop policy).
            if !placement
                .replicas(e)
                .iter()
                .any(|&r| cell.dev.online[r] && t_per_token[r].is_finite())
            {
                // No local replica can serve at all: a neighbor may
                // still host one (`BorrowExpert`); otherwise the
                // tokens are dropped by selection, as before.
                if let Some(barrier) = handover.try_borrow_probed(
                    probe,
                    req,
                    st.cell,
                    e,
                    q,
                    now,
                    queue_limit_s,
                    &mut *left,
                    &mut *right,
                ) {
                    if barrier > block_end {
                        block_end = barrier;
                    }
                }
                continue;
            }
            cell.cand.clear();
            for &r in placement.replicas(e) {
                // The bound measures *pre-existing* backlog
                // (committed queue state at block start), not the
                // block's own tentative placements — a single large
                // block on an idle cluster is barrier work, not
                // overload.
                let backlog_s = secs_from_nanos(cell.dev.busy_until[r].saturating_sub(now));
                if backlog_s <= queue_limit_s {
                    cell.cand.push(r);
                }
            }
            match dispatcher.choose_probed(
                probe,
                st.cell,
                e,
                &cell.cand,
                q,
                now,
                &cell.dev.scratch_busy,
                t_per_token,
                &cell.dev.online,
                cell.energy.score(),
            ) {
                Some(k) => k,
                None => {
                    // Every local replica is over the queue bound:
                    // borrowing a neighbor's replica beats invoking
                    // the drop policy.
                    if let Some(barrier) = handover.try_borrow_probed(
                        probe,
                        req,
                        st.cell,
                        e,
                        q,
                        now,
                        queue_limit_s,
                        &mut *left,
                        &mut *right,
                    ) {
                        if barrier > block_end {
                            block_end = barrier;
                        }
                        continue;
                    }
                    match drop_policy {
                        DropPolicy::DropRequest => {
                            // A rejection must leave no partial work
                            // behind — in *any* cell: un-stage the
                            // block's cross-cell borrows too.
                            handover.rollback_probed(
                                probe,
                                req,
                                st.cell,
                                now,
                                &mut *left,
                                &mut *right,
                            );
                            return BlockResult {
                                end: None,
                                shed_tokens: 0.0,
                                borrowed_groups: 0,
                                borrowed_tokens: 0.0,
                                wasted_tokens: 0.0,
                                hedges: 0,
                            };
                        }
                        DropPolicy::ShedTokens => {
                            shed += q;
                            // Shed demand is still demand: without
                            // this the autoscaler is blind to
                            // exactly the experts being shed.
                            // (ShedTokens never aborts the block, so
                            // this needs no rollback.)
                            cell.expert_tokens[e] += q;
                            probe.on_event(&TelemetryEvent::GroupShed {
                                req,
                                cell: st.cell,
                                expert: e,
                                tokens: q,
                                t: now,
                            });
                            let heavier = match best_shed {
                                None => true,
                                Some((_, bq)) => q > bq,
                            };
                            if heavier {
                                best_shed = Some((e, q));
                            }
                            continue;
                        }
                    }
                }
            }
        } else {
            match dispatcher.choose_probed(
                probe,
                st.cell,
                e,
                placement.replicas(e),
                q,
                now,
                &cell.dev.scratch_busy,
                t_per_token,
                &cell.dev.online,
                cell.energy.score(),
            ) {
                Some(k) => k,
                None => {
                    // No serviceable local replica: try a neighbor's
                    // (`BorrowExpert`); otherwise the tokens are
                    // dropped by selection, as before.
                    if let Some(barrier) = handover.try_borrow_probed(
                        probe,
                        req,
                        st.cell,
                        e,
                        q,
                        now,
                        queue_limit_s,
                        &mut *left,
                        &mut *right,
                    ) {
                        if barrier > block_end {
                            block_end = barrier;
                        }
                    }
                    continue;
                }
            }
        };
        // `service_mult[k]` is 1.0 without a fault plan: `q · t_k · 1.0`
        // is bit-exact `q · t_k`, so the zero-fault path is unchanged.
        let service_s = q * t_per_token[k] * cell.dev.service_mult[k];
        let start = cell.dev.scratch_busy[k].max(now);
        let done = start.saturating_add(nanos_from_secs(service_s));
        cell.dev.scratch_busy[k] = done;
        cell.placed.push(PlacedGroup {
            expert: e,
            device: k,
            tokens: q,
            service_s,
            start,
            done,
            hedge: false,
            cover: None,
        });
        let mut eff_done = done;
        // Hedged dispatch: if this group alone would bust the request's
        // deadline, place a speculative duplicate on the runner-up
        // replica — first finish wins the barrier, the loser's tokens
        // are waste by construction (both copies run to completion in
        // the FIFO-reservation model).
        if params.hedge && params.deadline_s > 0.0 {
            let deadline = st.arrived.saturating_add(nanos_from_secs(params.deadline_s));
            if done > deadline {
                if let Some(k2) = dispatcher.choose_excluding(
                    placement.replicas(e),
                    q,
                    now,
                    &cell.dev.scratch_busy,
                    t_per_token,
                    &cell.dev.online,
                    k,
                    cell.energy.score(),
                ) {
                    let service2 = q * t_per_token[k2] * cell.dev.service_mult[k2];
                    let start2 = cell.dev.scratch_busy[k2].max(now);
                    let done2 = start2.saturating_add(nanos_from_secs(service2));
                    cell.dev.scratch_busy[k2] = done2;
                    let pi = cell.placed.len() - 1;
                    cell.placed[pi].cover = Some(done2);
                    cell.placed.push(PlacedGroup {
                        expert: e,
                        device: k2,
                        tokens: q,
                        service_s: service2,
                        start: start2,
                        done: done2,
                        hedge: true,
                        cover: Some(done),
                    });
                    eff_done = done.min(done2);
                    wasted += q;
                    hedges += 1;
                    probe.on_event(&TelemetryEvent::Hedged {
                        req,
                        cell: st.cell,
                        expert: e,
                        primary: k,
                        device: k2,
                        tokens: q,
                        t: now,
                    });
                }
            }
        }
        if eff_done > block_end {
            block_end = eff_done;
        }
    }
    // A block must do *some* work: if shedding removed every group
    // (and nothing was borrowed either), serve the heaviest one
    // anyway — the barrier then reflects the overloaded device
    // instead of a zero-time hop.
    if cell.placed.is_empty() && !handover.has_staged() {
        if let Some((e, q)) = best_shed {
            if let Some(k) = dispatcher.choose_probed(
                probe,
                st.cell,
                e,
                placement.replicas(e),
                q,
                now,
                &cell.dev.scratch_busy,
                t_per_token,
                &cell.dev.online,
                cell.energy.score(),
            ) {
                shed -= q;
                // Un-count the shed-side demand: the commit pass
                // below records this group like any other placement.
                // (The earlier `GroupShed` event stands: a rescued
                // group appears as shed *then* placed in a trace.)
                cell.expert_tokens[e] -= q;
                let service_s = q * t_per_token[k] * cell.dev.service_mult[k];
                let start = cell.dev.scratch_busy[k].max(now);
                let done = start.saturating_add(nanos_from_secs(service_s));
                cell.dev.scratch_busy[k] = done;
                cell.placed.push(PlacedGroup {
                    expert: e,
                    device: k,
                    tokens: q,
                    service_s,
                    start,
                    done,
                    hedge: false,
                    cover: None,
                });
                if done > block_end {
                    block_end = done;
                }
            }
        }
    }
    // Pass 2: the block was admitted — commit the placements.
    // `GroupPlaced` fires only here, so a trace never contains a
    // group from a rolled-back (dropped) block.
    cell.dev.busy_until.copy_from_slice(&cell.dev.scratch_busy);
    for g in &cell.placed {
        cell.dev.busy[g.device].add_busy(g.service_s);
        // A hedged duplicate burns real device time (`busy`,
        // `served_tokens`) but is invisible to the demand signals — its
        // twin already fed the policy and the autoscaler.
        if !g.hedge {
            cell.policy.observe(g.expert, t_per_token[g.device]);
        }
        cell.dev.served_tokens[g.device] += g.tokens;
        if !g.hedge {
            cell.expert_tokens[g.expert] += g.tokens;
        }
        probe.on_event(&TelemetryEvent::GroupPlaced {
            req,
            cell: st.cell,
            device: g.device,
            expert: g.expert,
            tokens: g.tokens,
            enqueue: now,
            start: g.start,
            done: g.done,
        });
    }
    // Energy: every committed group debits its serving device under the
    // live bandwidth split — hedged duplicates burn real joules like
    // they burn real device time. Depletions queue in the energy FIFO;
    // the engines drain them into crashes right after this block.
    if cell.energy.enabled {
        let bw = cell.plane.bandwidth();
        for g in &cell.placed {
            cell.energy.debit(g.device, g.tokens, bw, now);
        }
    }
    // Fault runs track committed groups in the in-flight ledger so a
    // device crash can find and re-dispatch them. (Borrowed cross-cell
    // groups are not tracked: `BorrowExpert` runs serial-only and a
    // remote crash sweeping another cell's ledger would break shard
    // locality — documented simplification.)
    if params.faults {
        // Drop finished entries first so the ledger tracks the live
        // working set, not the whole run's history. Per-cell and
        // time-driven, so serial and sharded runs prune identically.
        cell.inflight.retain(|g| g.done > now);
        for g in &cell.placed {
            cell.inflight.push(InflightGroup {
                req,
                expert: g.expert,
                device: g.device,
                tokens: g.tokens,
                start: g.start,
                done: g.done,
                cover: g.cover,
            });
        }
    }
    // Commit the staged cross-cell groups. Accounting lands on the
    // *serving* cell (its control plane must see borrowed demand);
    // the home cell's selection policy observes the effective
    // per-token cost including both backhaul hops, and its
    // autoscaler still counts the expert as hot locally — so an
    // adaptive home cell replicates a chronically-borrowed expert
    // rather than borrowing forever.
    let mut borrowed_groups = 0usize;
    let mut borrowed_tokens = 0.0f64;
    for s in handover.staged() {
        // Directed per-pair hop costs (uniform configs reduce both to
        // the scalar, keeping the old arithmetic bit for bit).
        let out_s = handover.backhaul_pair(st.cell, s.cell);
        let back_s = handover.backhaul_pair(s.cell, st.cell);
        let serving = super::handover::cell_mut(st.cell, s.cell, &mut *left, &mut *right);
        serving.commit_remote(s.device, s.expert, s.tokens, s.service_s);
        // The borrowed group's joules land on the *serving* cell's
        // device, under that cell's bandwidth split — energy follows
        // the work, like the rest of the remote accounting.
        if serving.energy.enabled {
            let bw = serving.plane.bandwidth();
            serving.energy.debit(s.device, s.tokens, bw, now);
        }
        cell.policy
            .observe(s.expert, s.service_s / s.tokens + (out_s + back_s));
        cell.expert_tokens[s.expert] += s.tokens;
        borrowed_groups += 1;
        borrowed_tokens += s.tokens;
        probe.on_event(&TelemetryEvent::BorrowCommitted {
            req,
            home: st.cell,
            cell: s.cell,
            device: s.device,
            expert: s.expert,
            tokens: s.tokens,
            sent: s.sent,
            landed: s.sent.saturating_add(nanos_from_secs(s.tokens * out_s)),
            start: s.start,
            done: s.start.saturating_add(nanos_from_secs(s.service_s)),
            barrier: s.barrier,
        });
    }
    handover.clear_staged();
    BlockResult {
        end: Some(block_end),
        shed_tokens: shed,
        borrowed_groups,
        borrowed_tokens,
        wasted_tokens: wasted,
        hedges,
    }
}

// The arrival-rate and control-plane sweeps moved to
// `crate::experiment::sweeps` as thin wrappers over the typed
// `experiment::Grid` API (still re-exported from `crate::cluster`).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, DispatchKind};
    use crate::workload::{ArrivalProcess, Benchmark};

    fn small_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::single_cell();
        cfg.model.n_blocks = 8; // keep tests fast
        cfg
    }

    fn run_with(cfg: ClusterConfig, rate: f64, n: usize, seed: u64) -> ClusterOutcome {
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: rate }.generate(n, Benchmark::Piqa, seed);
        sim.run(&arrivals)
    }

    #[test]
    fn drains_and_conserves_requests_and_tokens() {
        let out = run_with(small_cfg(), 1.0, 40, 0);
        assert_eq!(out.arrived, 40);
        assert_eq!(out.completed, 40);
        assert_eq!(out.dropped, 0);
        assert_eq!(out.in_flight, 0);
        assert_eq!(out.arrived_tokens, out.completed_tokens);
        assert_eq!(out.shed_tokens, 0.0);
        assert!(out.makespan_s > 0.0);
        assert!(out.throughput_rps() > 0.0);
        assert!(out.goodput_tps() > 0.0);
        assert_eq!(out.drop_rate(), 0.0);
        assert_eq!(out.latency_ms.total_count(), 40);
        // Every arrival and every block completion is an event.
        assert!(out.events >= 40 * (1 + 8));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(small_cfg(), 2.0, 30, 3);
        let b = run_with(small_cfg(), 2.0, 30, 3);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency_ms.steady_values(), b.latency_ms.steady_values());
        assert_eq!(a.utilization, b.utilization);
    }

    #[test]
    fn adaptive_control_is_deterministic_too() {
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        let a = run_with(cfg.clone(), 4.0, 30, 3);
        let b = run_with(cfg, 4.0, 30, 3);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency_ms.steady_values(), b.latency_ms.steady_values());
        assert_eq!(a.control, b.control);
    }

    /// The telemetry contract: probes observe, never perturb. A run
    /// with a live (counting, sampling) probe must be bit-equal to the
    /// plain `run()` on every outcome field.
    #[test]
    fn probed_run_is_bit_equal_to_unprobed() {
        struct Counting {
            events: usize,
            arrives: usize,
            samples: usize,
        }
        impl Probe for Counting {
            fn sample_cadence(&self) -> Option<Nanos> {
                Some(10_000_000) // 10 ms of sim time
            }
            fn on_event(&mut self, event: &TelemetryEvent) {
                self.events += 1;
                if matches!(event, TelemetryEvent::Arrive { .. }) {
                    self.arrives += 1;
                }
            }
            fn on_sample(&mut self, _t: Nanos, cells: &[CellSample]) {
                self.samples += 1;
                assert!(!cells.is_empty());
            }
        }

        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 8;
        cfg.control = ControlKind::Adaptive;
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 6.0 }.generate(30, Benchmark::Piqa, 7);

        let base = ClusterSim::new(&cfg).unwrap().run(&arrivals);
        let mut probe = Counting { events: 0, arrives: 0, samples: 0 };
        let probed = ClusterSim::new(&cfg).unwrap().run_probed(&arrivals, &mut probe);

        assert_eq!(base.makespan_s, probed.makespan_s);
        assert_eq!(base.latency_ms.steady_values(), probed.latency_ms.steady_values());
        assert_eq!(base.utilization, probed.utilization);
        assert_eq!(base.control, probed.control);
        assert_eq!(base.solver, probed.solver);
        assert_eq!(base.events, probed.events);
        // ... and the probe actually saw the run.
        assert_eq!(probe.arrives, probed.arrived);
        assert!(probe.events > probe.arrives, "block/placement events too");
        assert!(probe.samples > 0, "cadence produced timeline samples");
    }

    /// `run()` must report aggregated solver introspection: the
    /// adaptive plane re-solves at least once under load, and means
    /// stay consistent with the raw counters.
    #[test]
    fn outcome_surfaces_solver_introspection() {
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        let out = run_with(cfg, 6.0, 40, 5);
        assert!(out.solver.solves > 0);
        assert!(out.solver_iters_max() >= out.solver_iters_mean());
        assert_eq!(
            out.solver_iters_mean(),
            out.solver.iterations_total as f64 / out.solver.solves as f64
        );
        let uniform = run_with(small_cfg(), 6.0, 40, 5);
        assert_eq!(uniform.solver.solves, 0, "uniform plane never solves");
        assert_eq!(uniform.solver_iters_mean(), 0.0);
    }

    #[test]
    fn reset_restores_fresh_behaviour() {
        // A reused, reset simulator must reproduce a fresh one exactly —
        // including adaptive-plane state (warm splits, hysteresis,
        // stats) and policy history.
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        cfg.cache_capacity = 2;
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 6.0 }.generate(40, Benchmark::Piqa, 2);
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let first = sim.run(&arrivals);
        sim.reset().unwrap();
        let second = sim.run(&arrivals);
        let fresh = ClusterSim::new(&cfg).unwrap().run(&arrivals);
        assert_eq!(second.makespan_s, fresh.makespan_s);
        assert_eq!(second.makespan_s, first.makespan_s);
        assert_eq!(
            second.latency_ms.steady_values(),
            fresh.latency_ms.steady_values()
        );
        assert_eq!(second.utilization, fresh.utilization);
        assert_eq!(second.control, fresh.control);
        assert_eq!(second.events, fresh.events);
    }

    #[test]
    fn latency_grows_with_load() {
        // At 0.2 rps requests never overlap; at 20 rps the inter-arrival
        // gap is far below the per-request service time, so queues must
        // form and p95 latency must rise clearly.
        let lo = run_with(small_cfg(), 0.2, 60, 1);
        let hi = run_with(small_cfg(), 20.0, 60, 1);
        assert!(
            hi.steady_latency().percentile(95.0) > lo.steady_latency().percentile(95.0),
            "p95 {} <= {}",
            hi.steady_latency().percentile(95.0),
            lo.steady_latency().percentile(95.0)
        );
    }

    #[test]
    fn utilization_bounded_and_nonzero() {
        let out = run_with(small_cfg(), 2.0, 40, 2);
        let util = out.flat_utilization();
        assert!(!util.is_empty());
        for &u in &util {
            assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
        }
        assert!(util.iter().any(|&u| u > 0.0));
    }

    #[test]
    fn multi_cell_spreads_requests() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 4;
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 2.0 }.generate(30, Benchmark::Piqa, 0);
        let out = sim.run(&arrivals);
        assert_eq!(out.completed, 30);
        assert_eq!(out.utilization.len(), 2);
        // both cells did work
        for cell_util in &out.utilization {
            assert!(cell_util.iter().any(|&u| u > 0.0), "idle cell");
        }
    }

    #[test]
    fn offline_device_work_reroutes_to_replicas() {
        let mut cfg = small_cfg();
        cfg.cache_capacity = 2;
        cfg.dispatch = DispatchKind::LoadAware;
        let mut sim = ClusterSim::new(&cfg).unwrap();
        // Find a device hosting a replicated expert and kill it.
        sim.set_device_online(0, 7, false);
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 1.0 }.generate(20, Benchmark::Piqa, 4);
        let out = sim.run(&arrivals);
        assert_eq!(out.completed, 20);
        assert_eq!(out.utilization[0][7], 0.0, "offline device served work");
    }

    #[test]
    fn static_planes_never_tick_and_report_frozen_split() {
        let cfg = small_cfg();
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let share = cfg.cells[0].channel.total_bandwidth_hz / cfg.cells[0].n_devices() as f64;
        for &b in sim.bandwidth(0) {
            assert!((b - share).abs() < 1e-6);
        }
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(20, Benchmark::Piqa, 0);
        let out = sim.run(&arrivals);
        assert_eq!(out.control_total().resolves, 0);
        assert_eq!(out.control_total().churn_frac, 0.0);
    }

    #[test]
    fn adaptive_plane_resolves_during_run() {
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        cfg.control_epoch_s = 0.1;
        let out = run_with(cfg, 6.0, 60, 0);
        assert_eq!(out.completed, 60);
        let ctl = out.control_total();
        assert!(ctl.resolves >= 1, "adaptive plane never re-solved");
        assert!(ctl.churn_frac > 0.0, "re-solve moved no bandwidth");
    }

    #[test]
    fn bounded_queue_drop_request_rejects_under_overload() {
        // Limit chosen so the first (empty-system) requests clear it but
        // sustained 50 rps overload must trip it.
        let mut cfg = small_cfg();
        cfg.queue_limit_s = 0.2;
        cfg.drop_policy = DropPolicy::DropRequest;
        let out = run_with(cfg, 50.0, 80, 1);
        assert!(out.dropped > 0, "overload never tripped admission control");
        assert_eq!(out.arrived, 80);
        assert_eq!(out.completed + out.dropped, 80);
        assert_eq!(out.in_flight, 0);
        assert!(out.drop_rate() > 0.0 && out.drop_rate() <= 1.0);
        assert!(out.dropped_tokens > 0);
    }

    #[test]
    fn bounded_queue_shed_tokens_keeps_requests_completing() {
        let mut cfg = small_cfg();
        cfg.queue_limit_s = 0.2;
        cfg.drop_policy = DropPolicy::ShedTokens;
        let out = run_with(cfg, 50.0, 80, 1);
        assert_eq!(out.completed, 80, "shedding must not reject requests");
        assert_eq!(out.dropped, 0);
        assert!(out.shed_tokens > 0.0, "overload never shed a group");
        assert!(out.shed_tps() > 0.0, "shed volume must be reportable");
        assert_eq!(out.arrived_tokens, out.completed_tokens);
    }

    #[test]
    fn handover_none_reports_zero_handover_metrics() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 4;
        let out = run_with(cfg, 4.0, 40, 0);
        assert_eq!(out.handovers, 0);
        assert_eq!(out.borrowed_groups, 0);
        assert_eq!(out.borrowed_tokens, 0.0);
        assert_eq!(out.handover_rate(), 0.0);
    }

    #[test]
    fn rehome_on_arrival_still_drains_and_conserves() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 4;
        cfg.handover = crate::config::HandoverPolicy::RehomeOnArrival;
        let out = run_with(cfg, 6.0, 40, 1);
        assert_eq!(out.completed, 40);
        assert_eq!(out.in_flight, 0);
        assert_eq!(out.arrived_tokens, out.completed_tokens);
        // Re-homing never borrows groups.
        assert_eq!(out.borrowed_groups, 0);
        assert!(out.handover_rate() <= 1.0);
    }

    #[test]
    fn cell_load_reflects_committed_backlog() {
        let cfg = small_cfg();
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let idle = sim.cell_load(0, 0.0);
        assert_eq!(idle.backlog_s_total, 0.0);
        assert_eq!(idle.online_devices, 8);
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 50.0 }.generate(20, Benchmark::Piqa, 0);
        sim.run(&arrivals);
        // Queues drained at run end: backlog at a far-future instant is 0.
        assert_eq!(sim.cell_load(0, 1e6).backlog_s_total, 0.0);
    }

    #[test]
    fn backlog_delta_disabled_matches_epoch_only_exactly() {
        // The default (0) must leave adaptive behaviour bit-identical to
        // the pre-trigger simulator: the knob is opt-in.
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        let base = run_with(cfg.clone(), 6.0, 60, 0);
        cfg.control_backlog_delta_s = 0.0;
        let same = run_with(cfg, 6.0, 60, 0);
        assert_eq!(base.makespan_s, same.makespan_s);
        assert_eq!(base.control, same.control);
        assert_eq!(base.events, same.events);
    }

    #[test]
    fn backlog_delta_resolves_between_epochs() {
        // Epoch far beyond the run horizon: the cadence alone never
        // solves. A small drift threshold under overload must.
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        cfg.control_epoch_s = 1e6;
        let epoch_only = run_with(cfg.clone(), 20.0, 60, 1);
        assert_eq!(
            epoch_only.control_total().resolves,
            0,
            "cadence should never fire inside the horizon"
        );
        cfg.control_backlog_delta_s = 0.05;
        let triggered = run_with(cfg, 20.0, 60, 1);
        assert_eq!(triggered.completed, 60);
        assert!(
            triggered.control_total().resolves >= 1,
            "backlog drift never triggered a re-solve"
        );
    }

    #[test]
    fn backlog_delta_is_deterministic() {
        let mut cfg = small_cfg();
        cfg.control = ControlKind::Adaptive;
        cfg.control_backlog_delta_s = 0.1;
        let a = run_with(cfg.clone(), 8.0, 40, 3);
        let b = run_with(cfg, 8.0, 40, 3);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.control, b.control);
        assert_eq!(a.latency_ms.steady_values(), b.latency_ms.steady_values());
    }

    #[test]
    fn energy_off_outcome_reports_zero() {
        let out = run_with(small_cfg(), 1.0, 20, 0);
        assert_eq!(out.energy_j, 0.0);
        assert_eq!(out.joules_per_token(), 0.0);
        assert!(out.energy_cells.is_empty());
        assert_eq!(out.depleted_devices(), 0);
        assert_eq!(out.first_depletion, 0);
        assert_eq!(out.fleet_lifetime_s(), out.makespan_s);
    }

    #[test]
    fn energy_accounting_totals_are_deterministic() {
        let mut cfg = small_cfg();
        cfg.energy.compute_j_per_token = 1e-3;
        cfg.energy.tx_j_per_token = 2e-4;
        cfg.energy.rx_j_per_token = 1e-4;
        let a = run_with(cfg.clone(), 2.0, 30, 3);
        let b = run_with(cfg, 2.0, 30, 3);
        assert!(a.energy_j > 0.0, "served tokens billed no joules");
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.energy_cells, b.energy_cells);
        assert_eq!(a.energy_cells.len(), 1);
        assert!(a.joules_per_token() > 0.0);
        assert_eq!(a.depleted_devices(), 0, "no battery configured");
        assert_eq!(a.fleet_lifetime_s(), a.makespan_s);
        // Identical traffic, identical event count: billing is passive.
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn battery_depletion_crashes_and_reports_lifetime() {
        let mut cfg = small_cfg();
        cfg.cache_capacity = 2; // replicas, so crashed work can re-home
        cfg.dispatch = DispatchKind::LoadAware;
        cfg.energy.compute_j_per_token = 1.0;
        cfg.energy.battery_j = 50.0;
        let out = run_with(cfg, 4.0, 60, 1);
        assert!(out.depleted_devices() > 0, "batteries never depleted");
        assert!(out.first_depletion > 0);
        assert!(out.last_depletion >= out.first_depletion);
        assert!(out.fleet_lifetime_s() < out.makespan_s);
        assert_eq!(out.arrived, 60);
        assert_eq!(out.completed + out.dropped, 60);
        assert_eq!(out.in_flight, 0);
    }

    #[test]
    fn recharge_brings_devices_back() {
        let mut cfg = small_cfg();
        cfg.cache_capacity = 2;
        cfg.dispatch = DispatchKind::LoadAware;
        cfg.energy.compute_j_per_token = 1.0;
        cfg.energy.battery_j = 50.0;
        let dead = run_with(cfg.clone(), 4.0, 60, 1);
        cfg.energy.recharge_s = 0.05;
        let recharged = run_with(cfg, 4.0, 60, 1);
        assert!(dead.depleted_devices() > 0);
        assert!(recharged.depleted_devices() > 0);
        // Recharged devices come back online, so the fleet spends
        // strictly fewer device-seconds offline than permanent death.
        assert!(
            recharged.offline_device_s < dead.offline_device_s,
            "recharge {} !< permanent {}",
            recharged.offline_device_s,
            dead.offline_device_s
        );
        assert_eq!(recharged.completed + recharged.dropped, 60);
    }

    #[test]
    fn backlog_delta_ignored_by_static_planes() {
        let mut cfg = small_cfg();
        cfg.control_backlog_delta_s = 0.01; // StaticUniform: no epochs
        let out = run_with(cfg, 20.0, 40, 0);
        assert_eq!(out.completed, 40);
        assert_eq!(out.control_total().resolves, 0);
    }
}
