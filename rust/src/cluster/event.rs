//! Deterministic discrete-event core: virtual time + event heap.
//!
//! Events are ordered by `(time, lane, insertion sequence)`: two events
//! at the same virtual instant fire lowest lane first, and within a
//! lane in the order they were scheduled — the whole simulation is a
//! pure function of its inputs and seeds. The lane is an arbitrary
//! small integer supplied at scheduling time ([`EventQueue::schedule_at`]
//! uses lane 0); the cluster DES uses the owning *cell* index, which
//! makes the serial pop order exactly the canonical k-way merge of the
//! per-cell event streams by `(time, cell, seq)` — the order the
//! sharded engine ([`crate::cluster::shard`]) reproduces when it drains
//! its per-shard mailboxes, so sharded output can be byte-identical to
//! serial by construction rather than by luck.
//!
//! Time is integer nanoseconds ([`Nanos`]): total order, no
//! float-comparison pitfalls in the heap. The queue advances a shared
//! [`VirtualClock`] as it pops, so components holding a clone of the
//! clock (e.g. a [`crate::coordinator::batcher::DynamicBatcher`]) observe
//! simulation time for free.

use crate::util::clock::VirtualClock;
use std::cmp::Ordering;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in nanoseconds since simulation start.
pub type Nanos = u64;

/// Convert seconds (must be finite and non-negative) to [`Nanos`].
pub fn nanos_from_secs(s: f64) -> Nanos {
    assert!(s.is_finite() && s >= 0.0, "bad virtual duration {s}");
    (s * 1e9).round() as Nanos
}

/// Convert [`Nanos`] back to seconds.
pub fn secs_from_nanos(n: Nanos) -> f64 {
    n as f64 / 1e9
}

struct Scheduled<E> {
    at: Nanos,
    lane: u32,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.lane == other.lane && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at, self.lane, self.seq).cmp(&(other.at, other.lane, other.seq))
    }
}

/// The event queue driving one simulation run.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    next_seq: u64,
    clock: VirtualClock,
}

impl<E> EventQueue<E> {
    pub fn new(clock: VirtualClock) -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
            clock,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> Nanos {
        self.clock.nanos()
    }

    /// Schedule `event` at absolute virtual time `at`, on lane 0.
    // detlint: allow(visibility) lane-0 convenience wrapper delegating to the lane-aware API
    pub fn schedule_at(&mut self, at: Nanos, event: E) {
        self.schedule_at_in_lane(at, 0, event);
    }

    /// Schedule `event` at absolute virtual time `at` on `lane`.
    /// Simultaneous events fire lowest lane first (then scheduling
    /// order within a lane). Scheduling in the past is a logic error
    /// (would break causality), and it stays an error in release
    /// builds: a mis-computed delay (e.g. a handover backhaul) must
    /// abort loudly, not silently corrupt virtual time. The check runs
    /// once per *scheduled* event — off the per-event pop hot loop —
    /// so promoting it from `debug_assert!` costs nothing measurable.
    pub fn schedule_at_in_lane(&mut self, at: Nanos, lane: u32, event: E) {
        assert!(
            at >= self.now(),
            "event scheduled in the past (at {at} ns < now {} ns)",
            self.now()
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Scheduled {
            at,
            lane,
            seq,
            event,
        }));
    }

    /// Schedule `event` `delay` after the current virtual time, lane 0.
    // detlint: allow(visibility) lane-0 convenience wrapper delegating to the lane-aware API
    pub fn schedule_in(&mut self, delay: Nanos, event: E) {
        self.schedule_in_lane(delay, 0, event);
    }

    /// Schedule `event` `delay` after the current virtual time on `lane`.
    pub fn schedule_in_lane(&mut self, delay: Nanos, lane: u32, event: E) {
        let at = self.now().saturating_add(delay);
        self.schedule_at_in_lane(at, lane, event);
    }

    /// Pop the earliest event, advancing the virtual clock to its time.
    pub fn pop(&mut self) -> Option<(Nanos, E)> {
        let Reverse(s) = self.heap.pop()?;
        self.clock.advance_to_nanos(s.at);
        Some((s.at, s.event))
    }

    /// Time of the next pending event, if any.
    pub fn next_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new(VirtualClock::new());
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new(VirtualClock::new());
        q.schedule_at(5, 1);
        q.schedule_at(5, 2);
        q.schedule_at(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn ties_break_by_lane_before_insertion_order() {
        let mut q = EventQueue::new(VirtualClock::new());
        q.schedule_at_in_lane(5, 2, "lane2-first");
        q.schedule_at_in_lane(5, 0, "lane0");
        q.schedule_at_in_lane(5, 2, "lane2-second");
        q.schedule_at_in_lane(5, 1, "lane1");
        assert_eq!(q.pop().unwrap().1, "lane0");
        assert_eq!(q.pop().unwrap().1, "lane1");
        assert_eq!(q.pop().unwrap().1, "lane2-first");
        assert_eq!(q.pop().unwrap().1, "lane2-second");
    }

    #[test]
    fn time_still_dominates_lane() {
        let mut q = EventQueue::new(VirtualClock::new());
        q.schedule_at_in_lane(10, 0, "later-low-lane");
        q.schedule_at_in_lane(5, 7, "earlier-high-lane");
        assert_eq!(q.pop().unwrap().1, "earlier-high-lane");
        assert_eq!(q.pop().unwrap().1, "later-low-lane");
    }

    #[test]
    fn pop_advances_shared_clock() {
        let clock = VirtualClock::new();
        let mut q = EventQueue::new(clock.clone());
        q.schedule_at(1_000_000, ());
        assert_eq!(clock.nanos(), 0);
        q.pop();
        assert_eq!(clock.nanos(), 1_000_000);
    }

    #[test]
    fn schedule_in_is_relative() {
        let clock = VirtualClock::new();
        let mut q = EventQueue::new(clock.clone());
        q.schedule_at(100, "first");
        q.pop();
        q.schedule_in(50, "second");
        assert_eq!(q.pop(), Some((150, "second")));
    }

    #[test]
    fn unit_conversions_roundtrip() {
        assert_eq!(nanos_from_secs(1.5), 1_500_000_000);
        assert_eq!(secs_from_nanos(2_000_000_000), 2.0);
        assert_eq!(nanos_from_secs(0.0), 0);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn scheduling_in_the_past_panics_in_all_builds() {
        let mut q = EventQueue::new(VirtualClock::new());
        q.schedule_at(1_000, "future");
        q.pop(); // clock is now at 1000 ns
        q.schedule_at(500, "past"); // causality violation: must abort
    }

    #[test]
    fn empty_queue_reports_empty() {
        let mut q = EventQueue::<u8>::new(VirtualClock::new());
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert_eq!(q.next_time(), None);
        assert_eq!(q.pop(), None);
    }
}
