//! Inter-cell handover — the cluster-level dispatch layer above the
//! per-cell [`crate::cluster::dispatch::Dispatcher`].
//!
//! The paper pins each request to one BS cell for its whole lifetime, so
//! a saturated cell drops work while its neighbors idle. This module
//! moves work across cells under a [`HandoverPolicy`]:
//!
//! * **`RehomeOnArrival`** — at arrival, [`HandoverCoordinator::rehome`]
//!   homes the request on the cell with the lowest live backlog per
//!   online device ([`CellLoad::score`]) instead of blind round-robin.
//!   Ties keep the round-robin home, so an idle cluster behaves exactly
//!   like the baseline.
//! * **`BorrowExpert`** — at dispatch, when every *local* replica of a
//!   selected expert is over the queue bound or unserviceable,
//!   [`HandoverCoordinator::try_borrow`] routes that token group to the
//!   least-loaded neighbor cell's best replica. The group pays a
//!   per-token backhaul latency on each hop: the outbound transfer
//!   delays the earliest service start, and the return hop lands on the
//!   block's Eq. (11) attention barrier after the remote device
//!   finishes. The remote device's FIFO fills like any local dispatch.
//!
//! Borrows are **staged**: the remote queue instant is advanced
//! immediately (so several groups of one block borrowing the same
//! neighbor device queue behind each other), but utilization and token
//! accounting land only when the block commits. A
//! [`crate::config::DropPolicy::DropRequest`] rejection later in the
//! same block calls [`HandoverCoordinator::rollback`], which restores
//! every staged queue instant in reverse order — a dropped request
//! leaves no partial work in *any* cell.
//!
//! ## Hot-path discipline
//!
//! The coordinator owns reusable scratch (the ranked neighbor-candidate
//! list and the staged-borrow list), so a borrow attempt performs no
//! heap allocation after warm-up; with [`HandoverPolicy::None`] every
//! entry point returns immediately, leaving the simulator's behaviour
//! unchanged from the pre-handover baseline.

use super::event::{nanos_from_secs, secs_from_nanos, Nanos};
use crate::config::HandoverPolicy;
use crate::control::CellLoad;
use crate::telemetry::{Probe, TelemetryEvent};

/// The cell state the handover layer reads and (for borrows) writes.
/// Implemented by the simulator's per-cell runtime state; keeping it a
/// trait decouples the coordinator from the simulator and makes the
/// staging/rollback logic unit-testable with a mock.
pub trait HandoverCell {
    /// Devices hosting `expert` in this cell (home replica first).
    fn replicas(&self, expert: usize) -> &[usize];
    /// Instant each device's FIFO queue drains.
    fn busy_until(&self) -> &[Nanos];
    /// Overwrite one device's queue-drain instant (staging / rollback).
    fn set_busy_until(&mut self, device: usize, at: Nanos);
    /// Per-device service seconds per token under the cell's *current*
    /// bandwidth allocation.
    fn t_per_token(&self) -> &[f64];
    /// Device availability mask.
    fn online(&self) -> &[bool];
    /// Commit a borrowed group's accounting (utilization + the token
    /// counters the cell's control plane observes).
    fn commit_remote(&mut self, device: usize, expert: usize, tokens: f64, service_s: f64);
}

/// Resolve a global cell index against the simulator's split borrow
/// around the home cell: `left` holds cells `0..home`, `right` holds
/// `home + 1..`. Single home of the index arithmetic — staging,
/// rollback and commit must all route to the same cell.
pub fn cell_mut<'a, C>(home: usize, ci: usize, left: &'a mut [C], right: &'a mut [C]) -> &'a mut C {
    debug_assert_ne!(ci, home, "home cell is not reachable through the split");
    if ci < home {
        &mut left[ci]
    } else {
        &mut right[ci - home - 1]
    }
}

/// One staged cross-cell token group (tentative until the block commits).
#[derive(Debug, Clone, Copy)]
pub struct StagedBorrow {
    /// Serving (neighbor) cell.
    pub cell: usize,
    /// Serving device within that cell.
    pub device: usize,
    pub expert: usize,
    pub tokens: f64,
    /// Remote service seconds (`tokens · t_k`), excluding backhaul.
    pub service_s: f64,
    /// Remote queue instant before staging (rollback target).
    prev_busy: Nanos,
    /// Instant the tokens left the home cell (the borrow attempt).
    pub sent: Nanos,
    /// Instant remote service begins: the outbound hop has landed and
    /// the remote FIFO has drained to this group.
    pub start: Nanos,
    /// Instant the group clears the Eq. (11) barrier, including the
    /// return hop.
    pub barrier: Nanos,
}

/// Cluster-level dispatch coordinator: load-aware re-homing at arrival
/// and cross-cell expert borrowing at dispatch, with reusable scratch so
/// both sit on the DES hot path without allocating. `Clone` so the
/// sharded engine can hand each [`crate::cluster::shard`] shard its own
/// coordinator.
#[derive(Clone)]
pub struct HandoverCoordinator {
    policy: HandoverPolicy,
    backhaul_s_per_token: f64,
    /// Optional per-pair backhaul (seconds/token, `[from][to]`);
    /// validated square by [`crate::config::ClusterConfig::validate`].
    /// `None` means every hop pays the uniform scalar.
    backhaul_matrix: Option<Vec<Vec<f64>>>,
    /// Neighbor-candidate scratch: `(load score, cell)` pairs, ranked
    /// ascending per borrow attempt. Reused — never reallocated.
    order: Vec<(f64, usize)>,
    /// Cross-cell groups staged by the current block.
    staged: Vec<StagedBorrow>,
    /// Live fault multiplier on backhaul latency, driven by the fault
    /// plan's backhaul events: `1.0` nominal, `> 1.0` jitter/degradation,
    /// `0.0` full outage (borrowing disabled). Cluster-wide by design —
    /// the backhaul is one transport network.
    fault_mult: f64,
}

impl HandoverCoordinator {
    pub fn new(policy: HandoverPolicy, backhaul_s_per_token: f64) -> Self {
        Self {
            policy,
            backhaul_s_per_token,
            backhaul_matrix: None,
            order: Vec::new(),
            staged: Vec::new(),
            fault_mult: 1.0,
        }
    }

    /// Attach (or clear) a per-cell-pair backhaul matrix.
    pub fn with_backhaul_matrix(mut self, matrix: Option<Vec<Vec<f64>>>) -> Self {
        self.backhaul_matrix = matrix;
        self
    }

    pub fn policy(&self) -> HandoverPolicy {
        self.policy
    }

    /// One-way inter-cell transfer seconds per token (uniform default).
    pub fn backhaul_s_per_token(&self) -> f64 {
        self.backhaul_s_per_token
    }

    /// One-way transfer seconds per token for the directed hop
    /// `from → to`: the matrix entry when configured, else the scalar —
    /// times the live fault multiplier (`* 1.0` bit-exact when no
    /// backhaul fault is in progress).
    pub fn backhaul_pair(&self, from: usize, to: usize) -> f64 {
        let base = match &self.backhaul_matrix {
            Some(m) => m[from][to],
            None => self.backhaul_s_per_token,
        };
        base * self.fault_mult
    }

    /// Set the backhaul fault multiplier (`0.0` = outage: `try_borrow`
    /// refuses rather than promising free transfers).
    pub fn set_fault_mult(&mut self, mult: f64) {
        self.fault_mult = mult;
    }

    /// Drop any scratch state (simulator reset). Stats are accumulated
    /// by the run loop, so a reset coordinator is indistinguishable from
    /// a fresh one.
    pub fn reset(&mut self) {
        self.order.clear();
        self.staged.clear();
        self.fault_mult = 1.0;
    }

    /// Groups staged by the current block (empty unless `BorrowExpert`
    /// found local dispatch impossible this block).
    pub fn staged(&self) -> &[StagedBorrow] {
        &self.staged
    }

    pub fn has_staged(&self) -> bool {
        !self.staged.is_empty()
    }

    /// Forget the staged groups after the block committed them.
    pub fn clear_staged(&mut self) {
        self.staged.clear();
    }

    /// Home cell for a new arrival: the round-robin home unless the
    /// policy is `RehomeOnArrival`, in which case the cell with the
    /// lowest live [`CellLoad::score`] wins (ties — including the
    /// all-idle case — keep the round-robin home, so light traffic still
    /// spreads across cells).
    pub fn rehome<C: HandoverCell>(&self, rr_home: usize, now: Nanos, cells: &[C]) -> usize {
        if self.policy != HandoverPolicy::RehomeOnArrival || cells.len() <= 1 {
            return rr_home;
        }
        let score = |c: &C| CellLoad::observe(now, c.busy_until(), c.online()).score();
        let home_score = score(&cells[rr_home]);
        let mut best = (home_score, rr_home);
        for (ci, c) in cells.iter().enumerate() {
            if ci == rr_home {
                continue;
            }
            let s = score(c);
            // Strict < : the round-robin home keeps ties, and among
            // equally-loaded strangers the lowest index wins.
            if s < best.0 {
                best = (s, ci);
            }
        }
        best.1
    }

    /// Try to serve `tokens` tokens of `expert` on a neighbor cell
    /// because every local replica is over the queue bound or
    /// unserviceable. Neighbor cells are ranked by live load score;
    /// within the least-loaded cell that has a serviceable, under-bound
    /// replica, the replica with the earliest predicted completion wins
    /// (ties to the lower device index). On success the remote queue is
    /// staged forward and the group's barrier instant (including the
    /// return backhaul hop) is returned.
    ///
    /// `left`/`right` are the cells below/above the home cell index —
    /// the simulator's split borrow around its own (mutably held) home
    /// cell.
    #[allow(clippy::too_many_arguments)]
    pub fn try_borrow<C: HandoverCell>(
        &mut self,
        home: usize,
        expert: usize,
        tokens: f64,
        now: Nanos,
        queue_limit_s: f64,
        left: &mut [C],
        right: &mut [C],
    ) -> Option<Nanos> {
        if self.policy != HandoverPolicy::BorrowExpert {
            return None;
        }
        if self.fault_mult == 0.0 {
            return None; // backhaul outage: no inter-cell transfers
        }
        if left.is_empty() && right.is_empty() {
            return None;
        }
        // Rank neighbors by live load, cheapest first. The load reads
        // the staged queue instants too, so one block cannot dogpile a
        // neighbor that only *looked* idle before its own borrows.
        self.order.clear();
        for (ci, c) in left.iter().enumerate() {
            self.order.push((CellLoad::observe(now, c.busy_until(), c.online()).score(), ci));
        }
        for (j, c) in right.iter().enumerate() {
            let ci = home + 1 + j;
            self.order.push((CellLoad::observe(now, c.busy_until(), c.online()).score(), ci));
        }
        self.order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

        for &(score, ci) in &self.order {
            if !score.is_finite() {
                break; // dead cells sort last; nothing serviceable beyond
            }
            // Directed hop costs: the outbound transfer pays
            // `home → ci`, the barrier return pays `ci → home` (they
            // differ under an asymmetric backhaul matrix).
            let backhaul = nanos_from_secs(tokens * self.backhaul_pair(home, ci));
            let backhaul_return = nanos_from_secs(tokens * self.backhaul_pair(ci, home));
            let cell = cell_mut(home, ci, &mut *left, &mut *right);
            let t = cell.t_per_token();
            let online = cell.online();
            let busy = cell.busy_until();
            let mut best: Option<(Nanos, usize)> = None;
            for &k in cell.replicas(expert) {
                if !online[k] || !t[k].is_finite() {
                    continue;
                }
                // The borrow target must itself be under the queue
                // bound — handover relieves overload, it must not
                // launder it into a neighbor that is drowning too. The
                // bound measures *committed* backlog only, mirroring the
                // local admission rule: the block's own staged borrows
                // (whose first stage recorded the committed instant in
                // `prev_busy`) are barrier work, not overload, so a
                // multi-group block cannot drop itself on an idle
                // neighbor.
                if queue_limit_s > 0.0 {
                    let committed = self
                        .staged
                        .iter()
                        .find(|s| s.cell == ci && s.device == k)
                        .map(|s| s.prev_busy)
                        .unwrap_or(busy[k]);
                    if secs_from_nanos(committed.saturating_sub(now)) > queue_limit_s {
                        continue;
                    }
                }
                // Outbound hop: tokens reach the neighbor `backhaul`
                // after `now`; service starts once both the transfer and
                // the remote FIFO allow. FIFO reservation semantics: the
                // remote queue instant advances to the group's finish,
                // including any idle gap waiting for the transfer to
                // land — once enqueued, later work queues behind it.
                let start = busy[k].max(now.saturating_add(backhaul));
                let done = start.saturating_add(nanos_from_secs(tokens * t[k]));
                let better = match best {
                    None => true,
                    Some((bd, bk)) => done < bd || (done == bd && k < bk),
                };
                if better {
                    best = Some((done, k));
                }
            }
            if let Some((done, k)) = best {
                let service_s = tokens * cell.t_per_token()[k];
                let prev_busy = cell.busy_until()[k];
                let start = prev_busy.max(now.saturating_add(backhaul));
                cell.set_busy_until(k, done);
                let barrier = done.saturating_add(backhaul_return);
                self.staged.push(StagedBorrow {
                    cell: ci,
                    device: k,
                    expert,
                    tokens,
                    service_s,
                    prev_busy,
                    sent: now,
                    start,
                    barrier,
                });
                return Some(barrier);
            }
        }
        None
    }

    /// Undo every staged borrow (the block was rejected by
    /// `DropRequest`): restore the remote queue instants in reverse
    /// staging order, then forget the stages.
    pub fn rollback<C: HandoverCell>(&mut self, home: usize, left: &mut [C], right: &mut [C]) {
        for s in self.staged.iter().rev() {
            cell_mut(home, s.cell, &mut *left, &mut *right).set_busy_until(s.device, s.prev_busy);
        }
        self.staged.clear();
    }

    /// [`Self::try_borrow`] plus a [`TelemetryEvent::BorrowStaged`]
    /// emitted for a successful stage. With
    /// [`crate::telemetry::NullProbe`] this monomorphizes to exactly
    /// `try_borrow`.
    #[allow(clippy::too_many_arguments)]
    pub fn try_borrow_probed<C: HandoverCell, P: Probe>(
        &mut self,
        probe: &mut P,
        req: usize,
        home: usize,
        expert: usize,
        tokens: f64,
        now: Nanos,
        queue_limit_s: f64,
        left: &mut [C],
        right: &mut [C],
    ) -> Option<Nanos> {
        let got = self.try_borrow(home, expert, tokens, now, queue_limit_s, left, right);
        if got.is_some() {
            // try_borrow pushed exactly one stage on success.
            // detlint: allow(panic) Some(got) implies try_borrow staged a group; unreachable
            let s = self.staged.last().expect("successful borrow stages a group");
            probe.on_event(&TelemetryEvent::BorrowStaged {
                req,
                home,
                cell: s.cell,
                device: s.device,
                expert: s.expert,
                tokens: s.tokens,
                t: now,
                barrier: s.barrier,
            });
        }
        got
    }

    /// [`Self::rollback`] plus a [`TelemetryEvent::BorrowRolledBack`]
    /// when any stages were undone.
    pub fn rollback_probed<C: HandoverCell, P: Probe>(
        &mut self,
        probe: &mut P,
        req: usize,
        home: usize,
        now: Nanos,
        left: &mut [C],
        right: &mut [C],
    ) {
        if !self.staged.is_empty() {
            probe.on_event(&TelemetryEvent::BorrowRolledBack {
                req,
                home,
                staged: self.staged.len(),
                t: now,
            });
        }
        self.rollback(home, left, right);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal mock cell: every expert is hosted on every device.
    struct MockCell {
        busy: Vec<Nanos>,
        t: Vec<f64>,
        online: Vec<bool>,
        all: Vec<usize>,
        committed: Vec<(usize, usize, f64)>,
    }

    impl MockCell {
        fn new(busy: Vec<Nanos>, t: Vec<f64>) -> Self {
            let n = busy.len();
            Self {
                busy,
                t,
                online: vec![true; n],
                all: (0..n).collect(),
                committed: Vec::new(),
            }
        }
    }

    impl HandoverCell for MockCell {
        fn replicas(&self, _expert: usize) -> &[usize] {
            &self.all
        }
        fn busy_until(&self) -> &[Nanos] {
            &self.busy
        }
        fn set_busy_until(&mut self, device: usize, at: Nanos) {
            self.busy[device] = at;
        }
        fn t_per_token(&self) -> &[f64] {
            &self.t
        }
        fn online(&self) -> &[bool] {
            &self.online
        }
        fn commit_remote(&mut self, device: usize, expert: usize, tokens: f64, _service_s: f64) {
            self.committed.push((device, expert, tokens));
        }
    }

    #[test]
    fn none_policy_never_borrows_or_rehomes() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::None, 1e-4);
        let mut left = [MockCell::new(vec![0; 2], vec![1e-3; 2])];
        let mut right: [MockCell; 0] = [];
        assert_eq!(h.try_borrow(1, 0, 10.0, 0, 0.0, &mut left, &mut right), None);
        assert!(!h.has_staged());
        let cells = [
            MockCell::new(vec![5_000_000_000; 2], vec![1e-3; 2]),
            MockCell::new(vec![0; 2], vec![1e-3; 2]),
        ];
        assert_eq!(h.rehome(0, 0, &cells), 0, "None keeps round-robin home");
    }

    #[test]
    fn rehome_picks_least_loaded_and_keeps_home_on_ties() {
        let h = HandoverCoordinator::new(HandoverPolicy::RehomeOnArrival, 1e-4);
        // Cell 0 backlogged, cell 1 idle: arrival homed on 0 moves to 1.
        let cells = [
            MockCell::new(vec![5_000_000_000; 2], vec![1e-3; 2]),
            MockCell::new(vec![0; 2], vec![1e-3; 2]),
        ];
        assert_eq!(h.rehome(0, 0, &cells), 1);
        // Arrival homed on the idle cell stays put.
        assert_eq!(h.rehome(1, 0, &cells), 1);
        // All idle: round-robin home wins the tie, whichever it is.
        let idle = [
            MockCell::new(vec![0; 2], vec![1e-3; 2]),
            MockCell::new(vec![0; 2], vec![1e-3; 2]),
        ];
        assert_eq!(h.rehome(0, 0, &idle), 0);
        assert_eq!(h.rehome(1, 0, &idle), 1);
    }

    #[test]
    fn borrow_targets_least_loaded_neighbor_and_pays_backhaul_both_ways() {
        // 1 ms/token backhaul, 10 tokens => 10 ms per hop.
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 1e-3);
        // Home is cell 1. Cell 0 is backlogged, cell 2 idle with
        // 1 ms/token service.
        let mut left = [MockCell::new(vec![8_000_000_000; 2], vec![1e-3; 2])];
        let mut right = [MockCell::new(vec![0; 2], vec![1e-3; 2])];
        let barrier = h
            .try_borrow(1, 3, 10.0, 0, 0.0, &mut left, &mut right)
            .expect("idle neighbor must accept the borrow");
        // out hop 10 ms + service 10 ms + return hop 10 ms = 30 ms.
        assert_eq!(barrier, 30_000_000);
        let s = h.staged()[0];
        assert_eq!((s.cell, s.device, s.expert), (2, 0, 3));
        // The remote FIFO advanced to the device-done instant (20 ms),
        // not the barrier.
        assert_eq!(right[0].busy[0], 20_000_000);
        // Untouched neighbor: the backlogged cell keeps its queue.
        assert_eq!(left[0].busy[0], 8_000_000_000);
    }

    #[test]
    fn borrow_pays_directed_per_pair_backhaul() {
        // Asymmetric matrix: home(0) → neighbor(1) costs 1 ms/token,
        // the return hop 2 ms/token. 10 tokens at 1 ms/token service:
        // out 10 ms + service 10 ms + return 20 ms = 40 ms barrier.
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 1e-3)
            .with_backhaul_matrix(Some(vec![vec![0.0, 1e-3], vec![2e-3, 0.0]]));
        assert_eq!(h.backhaul_pair(0, 1), 1e-3);
        assert_eq!(h.backhaul_pair(1, 0), 2e-3);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        let barrier = h.try_borrow(0, 0, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        assert_eq!(barrier, 40_000_000);
        let s = h.staged()[0];
        assert_eq!(s.start, 10_000_000, "outbound hop uses the home→cell entry");
        // Remote FIFO advances to device-done (20 ms), not the barrier.
        assert_eq!(right[0].busy[0], 20_000_000);
        // Without a matrix the same coordinator falls back to the scalar.
        let h2 = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 5e-4);
        assert_eq!(h2.backhaul_pair(0, 1), 5e-4);
        assert_eq!(h2.backhaul_pair(1, 0), 5e-4);
    }

    #[test]
    fn borrow_respects_remote_queue_bound() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        // Only neighbor has 2 s of backlog on every device.
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![2_000_000_000; 2], vec![1e-3; 2])];
        assert_eq!(
            h.try_borrow(0, 0, 5.0, 0, 0.5, &mut left, &mut right),
            None,
            "a drowning neighbor must not accept borrowed work"
        );
        // With a generous bound the same borrow succeeds.
        assert!(h.try_borrow(0, 0, 5.0, 0, 5.0, &mut left, &mut right).is_some());
    }

    #[test]
    fn staged_borrows_queue_behind_each_other_and_rollback_restores() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        // One neighbor, one device, 1 ms/token.
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        let b1 = h.try_borrow(0, 0, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        let b2 = h.try_borrow(0, 1, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        // Second group queues behind the first on the same device.
        assert_eq!(b1, 10_000_000);
        assert_eq!(b2, 20_000_000);
        assert_eq!(h.staged().len(), 2);
        // DropRequest fires: rollback must restore the original queue.
        h.rollback(0, &mut left, &mut right);
        assert_eq!(right[0].busy[0], 0);
        assert!(!h.has_staged());
    }

    #[test]
    fn own_staged_borrows_do_not_trip_the_remote_bound() {
        // The remote queue bound measures committed backlog only,
        // mirroring the local admission rule: a multi-group block on an
        // idle neighbor is barrier work, not overload. The first borrow
        // stages 0.6 s of work — beyond the 0.5 s bound — yet the
        // second group of the same block must still be admitted, and it
        // queues behind the first.
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        let b1 = h.try_borrow(0, 0, 600.0, 0, 0.5, &mut left, &mut right).unwrap();
        assert_eq!(b1, 600_000_000);
        let b2 = h
            .try_borrow(0, 1, 100.0, 0, 0.5, &mut left, &mut right)
            .expect("own staged work must not count against the bound");
        assert_eq!(b2, 700_000_000);
        // Committed (non-staged) backlog beyond the bound still refuses.
        h.clear_staged();
        assert_eq!(h.try_borrow(0, 2, 10.0, 0, 0.5, &mut left, &mut right), None);
    }

    #[test]
    fn borrow_skips_offline_and_unserviceable_replicas() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0, 0, 0], vec![f64::INFINITY, 1e-3, 1e-4])];
        right[0].online[2] = false;
        // Device 0 starved of spectrum, device 2 offline: device 1 wins.
        let barrier = h.try_borrow(0, 0, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        assert_eq!(h.staged()[0].device, 1);
        assert_eq!(barrier, 10_000_000);
        // Everything gone: no borrow.
        right[0].online[1] = false;
        h.clear_staged();
        assert_eq!(h.try_borrow(0, 0, 10.0, 0, 0.0, &mut left, &mut right), None);
    }

    #[test]
    fn commit_hands_accounting_to_the_serving_cell() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        h.try_borrow(0, 4, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        // The block was admitted: the simulator walks the staged groups
        // and commits each to its serving cell.
        for s in h.staged() {
            right[s.cell - 1].commit_remote(s.device, s.expert, s.tokens, s.service_s);
        }
        h.clear_staged();
        assert_eq!(right[0].committed, vec![(0, 4, 10.0)]);
        assert!(!h.has_staged());
    }

    #[test]
    fn staged_borrow_records_send_and_start_instants() {
        // 1 ms/token backhaul, 10 tokens => the outbound hop lands at
        // 10 ms; the idle remote device starts right then.
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 1e-3);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        h.try_borrow(0, 0, 10.0, 5_000, 0.0, &mut left, &mut right).unwrap();
        let s = h.staged()[0];
        assert_eq!(s.sent, 5_000);
        assert_eq!(s.start, 10_005_000);
        assert_eq!(s.barrier, 30_005_000);
    }

    #[test]
    fn probed_wrappers_emit_stage_and_rollback_events() {
        use crate::telemetry::{Probe, TelemetryEvent};
        #[derive(Default)]
        struct Collect(Vec<TelemetryEvent>);
        impl Probe for Collect {
            fn on_event(&mut self, e: &TelemetryEvent) {
                self.0.push(*e);
            }
        }
        let mut probe = Collect::default();
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        h.try_borrow_probed(&mut probe, 7, 0, 3, 10.0, 0, 0.0, &mut left, &mut right)
            .unwrap();
        h.rollback_probed(&mut probe, 7, 0, 42, &mut left, &mut right);
        assert_eq!(right[0].busy[0], 0, "rollback must still restore the queue");
        assert!(matches!(
            probe.0[0],
            TelemetryEvent::BorrowStaged { req: 7, cell: 1, expert: 3, .. }
        ));
        assert!(matches!(
            probe.0[1],
            TelemetryEvent::BorrowRolledBack { req: 7, staged: 1, t: 42, .. }
        ));
        // An empty rollback emits nothing.
        let n = probe.0.len();
        h.rollback_probed(&mut probe, 7, 0, 43, &mut left, &mut right);
        assert_eq!(probe.0.len(), n);
    }

    #[test]
    fn reset_clears_scratch() {
        let mut h = HandoverCoordinator::new(HandoverPolicy::BorrowExpert, 0.0);
        let mut left: [MockCell; 0] = [];
        let mut right = [MockCell::new(vec![0], vec![1e-3])];
        h.try_borrow(0, 0, 10.0, 0, 0.0, &mut left, &mut right).unwrap();
        assert!(h.has_staged());
        h.reset();
        assert!(!h.has_staged());
        assert_eq!(h.policy(), HandoverPolicy::BorrowExpert);
    }
}
