//! Expert replication and placement under per-device cache capacity.
//!
//! The paper's §V setup pins expert `k` to device `k`. At serving scale
//! that makes the slowest / farthest device a permanent straggler: every
//! block's attention waits on it (Eq. (11)). Devices can typically cache
//! more than one expert's weights, so the cluster lets each expert live
//! on several devices — bounded by a per-device cache capacity — and the
//! dispatcher picks a replica per block ([`crate::cluster::dispatch`]).
//!
//! [`Placement::optimize`] is a greedy balancer: starting from the
//! round-robin home placement, it repeatedly replicates the heaviest
//! expert hosted on the projected-slowest device onto the device whose
//! projected completion time it improves most, until cache slots run out
//! or no strict improvement remains. Projected load assumes each
//! expert's tokens split evenly across its replicas — the dispatcher's
//! steady-state behaviour under balanced queues.

use anyhow::Result;

/// An expert→devices map for one cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// `replicas[e]` — devices hosting expert `e` (home replica first).
    replicas: Vec<Vec<usize>>,
    n_devices: usize,
    cache_capacity: usize,
}

impl Placement {
    /// Round-robin home placement, no replication: expert `e` on device
    /// `e % n_devices`. Requires enough total cache slots.
    pub fn home(n_experts: usize, n_devices: usize, cache_capacity: usize) -> Self {
        assert!(n_devices > 0 && cache_capacity > 0);
        assert!(
            n_experts <= n_devices * cache_capacity,
            "{n_experts} experts exceed {n_devices}x{cache_capacity} cache slots"
        );
        Self {
            replicas: (0..n_experts).map(|e| vec![e % n_devices]).collect(),
            n_devices,
            cache_capacity,
        }
    }

    /// Greedy replication on top of the home placement.
    ///
    /// * `t_per_token[k]` — per-token service seconds on device `k`
    ///   (comm + comp under the cell's uniform bandwidth share, Eq. (8));
    /// * `expected_load[e]` — relative token mass routed to expert `e`
    ///   (uniform when unknown).
    pub fn optimize(
        n_experts: usize,
        t_per_token: &[f64],
        expected_load: &[f64],
        cache_capacity: usize,
    ) -> Self {
        let n_devices = t_per_token.len();
        assert_eq!(expected_load.len(), n_experts, "load arity mismatch");
        let mut p = Self::home(n_experts, n_devices, cache_capacity);
        if cache_capacity == 1 {
            return p; // no free slots beyond homes
        }

        // Projected completion seconds per device if each expert's load
        // splits evenly across its current replicas. Written into a
        // reused buffer — the adaptive control plane runs this optimizer
        // on every epoch tick, so the greedy loop must not churn the
        // heap (no per-step placement clones either: a rejected trial
        // replica is popped back off).
        let project_into = |p: &Placement, out: &mut [f64]| {
            out.iter_mut().for_each(|x| *x = 0.0);
            for (e, reps) in p.replicas.iter().enumerate() {
                let share = expected_load[e] / reps.len() as f64;
                for &k in reps {
                    out[k] += share * t_per_token[k];
                }
            }
        };

        let mut proj = vec![0.0f64; n_devices];
        let mut proj_new = vec![0.0f64; n_devices];
        let mut hosted = p.experts_per_device();
        let free_slots = n_devices * cache_capacity - n_experts;
        for _ in 0..free_slots {
            project_into(&p, &mut proj);
            // total_cmp matches partial_cmp on the finite projections
            // and cannot panic; `proj` is non-empty (n_devices >= 1).
            let Some(worst) = proj
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(k, _)| k)
            else {
                break;
            };
            // Heaviest per-replica expert on the worst device.
            let Some(expert) = (0..n_experts)
                .filter(|&e| p.replicas[e].contains(&worst))
                .max_by(|&a, &b| {
                    let la = expected_load[a] / p.replicas[a].len() as f64;
                    let lb = expected_load[b] / p.replicas[b].len() as f64;
                    la.total_cmp(&lb)
                })
            else {
                break; // worst device hosts nothing (all load elsewhere)
            };
            // Best target: free cache slot, not already a replica, and
            // the lowest projected completion after taking its share.
            let new_reps = (p.replicas[expert].len() + 1) as f64;
            let target = (0..n_devices)
                .filter(|&k| hosted[k] < cache_capacity && !p.replicas[expert].contains(&k))
                .min_by(|&a, &b| {
                    let ca = proj[a] + expected_load[expert] / new_reps * t_per_token[a];
                    let cb = proj[b] + expected_load[expert] / new_reps * t_per_token[b];
                    ca.total_cmp(&cb)
                });
            let Some(target) = target else { break };
            // Only accept strict improvement of the bottleneck.
            p.replicas[expert].push(target);
            project_into(&p, &mut proj_new);
            let new_max = proj_new.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let old_max = proj.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if new_max >= old_max {
                p.replicas[expert].pop();
                break;
            }
            hosted[target] += 1;
        }
        p
    }

    pub fn n_experts(&self) -> usize {
        self.replicas.len()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn cache_capacity(&self) -> usize {
        self.cache_capacity
    }

    /// Devices hosting expert `e` (home first).
    pub fn replicas(&self, e: usize) -> &[usize] {
        &self.replicas[e]
    }

    /// Experts cached per device.
    pub fn experts_per_device(&self) -> Vec<usize> {
        let mut n = vec![0usize; self.n_devices];
        for reps in &self.replicas {
            for &k in reps {
                n[k] += 1;
            }
        }
        n
    }

    /// Check every invariant: each expert hosted at least once, device
    /// indices valid, no duplicate replicas, cache capacity respected.
    pub fn validate(&self) -> Result<()> {
        for (e, reps) in self.replicas.iter().enumerate() {
            anyhow::ensure!(!reps.is_empty(), "expert {e} has no replica");
            for &k in reps {
                anyhow::ensure!(k < self.n_devices, "expert {e}: bad device {k}");
            }
            let mut sorted = reps.clone();
            sorted.sort_unstable();
            sorted.dedup();
            anyhow::ensure!(
                sorted.len() == reps.len(),
                "expert {e}: duplicate replicas {reps:?}"
            );
        }
        for (k, &n) in self.experts_per_device().iter().enumerate() {
            anyhow::ensure!(
                n <= self.cache_capacity,
                "device {k} hosts {n} experts, cache is {}",
                self.cache_capacity
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn home_is_identity_when_square() {
        let p = Placement::home(8, 8, 1);
        p.validate().unwrap();
        for e in 0..8 {
            assert_eq!(p.replicas(e), &[e]);
        }
        assert_eq!(p.experts_per_device(), vec![1; 8]);
    }

    #[test]
    fn home_wraps_when_more_experts_than_devices() {
        let p = Placement::home(8, 4, 2);
        p.validate().unwrap();
        assert_eq!(p.replicas(5), &[1]);
        assert_eq!(p.experts_per_device(), vec![2; 4]);
    }

    #[test]
    #[should_panic(expected = "cache slots")]
    fn home_rejects_infeasible() {
        let _ = Placement::home(9, 4, 2);
    }

    #[test]
    fn optimize_with_capacity_one_is_home() {
        let t = vec![1e-3; 8];
        let load = vec![1.0; 8];
        assert_eq!(
            Placement::optimize(8, &t, &load, 1),
            Placement::home(8, 8, 1)
        );
    }

    #[test]
    fn optimize_replicates_slow_homes_onto_fast_devices() {
        // Device 3 is 20x slower: its home expert must gain a replica on
        // some faster device.
        let t = vec![1e-4, 1e-4, 1e-4, 2e-3];
        let load = vec![1.0; 4];
        let p = Placement::optimize(4, &t, &load, 2);
        p.validate().unwrap();
        assert!(
            p.replicas(3).len() >= 2,
            "slow-homed expert not replicated: {:?}",
            p.replicas(3)
        );
        assert!(p.replicas(3).iter().any(|&k| k != 3));
    }

    #[test]
    fn optimize_respects_capacity_on_heterogeneous_fleet() {
        let t = vec![5e-5, 1e-4, 3e-4, 1e-3, 2e-3, 5e-3];
        let load = vec![3.0, 1.0, 1.0, 1.0, 2.0, 1.0];
        for cap in 1..=4 {
            let p = Placement::optimize(6, &t, &load, cap);
            p.validate().unwrap();
            assert!(p.experts_per_device().iter().all(|&n| n <= cap));
        }
    }

    #[test]
    fn optimize_reduces_projected_bottleneck() {
        let t = vec![1e-4, 1e-4, 1e-3, 5e-3];
        let load = vec![1.0; 4];
        let proj = |p: &Placement| -> f64 {
            let mut dev = vec![0.0f64; 4];
            for e in 0..4 {
                let share = 1.0 / p.replicas(e).len() as f64;
                for &k in p.replicas(e) {
                    dev[k] += share * t[k];
                }
            }
            dev.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        };
        let home = Placement::home(4, 4, 3);
        let opt = Placement::optimize(4, &t, &load, 3);
        assert!(
            proj(&opt) < proj(&home),
            "optimized {} vs home {}",
            proj(&opt),
            proj(&home)
        );
    }
}
