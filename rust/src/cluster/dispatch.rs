//! Replica dispatch: which device serves an expert's token group.
//!
//! Once the selection policy has fixed `q_e` tokens for expert `e` in a
//! block, the BS must pick one of the expert's replicas. The load-aware
//! dispatcher minimises the *predicted completion instant* — queue
//! backlog plus the Eq. (9)–(10) service time `q_e · t_k` — which is the
//! per-expert analogue of minimising the block's attention waiting
//! latency (Eq. (11)) given current queue state. The static dispatcher
//! always uses the home replica, reproducing the paper's fixed
//! expert-per-device assignment as a baseline.
//!
//! The dispatcher is stateless: `t_per_token` must be the *current*
//! service-time vector read from the cell's
//! [`crate::control::ControlPlane`] at dispatch time — never a cached
//! copy — so a control-epoch re-allocation immediately changes which
//! replica wins. Replicas whose service time is non-finite (offline, or
//! starved of spectrum by a re-solve) are never chosen.

use super::event::{nanos_from_secs, secs_from_nanos, Nanos};
use crate::config::DispatchKind;
use crate::telemetry::{Probe, TelemetryEvent};

/// Energy view of a cell's devices at dispatch time, borrowed from
/// [`crate::cluster::energy::CellEnergy`]. With `weight == 0.0` (the
/// [`Self::OFF`] constant) every chooser takes the exact pre-energy
/// integer-scored path — bit-equal to the engine before the energy
/// subsystem existed. With `weight > 0.0` the load-aware chooser ranks
/// replicas by `predicted finish seconds + weight · tokens · cost_j[k] ·
/// (2 - frac[k])`: the energy term is the marginal joules of placing the
/// group on device `k`, inflated up to 2x as its battery drains so the
/// dispatcher spreads load away from nearly-dead devices.
#[derive(Debug, Clone, Copy)]
pub struct EnergyScore<'a> {
    /// Weight of the energy term (0 = pure latency).
    pub weight: f64,
    /// Marginal joules per token on device `k` (compute + radio at the
    /// current bandwidth split).
    pub cost_j: &'a [f64],
    /// Remaining battery fraction of device `k` in `[0, 1]`
    /// (1.0 for mains-powered devices).
    pub frac: &'a [f64],
}

impl EnergyScore<'_> {
    /// The disabled score: selects the pre-energy dispatch path.
    pub const OFF: EnergyScore<'static> = EnergyScore {
        weight: 0.0,
        cost_j: &[],
        frac: &[],
    };
}

/// Replica chooser. Stateless: queue state is passed per call so the
/// simulator remains the single owner of device state.
#[derive(Debug, Clone, Copy)]
pub struct Dispatcher {
    pub kind: DispatchKind,
}

impl Dispatcher {
    pub fn new(kind: DispatchKind) -> Self {
        Self { kind }
    }

    /// Pick the serving device for `tokens` tokens of one expert.
    ///
    /// * `replicas` — candidate devices (home first);
    /// * `busy_until[k]` — instant device `k`'s FIFO queue drains;
    /// * `t_per_token[k]` — service seconds per token on device `k`;
    /// * `online[k]` — device availability.
    ///
    /// Returns `None` when no replica is serviceable — online with a
    /// finite service time (a control-plane re-solve can starve an
    /// online device of spectrum entirely).
    ///
    /// `energy` selects the scoring: [`EnergyScore::OFF`] is the exact
    /// integer-scored pre-energy path; a positive weight switches the
    /// load-aware arm to the weighted latency+energy objective (static
    /// dispatch ignores it — the home-replica baseline stays a baseline).
    ///
    /// Runs once per selected expert per block on the DES hot path:
    /// allocation-free by construction (pure reduction over borrowed
    /// slices), and inlined into the dispatch loop.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn choose(
        &self,
        replicas: &[usize],
        tokens: f64,
        now: Nanos,
        busy_until: &[Nanos],
        t_per_token: &[f64],
        online: &[bool],
        energy: EnergyScore,
    ) -> Option<usize> {
        match self.kind {
            // First serviceable replica in replica order — the home
            // replica whenever it is up and has finite service time.
            DispatchKind::Static => replicas
                .iter()
                .copied()
                .find(|&k| online[k] && t_per_token[k].is_finite()),
            DispatchKind::LoadAware => {
                if energy.weight > 0.0 {
                    return self.choose_energy(
                        replicas,
                        tokens,
                        now,
                        busy_until,
                        t_per_token,
                        online,
                        energy,
                        usize::MAX,
                    );
                }
                let mut best: Option<(Nanos, usize)> = None;
                for k in replicas.iter().copied().filter(|&k| online[k]) {
                    if !t_per_token[k].is_finite() {
                        continue;
                    }
                    let start = busy_until[k].max(now);
                    let finish =
                        start.saturating_add(nanos_from_secs(tokens * t_per_token[k]));
                    // Strict < keeps ties on the lower device index
                    // (candidates iterate in replica order, home first).
                    let better = match best {
                        None => true,
                        Some((bf, bk)) => finish < bf || (finish == bf && k < bk),
                    };
                    if better {
                        best = Some((finish, k));
                    }
                }
                best.map(|(_, k)| k)
            }
        }
    }

    /// The weighted latency+energy objective: minimise
    /// `finish_seconds + weight · tokens · cost_j[k] · (2 - frac[k])`
    /// over serviceable replicas, excluding `exclude` (`usize::MAX` =
    /// no exclusion). Pure f64 reduction over borrowed slices in replica
    /// order with strict-< and tie-to-lower-index — deterministic and
    /// allocation-free like the integer path it replaces.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn choose_energy(
        &self,
        replicas: &[usize],
        tokens: f64,
        now: Nanos,
        busy_until: &[Nanos],
        t_per_token: &[f64],
        online: &[bool],
        energy: EnergyScore,
        exclude: usize,
    ) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for k in replicas
            .iter()
            .copied()
            .filter(|&k| k != exclude && online[k])
        {
            if !t_per_token[k].is_finite() {
                continue;
            }
            let start = busy_until[k].max(now);
            let finish = start.saturating_add(nanos_from_secs(tokens * t_per_token[k]));
            let score = secs_from_nanos(finish)
                + energy.weight * tokens * energy.cost_j[k] * (2.0 - energy.frac[k]);
            let better = match best {
                None => true,
                Some((bs, bk)) => score < bs || (score == bs && k < bk),
            };
            if better {
                best = Some((score, k));
            }
        }
        best.map(|(_, k)| k)
    }

    /// [`Self::choose`] restricted to replicas other than `exclude` —
    /// the hedged-dispatch second pick. Predictions use the base
    /// `t_per_token` like every other dispatch: the dispatcher does not
    /// see live fault multipliers, which is exactly what makes a hidden
    /// straggler worth hedging against.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn choose_excluding(
        &self,
        replicas: &[usize],
        tokens: f64,
        now: Nanos,
        busy_until: &[Nanos],
        t_per_token: &[f64],
        online: &[bool],
        exclude: usize,
        energy: EnergyScore,
    ) -> Option<usize> {
        match self.kind {
            DispatchKind::Static => replicas
                .iter()
                .copied()
                .find(|&k| k != exclude && online[k] && t_per_token[k].is_finite()),
            DispatchKind::LoadAware => {
                if energy.weight > 0.0 {
                    return self.choose_energy(
                        replicas,
                        tokens,
                        now,
                        busy_until,
                        t_per_token,
                        online,
                        energy,
                        exclude,
                    );
                }
                let mut best: Option<(Nanos, usize)> = None;
                for k in replicas
                    .iter()
                    .copied()
                    .filter(|&k| k != exclude && online[k])
                {
                    if !t_per_token[k].is_finite() {
                        continue;
                    }
                    let start = busy_until[k].max(now);
                    let finish =
                        start.saturating_add(nanos_from_secs(tokens * t_per_token[k]));
                    let better = match best {
                        None => true,
                        Some((bf, bk)) => finish < bf || (finish == bf && k < bk),
                    };
                    if better {
                        best = Some((finish, k));
                    }
                }
                best.map(|(_, k)| k)
            }
        }
    }

    /// [`Self::choose`] plus a [`TelemetryEvent::DispatchDecision`]
    /// emitted into `probe`. With [`crate::telemetry::NullProbe`] this
    /// monomorphizes to exactly `choose` — the event construction is
    /// dead code the optimizer drops.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn choose_probed<P: Probe>(
        &self,
        probe: &mut P,
        cell: usize,
        expert: usize,
        replicas: &[usize],
        tokens: f64,
        now: Nanos,
        busy_until: &[Nanos],
        t_per_token: &[f64],
        online: &[bool],
        energy: EnergyScore,
    ) -> Option<usize> {
        let device = self.choose(replicas, tokens, now, busy_until, t_per_token, online, energy);
        probe.on_event(&TelemetryEvent::DispatchDecision {
            cell,
            expert,
            tokens,
            device,
            candidates: replicas.len(),
            t: now,
        });
        device
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ONLINE4: [bool; 4] = [true; 4];

    #[test]
    fn static_dispatch_picks_home() {
        let d = Dispatcher::new(DispatchKind::Static);
        let k = d.choose(&[2, 0, 1], 10.0, 0, &[0; 4], &[1e-3; 4], &ONLINE4, EnergyScore::OFF);
        assert_eq!(k, Some(2), "static picks the home (first) online replica");
        let offline_home = [false, true, true, false];
        let k = d.choose(
            &[3, 1],
            10.0,
            0,
            &[0; 4],
            &[1e-3; 4],
            &offline_home,
            EnergyScore::OFF,
        );
        assert_eq!(k, Some(1), "falls back to the next replica in order");
    }

    #[test]
    fn load_aware_prefers_faster_idle_device() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let t = [1e-3, 1e-5, 1e-4, 1e-2];
        let k = d.choose(&[0, 1, 3], 10.0, 0, &[0; 4], &t, &ONLINE4, EnergyScore::OFF);
        assert_eq!(k, Some(1));
    }

    #[test]
    fn load_aware_avoids_backlogged_device() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let t = [1e-5, 1e-4, 1.0, 1.0];
        // Device 0 is 10x faster but its queue drains a full second from
        // now; device 1 finishes sooner.
        let busy = [1_000_000_000, 0, 0, 0];
        let k = d.choose(&[0, 1], 100.0, 0, &busy, &t, &ONLINE4, EnergyScore::OFF);
        assert_eq!(k, Some(1));
    }

    #[test]
    fn offline_replicas_are_skipped() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let online = [false, true, true, true];
        let k = d.choose(&[0, 2], 5.0, 0, &[0; 4], &[1e-3; 4], &online, EnergyScore::OFF);
        assert_eq!(k, Some(2));
        let none = d.choose(&[0], 5.0, 0, &[0; 4], &[1e-3; 4], &online, EnergyScore::OFF);
        assert_eq!(none, None);
        let s = Dispatcher::new(DispatchKind::Static);
        assert_eq!(
            s.choose(&[0], 5.0, 0, &[0; 4], &[1e-3; 4], &online, EnergyScore::OFF),
            None
        );
    }

    #[test]
    fn ties_break_to_lower_device_index() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let k = d.choose(&[3, 1], 10.0, 0, &[0; 4], &[1e-3; 4], &ONLINE4, EnergyScore::OFF);
        assert_eq!(k, Some(1));
    }

    #[test]
    fn choose_excluding_skips_the_primary() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let t = [1e-5, 1e-4, 1e-3, 1.0];
        // Device 0 is best; excluding it yields the runner-up.
        assert_eq!(
            d.choose(&[0, 1, 2], 10.0, 0, &[0; 4], &t, &ONLINE4, EnergyScore::OFF),
            Some(0)
        );
        assert_eq!(
            d.choose_excluding(&[0, 1, 2], 10.0, 0, &[0; 4], &t, &ONLINE4, 0, EnergyScore::OFF),
            Some(1)
        );
        // A single-replica expert has no hedge target.
        assert_eq!(
            d.choose_excluding(&[0], 10.0, 0, &[0; 4], &t, &ONLINE4, 0, EnergyScore::OFF),
            None
        );
        let s = Dispatcher::new(DispatchKind::Static);
        assert_eq!(
            s.choose_excluding(&[0, 2], 10.0, 0, &[0; 4], &t, &ONLINE4, 0, EnergyScore::OFF),
            Some(2)
        );
    }

    #[test]
    fn static_dispatch_skips_unserviceable_home() {
        // A re-solve can starve an online device of spectrum (infinite
        // service time); static dispatch must fall through to the next
        // replica rather than schedule unbounded work.
        let s = Dispatcher::new(DispatchKind::Static);
        let t = [f64::INFINITY, 1e-3, 1e-3, 1e-3];
        assert_eq!(
            s.choose(&[0, 2], 5.0, 0, &[0; 4], &t, &ONLINE4, EnergyScore::OFF),
            Some(2)
        );
        assert_eq!(
            s.choose(&[0], 5.0, 0, &[0; 4], &t, &ONLINE4, EnergyScore::OFF),
            None
        );
    }

    #[test]
    fn energy_score_steers_away_from_costly_device() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        // Identical latency everywhere; device 0 burns 10x the joules.
        let t = [1e-6; 4];
        let cost = [1.0, 0.1, 0.1, 0.1];
        let frac = [1.0; 4];
        let energy = EnergyScore { weight: 1.0, cost_j: &cost, frac: &frac };
        assert_eq!(
            d.choose(&[0, 1], 10.0, 0, &[0; 4], &t, &ONLINE4, energy),
            Some(1)
        );
        // Weight 0 falls back to the latency tie-break (lower index).
        assert_eq!(
            d.choose(&[0, 1], 10.0, 0, &[0; 4], &t, &ONLINE4, EnergyScore::OFF),
            Some(0)
        );
    }

    #[test]
    fn energy_score_spares_drained_battery() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        // Same cost per token, but device 0's battery is nearly dead:
        // the (2 - frac) inflation makes device 1 win despite the tie.
        let t = [1e-6; 4];
        let cost = [0.5; 4];
        let frac = [0.05, 0.9, 0.9, 0.9];
        let energy = EnergyScore { weight: 0.5, cost_j: &cost, frac: &frac };
        assert_eq!(
            d.choose(&[0, 1], 10.0, 0, &[0; 4], &t, &ONLINE4, energy),
            Some(1)
        );
    }

    #[test]
    fn energy_score_still_respects_latency() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        // Device 1 is cheaper but its queue drains a full second from
        // now; a small energy weight cannot overturn a 1 s latency gap.
        let t = [1e-5, 1e-5, 1.0, 1.0];
        let busy = [0, 1_000_000_000, 0, 0];
        let cost = [1.0, 0.01, 0.0, 0.0];
        let frac = [1.0; 4];
        let energy = EnergyScore { weight: 1e-3, cost_j: &cost, frac: &frac };
        assert_eq!(
            d.choose(&[0, 1], 10.0, 0, &busy, &t, &ONLINE4, energy),
            Some(0)
        );
    }

    #[test]
    fn energy_score_applies_to_hedge_pick() {
        let d = Dispatcher::new(DispatchKind::LoadAware);
        let t = [1e-6; 4];
        let cost = [0.1, 1.0, 0.1, 0.1];
        let frac = [1.0; 4];
        let energy = EnergyScore { weight: 1.0, cost_j: &cost, frac: &frac };
        // Excluding the winner, the cheap device 2 beats costly device 1.
        assert_eq!(
            d.choose_excluding(&[0, 1, 2], 10.0, 0, &[0; 4], &t, &ONLINE4, 0, energy),
            Some(2)
        );
    }
}
