//! Fault-plan compilation and graceful degradation for the cluster DES.
//!
//! [`compile`] turns a [`crate::config::FaultConfig`] into per-cell-lane
//! [`FaultEvent`] streams ahead of the run: every stochastic process
//! (crash/recover cycles, straggler episodes, link dips, backhaul outages)
//! is sampled from its own seeded RNG stream keyed by `(process, cell,
//! device)`, so the plan is a pure function of the config — independent of
//! thread count, engine (serial vs sharded) and arrival stream. Each
//! engine walks its lane with a cursor, scheduling the next `FaultEvent`
//! on the owning cell's `EventQueue` lane, which is exactly the mechanism
//! that already keeps serial and sharded pop order byte-identical.
//!
//! The *degradation* half lives here too: [`apply_action`] mutates one
//! cell's state for a fault (taking a device offline clamps its queue and
//! sweeps the in-flight groups it loses), and [`resolve_lost_group`]
//! implements the recovery ladder for each lost group — hedged twin still
//! covers it → re-dispatch to a surviving replica (bounded by the
//! per-request retry budget) → fall back to the configured drop/shed
//! policy. Both engines run the same functions on the same state in the
//! same order, so fault runs stay byte-identical at any thread count.
//!
//! An empty plan compiles to empty lanes; the serial event loop
//! monomorphizes the fault machinery away (`const FAULTS: bool`) and the
//! per-dispatch touches are bit-exact no-ops (a `* 1.0` service
//! multiplier, branches that never take), so zero-fault runs reproduce
//! the pre-fault engine bit for bit — the same discipline `NullProbe`
//! established for telemetry.

use super::dispatch::Dispatcher;
use super::event::{nanos_from_secs, Nanos};
use super::handover::HandoverCoordinator;
use super::sim::{Cell, ReqState, SimParams};
use crate::config::{ClusterConfig, DropPolicy, FaultKind};
use crate::telemetry::{Probe, TelemetryEvent};
use crate::util::Rng;

/// One concrete state change the fault plan applies to a cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Device goes offline; queued and in-service work on it is lost.
    Crash { device: usize },
    /// Device comes back online (empty queue, fresh service multiplier
    /// history — multipliers persist across crashes by design: a slow
    /// device that crashes is still slow when it recovers).
    Recover { device: usize },
    StraggleStart { device: usize, mult: f64 },
    StraggleEnd { device: usize },
    LinkDipStart { device: usize, mult: f64 },
    LinkDipEnd { device: usize },
    /// Cluster-wide backhaul multiplier (`0.0` = outage: no borrows).
    BackhaulDegrade { mult: f64 },
    BackhaulRestore,
}

/// A compiled fault occurrence on one cell's lane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: Nanos,
    pub action: FaultAction,
}

// Stream tags mixed into the fault seed so each (process, cell, device)
// triple draws from an independent RNG stream.
const TAG_CRASH: u64 = 0xC7A5;
const TAG_STRAGGLE: u64 = 0x57A6;
const TAG_LINK: u64 = 0x11D1;
const TAG_BACKHAUL: u64 = 0xBAC4;

fn stream_rng(seed: u64, tag: u64, cell: usize, device: usize) -> Rng {
    Rng::seed_from_u64(seed ^ (tag << 32) ^ ((cell as u64) << 16) ^ device as u64)
}

/// Exponential variate with the given mean (inverse CDF; the u == 0
/// clamp keeps `ln` finite).
fn exp_s(rng: &mut Rng, mean_s: f64) -> f64 {
    -mean_s * rng.f64().max(f64::MIN_POSITIVE).ln()
}

/// Compile the config's fault plan into per-cell event lanes, sorted by
/// `(time, generation order)`. Pure: same config → same lanes, on every
/// engine and thread count.
pub fn compile(cfg: &ClusterConfig) -> Vec<Vec<FaultEvent>> {
    let f = &cfg.faults;
    let n_cells = cfg.cells.len();
    let mut lanes: Vec<Vec<(Nanos, usize, FaultAction)>> = vec![Vec::new(); n_cells];
    if f.is_empty() {
        return lanes
            .into_iter()
            .map(|_| Vec::new())
            .collect();
    }
    let horizon = f.horizon_s;
    let mut seq = 0usize;
    let mut push = |lanes: &mut Vec<Vec<(Nanos, usize, FaultAction)>>,
                    ci: usize,
                    at_s: f64,
                    action: FaultAction| {
        lanes[ci].push((nanos_from_secs(at_s), seq, action));
        seq += 1;
    };

    for ci in 0..n_cells {
        let n_dev = cfg.cells[ci].devices.len();
        // Crash/recover renewal process per device.
        if f.mttf_s > 0.0 {
            for k in 0..n_dev {
                let mut rng = stream_rng(f.seed, TAG_CRASH, ci, k);
                let mut t = 0.0;
                loop {
                    t += exp_s(&mut rng, f.mttf_s);
                    if t >= horizon {
                        break;
                    }
                    push(&mut lanes, ci, t, FaultAction::Crash { device: k });
                    t += exp_s(&mut rng, f.mttr_s);
                    if t >= horizon {
                        break; // stays down past the horizon
                    }
                    push(&mut lanes, ci, t, FaultAction::Recover { device: k });
                }
            }
        }
        // Straggler episodes per device.
        if f.straggler_mtbf_s > 0.0 {
            for k in 0..n_dev {
                let mut rng = stream_rng(f.seed, TAG_STRAGGLE, ci, k);
                let mut t = 0.0;
                loop {
                    t += exp_s(&mut rng, f.straggler_mtbf_s);
                    if t >= horizon {
                        break;
                    }
                    push(
                        &mut lanes,
                        ci,
                        t,
                        FaultAction::StraggleStart {
                            device: k,
                            mult: f.straggler_mult,
                        },
                    );
                    let end = t + f.straggler_duration_s;
                    if end < horizon {
                        push(&mut lanes, ci, end, FaultAction::StraggleEnd { device: k });
                    }
                    t = end;
                }
            }
        }
        // Link-quality dips per device.
        if f.link_dip_mtbf_s > 0.0 {
            for k in 0..n_dev {
                let mut rng = stream_rng(f.seed, TAG_LINK, ci, k);
                let mut t = 0.0;
                loop {
                    t += exp_s(&mut rng, f.link_dip_mtbf_s);
                    if t >= horizon {
                        break;
                    }
                    push(
                        &mut lanes,
                        ci,
                        t,
                        FaultAction::LinkDipStart {
                            device: k,
                            mult: f.link_dip_mult,
                        },
                    );
                    let end = t + f.link_dip_duration_s;
                    if end < horizon {
                        push(&mut lanes, ci, end, FaultAction::LinkDipEnd { device: k });
                    }
                    t = end;
                }
            }
        }
        // Backhaul outages (one stream per cell, device index 0).
        if f.backhaul_outage_mtbf_s > 0.0 {
            let mut rng = stream_rng(f.seed, TAG_BACKHAUL, ci, 0);
            let mut t = 0.0;
            loop {
                t += exp_s(&mut rng, f.backhaul_outage_mtbf_s);
                if t >= horizon {
                    break;
                }
                push(&mut lanes, ci, t, FaultAction::BackhaulDegrade { mult: 0.0 });
                let end = t + f.backhaul_outage_duration_s;
                if end < horizon {
                    push(&mut lanes, ci, end, FaultAction::BackhaulRestore);
                }
                t = end;
            }
        }
    }
    // Scheduled faults, in config order. `device: None` is the
    // correlated whole-cell case, expanded in device order.
    for s in &f.scheduled {
        let n_dev = cfg.cells[s.cell].devices.len();
        let devices: Vec<usize> = match (s.kind, s.device) {
            (FaultKind::Backhaul, _) => vec![0],
            (_, Some(d)) => vec![d],
            (_, None) => (0..n_dev).collect(),
        };
        for k in devices {
            match s.kind {
                FaultKind::Crash => {
                    push(&mut lanes, s.cell, s.at_s, FaultAction::Crash { device: k });
                    if s.duration_s > 0.0 {
                        push(
                            &mut lanes,
                            s.cell,
                            s.at_s + s.duration_s,
                            FaultAction::Recover { device: k },
                        );
                    }
                }
                FaultKind::Straggle => {
                    push(
                        &mut lanes,
                        s.cell,
                        s.at_s,
                        FaultAction::StraggleStart {
                            device: k,
                            mult: s.mult,
                        },
                    );
                    if s.duration_s > 0.0 {
                        push(
                            &mut lanes,
                            s.cell,
                            s.at_s + s.duration_s,
                            FaultAction::StraggleEnd { device: k },
                        );
                    }
                }
                FaultKind::LinkDip => {
                    push(
                        &mut lanes,
                        s.cell,
                        s.at_s,
                        FaultAction::LinkDipStart {
                            device: k,
                            mult: s.mult,
                        },
                    );
                    if s.duration_s > 0.0 {
                        push(
                            &mut lanes,
                            s.cell,
                            s.at_s + s.duration_s,
                            FaultAction::LinkDipEnd { device: k },
                        );
                    }
                }
                FaultKind::Backhaul => {
                    push(
                        &mut lanes,
                        s.cell,
                        s.at_s,
                        FaultAction::BackhaulDegrade { mult: s.mult },
                    );
                    if s.duration_s > 0.0 {
                        push(&mut lanes, s.cell, s.at_s + s.duration_s, FaultAction::BackhaulRestore);
                    }
                }
            }
        }
    }
    lanes
        .into_iter()
        .map(|mut lane| {
            lane.sort_by_key(|&(at, seq, _)| (at, seq));
            lane.into_iter()
                .map(|(at, _, action)| FaultEvent { at, action })
                .collect()
        })
        .collect()
}

/// Per-cell fault runtime: the lane cursor plus the live episode state.
/// Rebuilt at every run start so a reset simulator replays the identical
/// plan.
#[derive(Debug, Clone)]
pub(super) struct CellFaults {
    /// Next un-scheduled event in the cell's compiled lane.
    pub(super) cursor: usize,
    /// Live straggler multiplier per device (1.0 = none).
    pub(super) straggle: Vec<f64>,
    /// Live link-dip multiplier per device (1.0 = none).
    pub(super) link: Vec<f64>,
    /// When each currently-offline device crashed (availability
    /// accounting; meaningful only while `online[k]` is false).
    pub(super) offline_since: Vec<Nanos>,
    /// Accumulated device-offline nanoseconds (integer sum — order-free,
    /// so serial and sharded accumulation agree bit for bit).
    pub(super) offline_ns: u64,
}

impl CellFaults {
    pub(super) fn new(n_dev: usize) -> Self {
        Self {
            cursor: 0,
            straggle: vec![1.0; n_dev],
            link: vec![1.0; n_dev],
            offline_since: vec![0; n_dev],
            offline_ns: 0,
        }
    }
}

/// A committed token group the fault layer may need to recover: enough
/// to re-dispatch it (or bill its loss) if its device crashes before
/// `done`. Tracked only when the run has a non-empty fault plan.
#[derive(Debug, Clone, Copy)]
pub(super) struct InflightGroup {
    pub(super) req: usize,
    pub(super) expert: usize,
    pub(super) device: usize,
    pub(super) tokens: f64,
    pub(super) start: Nanos,
    pub(super) done: Nanos,
    /// The hedged twin's finish instant, when this group has one: a
    /// crash of either twin is covered by the survivor.
    pub(super) cover: Option<Nanos>,
}

/// Apply one fault action to its cell at `now`. Crash actions append the
/// lost in-flight groups (queued or in service on the dead device) to
/// `lost`, in placement order, for the caller's recovery pass.
pub(super) fn apply_action<P: Probe>(
    action: FaultAction,
    ci: usize,
    now: Nanos,
    cell: &mut Cell,
    rt: &mut CellFaults,
    handover: &mut HandoverCoordinator,
    lost: &mut Vec<InflightGroup>,
    probe: &mut P,
) {
    match action {
        FaultAction::Crash { device: k } => {
            if !cell.dev.online[k] {
                return; // idempotent: scheduled crash over a stochastic one
            }
            cell.dev.online[k] = false;
            cell.plane.on_topology_change(&cell.dev.online);
            rt.offline_since[k] = now;
            probe.on_event(&TelemetryEvent::DeviceCrashed {
                cell: ci,
                device: k,
                t: now,
            });
            // The committed queue beyond `now` is lost with the device.
            // (Utilization keeps the already-billed busy seconds: the
            // work was committed and the capacity spent.)
            if cell.dev.busy_until[k] > now {
                cell.dev.busy_until[k] = now;
            }
            // Sweep the in-flight ledger: finished entries are pruned,
            // this device's unfinished groups are lost. Order-preserving
            // so recovery processes groups in placement order.
            let mut i = 0;
            while i < cell.inflight.len() {
                if cell.inflight[i].done <= now {
                    cell.inflight.remove(i);
                } else if cell.inflight[i].device == k {
                    lost.push(cell.inflight.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        FaultAction::Recover { device: k } => {
            if cell.dev.online[k] {
                return;
            }
            // A battery-dead device cannot be resurrected by a fault-plan
            // MTTR recovery: only a recharge episode clears depletion (the
            // offline clock keeps running — it is genuinely unavailable).
            if cell.energy.blocks_recover(k) {
                return;
            }
            cell.dev.online[k] = true;
            cell.plane.on_topology_change(&cell.dev.online);
            rt.offline_ns += now - rt.offline_since[k];
            probe.on_event(&TelemetryEvent::DeviceRecovered {
                cell: ci,
                device: k,
                t: now,
            });
        }
        FaultAction::StraggleStart { device: k, mult } => {
            rt.straggle[k] = mult;
            set_service_mult(cell, rt, ci, k, now, probe);
        }
        FaultAction::StraggleEnd { device: k } => {
            rt.straggle[k] = 1.0;
            set_service_mult(cell, rt, ci, k, now, probe);
        }
        FaultAction::LinkDipStart { device: k, mult } => {
            rt.link[k] = mult;
            set_service_mult(cell, rt, ci, k, now, probe);
        }
        FaultAction::LinkDipEnd { device: k } => {
            rt.link[k] = 1.0;
            set_service_mult(cell, rt, ci, k, now, probe);
        }
        FaultAction::BackhaulDegrade { mult } => {
            handover.set_fault_mult(mult);
            probe.on_event(&TelemetryEvent::BackhaulFault {
                cell: ci,
                mult,
                t: now,
            });
        }
        FaultAction::BackhaulRestore => {
            handover.set_fault_mult(1.0);
            probe.on_event(&TelemetryEvent::BackhaulFault {
                cell: ci,
                mult: 1.0,
                t: now,
            });
        }
    }
}

fn set_service_mult<P: Probe>(
    cell: &mut Cell,
    rt: &CellFaults,
    ci: usize,
    k: usize,
    now: Nanos,
    probe: &mut P,
) {
    let mult = rt.straggle[k] * rt.link[k];
    cell.dev.service_mult[k] = mult;
    probe.on_event(&TelemetryEvent::DeviceSlowdown {
        cell: ci,
        device: k,
        mult,
        t: now,
    });
}

/// What became of one crash-lost group after the recovery ladder.
pub(super) enum LossResolution {
    /// A hedged twin on another device still finishes the work.
    Covered,
    /// Re-placed on a surviving replica; `waste` is the in-service work
    /// the crash discarded (0 for still-queued groups).
    Redispatched { waste: f64 },
    /// Retry budget or replicas exhausted under [`DropPolicy::DropRequest`]:
    /// the request is dead.
    Dropped { waste: f64 },
    /// Retry budget or replicas exhausted under [`DropPolicy::ShedTokens`]:
    /// the group's tokens are shed, the request continues degraded.
    Shed { tokens: f64, waste: f64 },
}

/// Run the recovery ladder for one lost group. Updates the request's
/// barrier (re-dispatch and hedge-cover push the pending `BlockDone`
/// later) or marks it dropped; the caller translates the resolution into
/// its engine's counters.
#[allow(clippy::too_many_arguments)]
pub(super) fn resolve_lost_group<P: Probe>(
    g: &InflightGroup,
    st: &mut ReqState,
    ci: usize,
    now: Nanos,
    cell: &mut Cell,
    dispatcher: &Dispatcher,
    params: &SimParams,
    probe: &mut P,
) -> LossResolution {
    if let Some(c) = g.cover {
        // The speculative twin survives; its finish bounds the barrier.
        // The loser's tokens were already billed as waste at hedge time.
        if c > st.barrier {
            st.barrier = c;
        }
        return LossResolution::Covered;
    }
    // In-service work is discarded on a crash; queued groups lose nothing.
    let waste = if g.start < now { g.tokens } else { 0.0 };
    if st.retries < params.max_retries {
        let choice = {
            let placement = cell.plane.placement();
            dispatcher.choose(
                placement.replicas(g.expert),
                g.tokens,
                now,
                &cell.dev.busy_until,
                cell.plane.t_per_token(),
                &cell.dev.online,
                cell.energy.score(),
            )
        };
        if let Some(k) = choice {
            let t_k = cell.plane.t_per_token()[k];
            let service_s = g.tokens * t_k * cell.dev.service_mult[k];
            let start = cell.dev.busy_until[k].max(now);
            let done = start.saturating_add(nanos_from_secs(service_s));
            cell.dev.busy_until[k] = done;
            cell.dev.busy[k].add_busy(service_s);
            if cell.energy.enabled {
                let bw = cell.plane.bandwidth();
                cell.energy.debit(k, g.tokens, bw, now);
            }
            // Demand accounting: served_tokens feeds the dispatcher-load
            // signal, but expert_tokens already counted this group at its
            // original commit — re-adding would double the autoscaler's
            // demand estimate.
            cell.dev.served_tokens[k] += g.tokens;
            st.retries += 1;
            if done > st.barrier {
                st.barrier = done;
            }
            cell.inflight.push(InflightGroup {
                req: g.req,
                expert: g.expert,
                device: k,
                tokens: g.tokens,
                start,
                done,
                cover: None,
            });
            probe.on_event(&TelemetryEvent::Redispatched {
                req: g.req,
                cell: ci,
                expert: g.expert,
                device: k,
                tokens: g.tokens,
                t: now,
                done,
            });
            return LossResolution::Redispatched { waste };
        }
    }
    // Budget or replicas exhausted: fall back to the drop policy.
    match params.drop_policy {
        DropPolicy::DropRequest => {
            st.dropped = true;
            probe.on_event(&TelemetryEvent::Dropped {
                req: g.req,
                cell: ci,
                t: now,
            });
            LossResolution::Dropped { waste }
        }
        DropPolicy::ShedTokens => {
            probe.on_event(&TelemetryEvent::GroupShed {
                req: g.req,
                cell: ci,
                expert: g.expert,
                tokens: g.tokens,
                t: now,
            });
            LossResolution::Shed {
                tokens: g.tokens,
                waste,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, FaultKind, ScheduledFault};

    fn faulted_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.mttf_s = 5.0;
        cfg.faults.mttr_s = 1.0;
        cfg.faults.straggler_mtbf_s = 4.0;
        cfg.faults.horizon_s = 20.0;
        cfg
    }

    #[test]
    fn empty_plan_compiles_to_empty_lanes() {
        let cfg = ClusterConfig::edge_default();
        let lanes = compile(&cfg);
        assert_eq!(lanes.len(), cfg.cells.len());
        assert!(lanes.iter().all(|l| l.is_empty()));
    }

    #[test]
    fn compile_is_deterministic_and_sorted() {
        let cfg = faulted_cfg();
        let a = compile(&cfg);
        let b = compile(&cfg);
        assert_eq!(a, b);
        assert!(a.iter().any(|l| !l.is_empty()), "plan generated nothing");
        for lane in &a {
            for w in lane.windows(2) {
                assert!(w[0].at <= w[1].at, "lane not time-sorted");
            }
        }
    }

    #[test]
    fn fault_seed_changes_the_plan() {
        let cfg = faulted_cfg();
        let mut cfg2 = cfg.clone();
        cfg2.faults.seed ^= 0xDEAD;
        assert_ne!(compile(&cfg), compile(&cfg2));
    }

    #[test]
    fn sim_seed_does_not_change_the_plan() {
        let cfg = faulted_cfg();
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1234;
        assert_eq!(compile(&cfg), compile(&cfg2));
    }

    #[test]
    fn crash_recover_alternate_per_device() {
        let mut cfg = ClusterConfig::single_cell();
        cfg.faults.mttf_s = 3.0;
        cfg.faults.mttr_s = 0.5;
        cfg.faults.horizon_s = 50.0;
        let lanes = compile(&cfg);
        let n_dev = cfg.cells[0].devices.len();
        for k in 0..n_dev {
            let mut expect_crash = true;
            for ev in &lanes[0] {
                match ev.action {
                    FaultAction::Crash { device } if device == k => {
                        assert!(expect_crash, "two crashes without a recover (dev {k})");
                        expect_crash = false;
                    }
                    FaultAction::Recover { device } if device == k => {
                        assert!(!expect_crash, "recover before crash (dev {k})");
                        expect_crash = true;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn whole_cell_scheduled_crash_expands_per_device() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.scheduled.push(ScheduledFault {
            at_s: 1.0,
            cell: 1,
            device: None,
            kind: FaultKind::Crash,
            duration_s: 2.0,
            mult: 1.0,
        });
        let lanes = compile(&cfg);
        let n_dev = cfg.cells[1].devices.len();
        let crashes = lanes[1]
            .iter()
            .filter(|e| matches!(e.action, FaultAction::Crash { .. }))
            .count();
        let recovers = lanes[1]
            .iter()
            .filter(|e| matches!(e.action, FaultAction::Recover { .. }))
            .count();
        assert_eq!(crashes, n_dev);
        assert_eq!(recovers, n_dev);
        assert!(lanes[0].is_empty());
    }

    #[test]
    fn scheduled_backhaul_outage_emits_degrade_and_restore() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.scheduled.push(ScheduledFault {
            at_s: 0.5,
            cell: 0,
            device: None,
            kind: FaultKind::Backhaul,
            duration_s: 1.0,
            mult: 0.0,
        });
        let lanes = compile(&cfg);
        assert_eq!(lanes[0].len(), 2);
        assert_eq!(lanes[0][0].action, FaultAction::BackhaulDegrade { mult: 0.0 });
        assert_eq!(lanes[0][1].action, FaultAction::BackhaulRestore);
        assert!(lanes[0][0].at < lanes[0][1].at);
    }

    #[test]
    fn horizon_bounds_stochastic_generation() {
        let mut cfg = ClusterConfig::single_cell();
        cfg.faults.straggler_mtbf_s = 0.1;
        cfg.faults.straggler_duration_s = 0.05;
        cfg.faults.horizon_s = 2.0;
        let lanes = compile(&cfg);
        let bound = nanos_from_secs(2.0);
        assert!(lanes[0].iter().all(|e| e.at < bound));
        assert!(lanes[0].len() > 4, "expected a dense episode stream");
    }
}
