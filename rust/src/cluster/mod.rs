//! # `cluster` — discrete-event multi-cell serving simulation
//!
//! The paper's analysis (§III–§IV) and [`crate::coordinator::sim`]
//! evaluate **one** base station serving **one** batch at a time. The
//! north-star — sustained traffic from many users — needs the opposite
//! view: requests arrive while others are in flight, queue at devices,
//! and contend for compute and spectrum. This subsystem models that as a
//! deterministic discrete-event simulation (DES).
//!
//! ## Event model
//!
//! Virtual time is integer nanoseconds on a shared
//! [`crate::util::clock::VirtualClock`]; the [`event::EventQueue`] orders
//! events by `(time, lane, insertion seq)` — the lane is the owning cell
//! index, so simultaneous events fire lowest cell first, then in
//! scheduling order, and every run is a pure function of config + seeds.
//! That makes the serial pop order the canonical k-way merge of per-cell
//! event streams, which is what lets the sharded engine ([`shard`])
//! reproduce it exactly. Two event kinds drive the simulation:
//!
//! * **`Arrive(req)`** — an open-loop arrival
//!   ([`crate::workload::ArrivalProcess`]: Poisson or trace replay). The
//!   request is assigned to a cell round-robin and its first MoE block is
//!   dispatched immediately.
//! * **`BlockDone(req)`** — the Eq. (11) attention barrier of one block
//!   cleared. The request either advances to its next block (dispatching
//!   more device work) or, after block `I`, completes and records its
//!   end-to-end latency.
//!
//! Dispatching a block is synchronous bookkeeping: the cell's gate draws
//! weights, the selection policy (Algorithm 1 / top-k / Algorithm 2)
//! picks experts, and each selected expert's token group is routed by the
//! [`dispatch::Dispatcher`] to one replica. Token groups join that
//! device's FIFO queue (`busy_until[k]`): service starts when the queue
//! drains and lasts `q_e · t_k` seconds (Eqs. (8)–(10) under the cell's
//! uniform bandwidth share). The block's completion — the max over its
//! groups' finish instants — becomes the next `BlockDone` event. Waiting
//! time and utilization therefore *emerge* from load; nothing is assumed.
//!
//! ## Control plane, replication and placement
//!
//! Each cell's `(bandwidth allocation, service times, expert placement)`
//! are owned by its [`crate::control::ControlPlane`], selected by
//! [`crate::config::ControlKind`]: the static planes freeze them at
//! construction (uniform split, or a one-shot P3 pre-solve), while the
//! **adaptive** plane closes the paper's loop inside the DES —
//! `ControlTick` events on an epoch cadence convert observed queue
//! backlog into a demand vector, re-solve P3 warm-started from the
//! previous split, and re-balance expert replicas from observed
//! per-expert token counts (replica autoscaling). Placement is a
//! [`placement::Placement`]: experts may live on several devices,
//! bounded by a per-device cache capacity (the paper's §I "limited
//! computing and caching resources", Eq. (7)); the load-aware dispatcher
//! picks, per block, the replica with the earliest predicted completion
//! given current backlog, reading service times through the plane so
//! re-allocations take effect immediately. Cache capacity 1 (or
//! [`crate::config::DispatchKind::Static`]) reproduces the paper's fixed
//! expert-per-device assignment as a baseline.
//!
//! ## Admission control
//!
//! With [`crate::config::ClusterConfig::queue_limit_s`] set, a dispatch
//! finding every replica of an expert beyond the backlog bound triggers
//! the configured [`crate::config::DropPolicy`]: reject the whole
//! request, or shed only the offending token group (a block always
//! serves at least one group). Goodput, drop rate and shed rate are
//! reported next to the latency percentiles so overload shows up as
//! degraded useful work instead of unbounded queues — whichever policy
//! absorbs it.
//!
//! ## Inter-cell handover
//!
//! The [`handover`] layer sits *above* the per-cell dispatcher, selected
//! by [`crate::config::HandoverPolicy`]: `RehomeOnArrival` homes each
//! arrival on the cell with the lowest live backlog per online device
//! (a [`crate::control::CellLoad`] score) instead of blind round-robin,
//! and `BorrowExpert` routes a token group whose local replicas are all
//! over the queue bound (or unserviceable) to the least-loaded neighbor
//! cell's replica, paying `backhaul_s_per_token` per hop. Borrowed
//! groups ride the same Eq. (11) barrier, are staged-then-committed so a
//! `DropRequest` rejection leaves no partial work in any cell, and show
//! up as `handover_rate` / `borrowed_tokens` in both sweep CSVs. With
//! `HandoverPolicy::None` the simulator's behaviour is unchanged from
//! the pre-handover baseline (the new CSV columns are always zero), and
//! its output is byte-identical to a run where handover is configured
//! but never triggered.
//!
//! ## Entry points
//!
//! * [`sim::ClusterSim`] — build from a borrowed
//!   [`crate::config::ClusterConfig`] (sweeps never clone the config per
//!   point), feed an arrival stream, get a [`sim::ClusterOutcome`]
//!   (throughput, goodput, drop rate, steady-state p50/p95/p99 latency,
//!   per-device utilization, control-plane activity, events processed);
//!   [`sim::ClusterSim::reset`] restores the just-built state so one
//!   simulator serves many runs.
//! * [`shard`] — the sharded engine: `run_sharded(arrivals, threads)`
//!   gives each cell its own event queue and advances the shards
//!   concurrently inside conservative sync windows, draining per-shard
//!   mailboxes in canonical `(time, cell, seq)` order so outcomes,
//!   traces and timelines are byte-identical to the serial loop at any
//!   thread count (interacting handover policies fall back to serial —
//!   they read neighbor state at zero lookahead).
//! * [`crate::experiment`] — sweeps over this simulator are typed
//!   grids: an [`crate::experiment::Axis`] per knob, a
//!   [`crate::experiment::Grid`] for the cross-product, one
//!   [`crate::experiment::Record`] metric schema for every CSV/JSON.
//!   The legacy [`arrival_rate_sweep`] (`repro cluster`) and
//!   [`control_plane_sweep`] (`repro cluster --control compare`) are
//!   thin wrappers over it, re-exported here.
//!
//! ## Telemetry
//!
//! The DES is instrumented for [`crate::telemetry`]: the probed entry
//! points ([`sim::ClusterSim::run_probed`], `choose_probed`,
//! `try_borrow_probed`) emit structured
//! [`crate::telemetry::TelemetryEvent`]s — arrivals, dispatch
//! decisions, placements, sheds, borrow stage/commit/rollback, drops,
//! control re-solves with their P3 solver cost — and, on a
//! probe-chosen cadence, per-cell state snapshots. `run` is
//! `run_probed` with [`crate::telemetry::NullProbe`], whose empty
//! inline hooks monomorphize to the pre-telemetry hot path; probes
//! observe and never perturb, so a probed run's outcome is bit-equal
//! to an unprobed one (regression-tested, and watched by the
//! `cluster/des_run_2cell_nullprobe` bench harness).
//!
//! Every sweep runs its points on the [`crate::exec`] worker pool and
//! merges in canonical order — parallel output is byte-identical to
//! serial. The event loop itself is allocation-free per event (per-cell
//! scratch + the control plane's solver workspace). With
//! `control_backlog_delta_s > 0`, an adaptive cell also re-solves
//! between epoch ticks whenever its total queued seconds drift past the
//! threshold since the last solve — the queue-state-driven cadence the
//! allocation-free tick made affordable.
//!
//! ## Fault injection & graceful degradation
//!
//! The [`faults`] module compiles a seeded
//! [`crate::config::FaultConfig`] — scheduled and stochastic device
//! crash/recover (MTTF/MTTR renewal processes), straggler episodes that
//! multiply service time, link-quality dips, backhaul outages, and
//! correlated whole-cell events — into one sorted [`faults::FaultEvent`]
//! lane per cell, walked by `Fault` events on the same queues as the
//! rest of the DES. The plan is a pure function of the fault seed, so
//! serial and sharded runs stay byte-identical at any thread count; an
//! empty plan monomorphizes to the exact zero-fault hot path (the same
//! `NullProbe` discipline telemetry uses). On top of injection the
//! simulator degrades gracefully: a crash re-dispatches the device's
//! queued and in-service token groups to surviving replicas (bounded by
//! `max_retries`, then the configured drop policy), an optional
//! per-request `deadline_s` turns on SLO accounting, and `hedge` places
//! a speculative duplicate of any deadline-busting group on the
//! runner-up replica — first finish wins, the loser's tokens are
//! counted as waste. Outcomes report `slo_miss_rate`, `retries`,
//! `hedge_rate`, `wasted_tokens` and `availability` next to the
//! existing metrics.
//!
//! ## Energy model & battery churn
//!
//! The [`energy`] module compiles a validated
//! [`crate::config::EnergyConfig`] into per-cell [`energy::CellEnergy`]
//! state: every committed token group debits the serving device's
//! battery — compute joules/token plus radio TX/RX joules/token scaled
//! by the device's live bandwidth share (a thin slice means longer
//! airtime) — with heterogeneous fleets via round-robin device classes
//! and an optional idle draw. With `energy_weight > 0` the load-aware
//! dispatcher ranks replicas by a weighted latency+energy objective
//! ([`dispatch::EnergyScore`]) and the adaptive plane's demand vector is
//! biased away from drained batteries, trading p99 against joules/token
//! and fleet lifetime. A depleted battery drains a deterministic
//! [`faults::FaultAction::Crash`] into the existing per-cell fault lanes
//! (recharge optional via MTTR-style episodes), so device death
//! exercises the crash re-dispatch / hedging / SLO path as a *systemic*
//! phenomenon. Outcomes report total joules, joules/token and the
//! first/last-depletion fleet lifetime; an empty config monomorphizes
//! the accounting away (`ENERGY = false`), bit-equal to the pre-energy
//! engine, and energy-on runs stay byte-identical serial vs sharded.
//!
//! Follow-ons tracked in ROADMAP.md: handover hysteresis.

pub mod dispatch;
pub mod energy;
pub mod event;
pub mod faults;
pub mod handover;
pub mod placement;
pub mod shard;
pub mod sim;

pub use dispatch::{Dispatcher, EnergyScore};
pub use energy::CellEnergy;
pub use event::{nanos_from_secs, secs_from_nanos, EventQueue, Nanos};
pub use faults::{compile as compile_fault_plan, FaultAction, FaultEvent};
pub use handover::{HandoverCell, HandoverCoordinator, StagedBorrow};
pub use placement::Placement;
pub use sim::{ClusterOutcome, ClusterSim};
// The sweep entry points live in the experiment API now; re-exported so
// `wdmoe::cluster::arrival_rate_sweep` call sites keep working.
pub use crate::experiment::{arrival_rate_sweep, control_plane_sweep, SweepPoint, SweepResult};
