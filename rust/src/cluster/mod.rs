//! # `cluster` — discrete-event multi-cell serving simulation
//!
//! The paper's analysis (§III–§IV) and [`crate::coordinator::sim`]
//! evaluate **one** base station serving **one** batch at a time. The
//! north-star — sustained traffic from many users — needs the opposite
//! view: requests arrive while others are in flight, queue at devices,
//! and contend for compute and spectrum. This subsystem models that as a
//! deterministic discrete-event simulation (DES).
//!
//! ## Event model
//!
//! Virtual time is integer nanoseconds on a shared
//! [`crate::util::clock::VirtualClock`]; the [`event::EventQueue`] orders
//! events by `(time, insertion seq)` so simultaneous events fire in
//! scheduling order and every run is a pure function of config + seeds.
//! Two event kinds drive the simulation:
//!
//! * **`Arrive(req)`** — an open-loop arrival
//!   ([`crate::workload::ArrivalProcess`]: Poisson or trace replay). The
//!   request is assigned to a cell round-robin and its first MoE block is
//!   dispatched immediately.
//! * **`BlockDone(req)`** — the Eq. (11) attention barrier of one block
//!   cleared. The request either advances to its next block (dispatching
//!   more device work) or, after block `I`, completes and records its
//!   end-to-end latency.
//!
//! Dispatching a block is synchronous bookkeeping: the cell's gate draws
//! weights, the selection policy (Algorithm 1 / top-k / Algorithm 2)
//! picks experts, and each selected expert's token group is routed by the
//! [`dispatch::Dispatcher`] to one replica. Token groups join that
//! device's FIFO queue (`busy_until[k]`): service starts when the queue
//! drains and lasts `q_e · t_k` seconds (Eqs. (8)–(10) under the cell's
//! uniform bandwidth share). The block's completion — the max over its
//! groups' finish instants — becomes the next `BlockDone` event. Waiting
//! time and utilization therefore *emerge* from load; nothing is assumed.
//!
//! ## Replication and placement
//!
//! Each cell owns a [`placement::Placement`]: experts may live on several
//! devices, bounded by a per-device cache capacity (the paper's §I
//! "limited computing and caching resources", Eq. (7)). The greedy
//! optimizer replicates experts homed on slow/far devices onto fast ones;
//! the load-aware dispatcher then picks, per block, the replica with the
//! earliest predicted completion given current backlog. Cache capacity 1
//! (or [`crate::config::DispatchKind::Static`]) reproduces the paper's
//! fixed expert-per-device assignment as a baseline.
//!
//! ## Entry points
//!
//! * [`sim::ClusterSim`] — build from a [`crate::config::ClusterConfig`],
//!   feed an arrival stream, get a [`sim::ClusterOutcome`] (throughput,
//!   steady-state p50/p95/p99 latency, per-device utilization).
//! * [`sim::arrival_rate_sweep`] — the `repro cluster` CLI command: sweep
//!   Poisson arrival rates and emit the summary + utilization CSVs.
//!
//! Follow-ons tracked in ROADMAP.md: admission control, inter-cell
//! handover, an energy model, autoscaling of replicas.

pub mod dispatch;
pub mod event;
pub mod placement;
pub mod sim;

pub use dispatch::Dispatcher;
pub use event::{nanos_from_secs, secs_from_nanos, EventQueue, Nanos};
pub use placement::Placement;
pub use sim::{arrival_rate_sweep, ClusterOutcome, ClusterSim, SweepPoint, SweepResult};
