//! `repro` — the WDMoE command-line entry point.
//!
//! ```text
//! repro [--out DIR] [--artifacts DIR] [--config FILE.json] [--quick]
//!       [--seed N] <command> [command options]
//!
//! commands:
//!   serve [--requests N] [--benchmark NAME] [--policy P]
//!                 end-to-end serving: PJRT compute + wireless sim
//!                 (needs the `pjrt` cargo feature + built artifacts)
//!   cluster [--rates CSV] [--requests N] [--benchmark NAME]
//!           [--cache N] [--dispatch load_aware|static] [--cells N]
//!           [--control static_uniform|static_optimal|adaptive|compare]
//!           [--epoch S] [--backlog-delta S] [--queue-limit S]
//!           [--drop request|shed] [--handover none|rehome|borrow]
//!           [--backhaul S] [--backhaul-matrix M] [--threads N]
//!           [--faults FILE.json] [--mttf S] [--mttr S]
//!           [--straggler MTBF[:DUR:MULT]] [--deadline S] [--hedge]
//!           [--retries N] [--energy FILE.json] [--energy-weight W]
//!           [--battery J]
//!                 multi-cell discrete-event serving sweep: throughput,
//!                 goodput, drop rate, p50/p95/p99 latency, per-device
//!                 utilization, control-plane activity and handover
//!                 metrics vs arrival rate (CSV into --out); `--control
//!                 compare` runs all three control planes on identical
//!                 arrival streams; `--handover` enables load-aware
//!                 arrival re-homing or cross-cell expert borrowing
//!                 (per-token backhaul latency via --backhaul); the
//!                 fault flags arm a deterministic fault plan (device
//!                 crash/recover, straggler episodes, a full FaultConfig
//!                 JSON via --faults) with graceful degradation:
//!                 crashed work re-dispatches to surviving replicas
//!                 (bounded by --retries), --deadline turns on SLO
//!                 accounting and --hedge speculative duplicates; the
//!                 energy flags arm per-device battery accounting
//!                 (--energy loads an EnergyConfig JSON, --battery sets
//!                 capacity, --energy-weight biases dispatch toward
//!                 charged devices; depleted batteries crash through the
//!                 fault path and outcomes gain joules_per_token /
//!                 fleet_lifetime_s); sweep
//!                 points run on the parallel engine (--threads 0 =
//!                 one worker per core, 1 = serial; output is
//!                 byte-identical either way)
//!   sweep --axis NAME=SPEC [--axis NAME=SPEC ...] [--requests N]
//!         [--benchmark NAME] [--threads N] [--json]
//!         [+ the cluster base-config flags above]
//!                 typed experiment grid: the cross-product of every
//!                 --axis (comma list `0.5,1,2` or inclusive range
//!                 `start:step:end`; axes: rate, control, handover,
//!                 backhaul, queue_limit, drop, cache, dispatch, cells,
//!                 devices, seed, epoch, hysteresis, backlog_delta,
//!                 mttf, mttr, straggler, deadline, hedge,
//!                 energy_weight, battery, device_class)
//!                 through the parallel engine, one unified-schema
//!                 CSV (+ JSON with --json) into --out
//!   trace [--rate R] [--requests N] [--benchmark NAME]
//!         [--trace FILE.json] [--timeline FILE.csv]
//!         [--sample-every N] [--timeline-dt S] [--threads N]
//!         [+ the cluster base-config flags above]
//!                 one telemetry-instrumented DES run: a Chrome
//!                 trace-event JSON (load in Perfetto / chrome://tracing;
//!                 one lane per device, spans for queue/compute/backhaul)
//!                 plus a sim-time timeline CSV (per-cell backlog,
//!                 utilization, drop rate, live replicas on a --timeline-dt
//!                 cadence); probes only observe — the run's outcome is
//!                 bit-equal to the same `repro cluster` point
//!   bench [--json] [--smoke]
//!                 named performance harnesses (solver cold/warm, epoch
//!                 tick, dispatch, DES events/sec with and without the
//!                 no-op telemetry probe); --json writes
//!                 BENCH_cluster.json, --smoke uses tiny budgets (CI)
//!   config [simulation|testbed|serving|cluster]
//!                 print a preset config as JSON
//!   fig5 fig6 fig7 fig8 fig10 table1 table2 table3 table4
//!                 regenerate one paper table/figure
//!   all           regenerate everything
//! ```
//!
//! (Arg parsing is hand-rolled; clap is unavailable in the offline build
//! environment — DESIGN.md §Substitutions.)

use std::path::{Path, PathBuf};
use wdmoe::cluster::{arrival_rate_sweep, control_plane_sweep, ClusterOutcome, ClusterSim};
use wdmoe::config::{
    ClusterConfig, ControlKind, DispatchKind, DropPolicy, EnergyConfig, FaultConfig,
    HandoverPolicy, SystemConfig,
};
use wdmoe::experiment::{AxisSpec, Grid, Scenario};
use wdmoe::util::Json;
use wdmoe::repro::{self, ReproContext};
use wdmoe::telemetry::{ChromeTracer, TimelineSampler};
use wdmoe::workload::{ArrivalProcess, Benchmark};

const USAGE: &str = "\
repro — WDMoE: Wireless Distributed Mixture of Experts (reproduction CLI)

USAGE: repro [GLOBAL OPTIONS] <COMMAND> [COMMAND OPTIONS]

GLOBAL OPTIONS:
  --out DIR          output directory for CSVs        [results]
  --artifacts DIR    AOT artifacts (make artifacts)   [artifacts]
  --config FILE      config JSON override (SystemConfig; for the
                     `cluster` command a ClusterConfig as printed by
                     `repro config cluster`)
  --quick            coarser sweeps, single batch per point
  --seed N           base RNG seed                    [0]

COMMANDS:
  serve [--requests N] [--benchmark NAME] [--policy vanilla|wdmoe|testbed|random]
        (requires building with --features pjrt)
  cluster [--rates CSV] [--requests N] [--benchmark NAME]
          [--cache N] [--dispatch load_aware|static] [--cells N]
          [--control static_uniform|static_optimal|adaptive|compare]
          [--epoch S] [--backlog-delta S] [--queue-limit S]
          [--drop request|shed] [--handover none|rehome|borrow]
          [--backhaul S] [--backhaul-matrix \"a,b;c,d\"] [--threads N]
          [--faults FILE.json] [--mttf S] [--mttr S]
          [--straggler MTBF[:DUR:MULT]] [--deadline S] [--hedge]
          [--retries N] [--energy FILE.json] [--energy-weight W]
          [--battery J] [--trace FILE.json] [--timeline FILE.csv]
                          (--threads 0 = one worker per core; output is
                           byte-identical at any thread count; fault
                           flags inject deterministic crashes/stragglers
                           with re-dispatch, deadlines and hedging —
                           outcomes gain slo_miss_rate, retries,
                           hedge_rate, wasted_tokens, availability;
                           energy flags arm per-device battery
                           accounting and energy-aware dispatch —
                           outcomes gain joules_per_token, energy_j,
                           fleet_lifetime_s, depleted_devices;
                           --trace / --timeline additionally export
                           telemetry for the first rate — not with
                           --control compare)
  trace [--rate R] [--requests N] [--benchmark NAME]
        [--trace FILE.json] [--timeline FILE.csv]
        [--sample-every N] [--timeline-dt S] [--threads N]
        [+ the cluster base-config flags]
                          one instrumented DES run: Chrome trace-event
                          JSON (Perfetto) + sim-time timeline CSV;
                          --threads >1 (0 = auto) runs the sharded DES —
                          artifacts are byte-identical at any count
  sweep --axis NAME=SPEC [--axis NAME=SPEC ...]
        [--requests N] [--benchmark NAME] [--threads N] [--json]
        [+ the cluster base-config flags]
                          SPEC is a comma list (0.5,1,2 / none,borrow)
                          or an inclusive range start:step:end; axes:
                          rate control handover backhaul queue_limit
                          drop cache dispatch cells devices seed epoch
                          hysteresis backlog_delta mttf mttr straggler
                          deadline hedge energy_weight battery
                          device_class
  bench [--json] [--smoke]
  config [simulation|testbed|serving|cluster]
  fig5 | fig6 | fig7 | fig8 | fig10
  table1 | table2 | table3 | table4
  ablate        design-decision ablations (allocation granularity, bias, theta)
  all
";

struct Args {
    out: PathBuf,
    artifacts: PathBuf,
    config: Option<PathBuf>,
    quick: bool,
    /// `--seed` if given; `None` lets a `--config` file's seed stand.
    seed: Option<u64>,
    cmd: String,
    rest: Vec<String>,
}

impl Args {
    fn seed_or_default(&self) -> u64 {
        self.seed.unwrap_or(0)
    }
}

fn parse_args() -> anyhow::Result<Args> {
    let mut out = PathBuf::from("results");
    let mut artifacts = PathBuf::from("artifacts");
    let mut config = None;
    let mut quick = false;
    let mut seed = None;
    let mut cmd = None;
    let mut rest = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut take = |name: &str| -> anyhow::Result<String> {
            it.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
        };
        match a.as_str() {
            "--out" => out = PathBuf::from(take("--out")?),
            "--artifacts" => artifacts = PathBuf::from(take("--artifacts")?),
            "--config" => config = Some(PathBuf::from(take("--config")?)),
            "--quick" => quick = true,
            "--seed" => seed = Some(take("--seed")?.parse()?),
            "--help" | "-h" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other if cmd.is_none() && !other.starts_with('-') => cmd = Some(other.to_string()),
            other if cmd.is_some() => rest.push(other.to_string()),
            other => anyhow::bail!("unknown option {other}\n{USAGE}"),
        }
    }
    Ok(Args {
        out,
        artifacts,
        config,
        quick,
        seed,
        cmd: cmd.ok_or_else(|| anyhow::anyhow!("no command given\n{USAGE}"))?,
        rest,
    })
}

fn rest_opt(rest: &[String], key: &str) -> Option<String> {
    rest.iter()
        .position(|a| a == key)
        .and_then(|i| rest.get(i + 1).cloned())
}

/// Every value of a repeatable option (`--axis a=1 --axis b=2`).
fn rest_all(rest: &[String], key: &str) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        if rest[i] == key {
            let v = rest
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("{key} needs a value"))?;
            out.push(v.clone());
            i += 2;
        } else {
            i += 1;
        }
    }
    Ok(out)
}

/// The base-config flags `cluster` and `sweep` share: load/override a
/// [`ClusterConfig`] before rates or axes are applied on top.
fn cluster_base_config(args: &Args) -> anyhow::Result<ClusterConfig> {
    // --config takes a ClusterConfig JSON here (the format
    // `repro config cluster` prints), not a SystemConfig.
    let mut cfg = match &args.config {
        Some(p) => ClusterConfig::from_json_file(p)?,
        None => ClusterConfig::edge_default(),
    };
    // --seed overrides; otherwise a --config file's seed stands.
    if let Some(seed) = args.seed {
        cfg.seed = seed;
    }
    let rest = &args.rest;
    if let Some(n) = rest_opt(rest, "--cells") {
        let n: usize = n.parse()?;
        anyhow::ensure!(n >= 1, "--cells must be >= 1");
        cfg = cfg.with_n_cells(n);
    }
    if let Some(c) = rest_opt(rest, "--cache") {
        cfg.cache_capacity = c.parse()?;
    }
    if let Some(d) = rest_opt(rest, "--dispatch") {
        cfg.dispatch = DispatchKind::parse(&d)?;
    }
    if let Some(e) = rest_opt(rest, "--epoch") {
        cfg.control_epoch_s = e.parse()?;
    }
    if let Some(b) = rest_opt(rest, "--backlog-delta") {
        cfg.control_backlog_delta_s = b.parse()?;
    }
    if let Some(q) = rest_opt(rest, "--queue-limit") {
        cfg.queue_limit_s = q.parse()?;
    }
    if let Some(d) = rest_opt(rest, "--drop") {
        cfg.drop_policy = DropPolicy::parse(&d)?;
    }
    if let Some(h) = rest_opt(rest, "--handover") {
        cfg.handover = HandoverPolicy::parse(&h)?;
    }
    if let Some(b) = rest_opt(rest, "--backhaul") {
        cfg.backhaul_s_per_token = b.parse()?;
    }
    if let Some(m) = rest_opt(rest, "--backhaul-matrix") {
        // Rows separated by ';', entries by ',': "0,2e-3;1e-3,0" is a
        // directed 2x2 `matrix[from][to]` (the diagonal is never read).
        // Shape and entries are checked by `ClusterConfig::validate`.
        let matrix = m
            .split(';')
            .map(|row| {
                row.split(',')
                    .map(|s| s.trim().parse::<f64>().map_err(anyhow::Error::from))
                    .collect::<anyhow::Result<Vec<f64>>>()
            })
            .collect::<anyhow::Result<Vec<Vec<f64>>>>()?;
        cfg.backhaul_matrix = Some(matrix);
    }
    if let Some(p) = rest_opt(rest, "--faults") {
        // A full FaultConfig JSON (scheduled faults, seeds, episode
        // parameters) — the format `FaultConfig::to_json` prints. The
        // scalar flags below override on top of it.
        let text = std::fs::read_to_string(&p)
            .map_err(|e| anyhow::anyhow!("--faults {p}: {e}"))?;
        cfg.faults = FaultConfig::from_json(&Json::parse(&text)?)?;
    }
    if let Some(m) = rest_opt(rest, "--mttf") {
        cfg.faults.mttf_s = m.parse()?;
    }
    if let Some(m) = rest_opt(rest, "--mttr") {
        cfg.faults.mttr_s = m.parse()?;
    }
    if let Some(s) = rest_opt(rest, "--straggler") {
        // MTBF[:DURATION[:MULT]] — e.g. `--straggler 20:2:6` gives each
        // device a straggler episode every ~20 s lasting ~2 s at 6x.
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            (1..=3).contains(&parts.len()),
            "--straggler takes MTBF[:DURATION[:MULT]], got {s}"
        );
        cfg.faults.straggler_mtbf_s = parts[0].parse()?;
        if let Some(d) = parts.get(1) {
            cfg.faults.straggler_duration_s = d.parse()?;
        }
        if let Some(m) = parts.get(2) {
            cfg.faults.straggler_mult = m.parse()?;
        }
    }
    if let Some(d) = rest_opt(rest, "--deadline") {
        cfg.deadline_s = d.parse()?;
    }
    if rest.iter().any(|a| a == "--hedge") {
        cfg.hedge = true;
    }
    if let Some(r) = rest_opt(rest, "--retries") {
        cfg.max_retries = r.parse()?;
    }
    if let Some(p) = rest_opt(rest, "--energy") {
        // A full EnergyConfig JSON (per-token joule costs, battery,
        // classes) — the format `EnergyConfig::to_json` prints. The
        // scalar flags below override on top of it.
        let text = std::fs::read_to_string(&p)
            .map_err(|e| anyhow::anyhow!("--energy {p}: {e}"))?;
        cfg.energy = EnergyConfig::from_json(&Json::parse(&text)?)?;
    }
    if let Some(w) = rest_opt(rest, "--energy-weight") {
        cfg.energy_weight = w.parse()?;
    }
    if let Some(b) = rest_opt(rest, "--battery") {
        cfg.energy.battery_j = b.parse()?;
    }
    Ok(cfg)
}

fn bench_arg(rest: &[String]) -> anyhow::Result<Benchmark> {
    let bench_name = rest_opt(rest, "--benchmark").unwrap_or_else(|| "PIQA".to_string());
    Benchmark::from_name(&bench_name)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {bench_name}"))
}

#[cfg(feature = "pjrt")]
fn parse_policy(s: &str) -> anyhow::Result<wdmoe::config::PolicyKind> {
    use wdmoe::config::PolicyKind;
    Ok(match s.to_lowercase().as_str() {
        "vanilla" | "topk" | "mixtral" => PolicyKind::VanillaTopK,
        "wdmoe" | "alg1" => PolicyKind::Wdmoe,
        "testbed" | "alg2" => PolicyKind::Testbed,
        "random" => PolicyKind::Random,
        other => anyhow::bail!("unknown policy {other}"),
    })
}

fn main() -> anyhow::Result<()> {
    let args = parse_args()?;
    let ctx = ReproContext {
        out_dir: args.out.clone(),
        artifacts_dir: Some(args.artifacts.clone()),
        quick: args.quick,
        seed: args.seed_or_default(),
    };
    match args.cmd.as_str() {
        "config" => {
            let preset = args.rest.first().map(|s| s.as_str()).unwrap_or("simulation");
            let json = match preset {
                "simulation" => SystemConfig::paper_simulation().to_json(),
                "testbed" => SystemConfig::paper_testbed().to_json(),
                "serving" => SystemConfig::artifact_serving().to_json(),
                "cluster" => ClusterConfig::edge_default().to_json(),
                other => anyhow::bail!("unknown preset {other}"),
            };
            println!("{}", json.to_string());
        }
        "serve" => {
            #[cfg(feature = "pjrt")]
            {
                let requests: usize = rest_opt(&args.rest, "--requests")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(16);
                let bench_name =
                    rest_opt(&args.rest, "--benchmark").unwrap_or_else(|| "PIQA".to_string());
                let bench = Benchmark::from_name(&bench_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown benchmark {bench_name}"))?;
                let kind = parse_policy(
                    &rest_opt(&args.rest, "--policy").unwrap_or_else(|| "wdmoe".to_string()),
                )?;
                let cfg = match &args.config {
                    Some(p) => SystemConfig::from_json_file(p)?,
                    None => SystemConfig::artifact_serving(),
                };
                serve(&args.artifacts, cfg, bench, kind, requests, args.seed_or_default())?;
            }
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!(
                "`serve` executes the AOT artifacts via PJRT — rebuild with \
                 `cargo build --release --features pjrt` (see rust/Cargo.toml)"
            );
        }
        "cluster" => cluster_cmd(&args)?,
        "trace" => trace_cmd(&args)?,
        "sweep" => sweep_cmd(&args)?,
        "bench" => bench_cmd(&args)?,
        "fig5" => drop(repro::fig5(&ctx)?),
        "fig6" => drop(repro::fig6(&ctx)?),
        "fig7" => drop(repro::fig7(&ctx)?),
        "fig8" => drop(repro::fig8(&ctx)?),
        "fig10" => drop(repro::fig10(&ctx)?),
        "table1" => drop(repro::capability::table1(&ctx)?),
        "table2" => drop(repro::table2(&ctx)?),
        "table3" => drop(repro::capability::table3(&ctx)?),
        "table4" => drop(repro::table4(&ctx)?),
        "ablate" => repro::ablations::all(&ctx)?,
        "all" => repro::all(&ctx)?,
        other => anyhow::bail!("unknown command {other}\n{USAGE}"),
    }
    Ok(())
}

/// `repro cluster` — multi-cell DES arrival-rate sweep (a one-axis grid
/// of the experiment API, kept in its historical shape).
fn cluster_cmd(args: &Args) -> anyhow::Result<()> {
    let mut cfg = cluster_base_config(args)?;
    let compare = match rest_opt(&args.rest, "--control") {
        Some(s) if s == "compare" => true,
        Some(s) => {
            cfg.control = ControlKind::parse(&s)?;
            false
        }
        None => false,
    };
    let bench = bench_arg(&args.rest)?;
    let requests: usize = rest_opt(&args.rest, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(if args.quick { 120 } else { 400 });
    let rates: Vec<f64> = match rest_opt(&args.rest, "--rates") {
        Some(csv) => csv
            .split(',')
            .map(|s| s.trim().parse::<f64>().map_err(anyhow::Error::from))
            .collect::<anyhow::Result<Vec<f64>>>()?,
        None if args.quick => vec![0.5, 1.0, 2.0, 4.0],
        None => vec![0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0],
    };
    anyhow::ensure!(!rates.is_empty(), "--rates must name at least one rate");
    anyhow::ensure!(
        rates.iter().all(|r| r.is_finite() && *r > 0.0),
        "--rates must be finite and positive, got {rates:?}"
    );
    // 0 = one worker per core (the default). Output is merged in
    // canonical point order, so any thread count yields the same CSVs.
    let threads: usize = rest_opt(&args.rest, "--threads")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let trace_path = rest_opt(&args.rest, "--trace").map(PathBuf::from);
    let timeline_path = rest_opt(&args.rest, "--timeline").map(PathBuf::from);
    anyhow::ensure!(
        !(compare && (trace_path.is_some() || timeline_path.is_some())),
        "--trace/--timeline export a single run; not available with --control compare"
    );

    println!(
        "cluster sweep: {} cells, cache {}, dispatch {}, control {}, handover {}, \
         {} x {} requests, rates {:?}, {} workers",
        cfg.n_cells(),
        cfg.cache_capacity,
        cfg.dispatch.as_str(),
        if compare { "compare" } else { cfg.control.as_str() },
        cfg.handover.as_str(),
        bench.name(),
        requests,
        rates,
        wdmoe::exec::resolve_threads(threads)
    );
    if compare {
        let table = control_plane_sweep(&cfg, &rates, requests, bench, cfg.seed, threads)?;
        println!("{}", table.render());
        let p = table.write_csv(&args.out)?;
        println!("  -> {}\n", p.display());
        return Ok(());
    }
    let sweep = arrival_rate_sweep(&cfg, &rates, requests, bench, cfg.seed, threads)?;
    println!("{}", sweep.summary.render());
    let p = sweep.summary.write_csv(&args.out)?;
    println!("  -> {}\n", p.display());
    println!("{}", sweep.utilization.render());
    let p = sweep.utilization.write_csv(&args.out)?;
    println!("  -> {}\n", p.display());
    // A one-rate sweep is a single run: surface the control-plane and
    // solver activity the CSV only aggregates.
    if rates.len() == 1 {
        print_single_run(rates[0], &sweep.points[0].outcome);
    }
    // Telemetry export replays the *first* rate's exact arrival stream
    // through an instrumented run; probes never perturb, so the traced
    // outcome is bit-equal to the sweep's first row.
    if trace_path.is_some() || timeline_path.is_some() {
        run_traced(
            &cfg,
            rates[0],
            requests,
            bench,
            1,
            0.05,
            threads,
            trace_path.as_deref(),
            timeline_path.as_deref(),
        )?;
    }
    Ok(())
}

/// `repro trace` — one telemetry-instrumented DES run: Chrome
/// trace-event JSON (Perfetto / chrome://tracing) plus a sim-time
/// timeline CSV.
fn trace_cmd(args: &Args) -> anyhow::Result<()> {
    let mut cfg = cluster_base_config(args)?;
    if let Some(c) = rest_opt(&args.rest, "--control") {
        cfg.control = ControlKind::parse(&c)?;
    }
    let bench = bench_arg(&args.rest)?;
    let rate: f64 = rest_opt(&args.rest, "--rate")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4.0);
    anyhow::ensure!(
        rate.is_finite() && rate > 0.0,
        "--rate must be finite and positive, got {rate}"
    );
    let requests: usize = rest_opt(&args.rest, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(if args.quick { 40 } else { 120 });
    // Keep every Nth request's lane in the trace (1 = all of them).
    let sample_every: usize = rest_opt(&args.rest, "--sample-every")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    let timeline_dt: f64 = rest_opt(&args.rest, "--timeline-dt")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.05);
    anyhow::ensure!(
        timeline_dt.is_finite() && timeline_dt > 0.0,
        "--timeline-dt must be finite and positive, got {timeline_dt}"
    );
    // The sharded engine replays telemetry in canonical order, so any
    // thread count writes byte-identical artifacts; 1 (the default) is
    // the serial loop, 0 = one worker per core.
    let threads: usize = rest_opt(&args.rest, "--threads")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    let trace_path = rest_opt(&args.rest, "--trace")
        .map(PathBuf::from)
        .unwrap_or_else(|| args.out.join("trace.json"));
    let timeline_path = rest_opt(&args.rest, "--timeline")
        .map(PathBuf::from)
        .unwrap_or_else(|| args.out.join("timeline.csv"));
    println!(
        "trace: {} cells, control {}, handover {}, {} x {} requests @ {} rps",
        cfg.n_cells(),
        cfg.control.as_str(),
        cfg.handover.as_str(),
        bench.name(),
        requests,
        rate
    );
    let out = run_traced(
        &cfg,
        rate,
        requests,
        bench,
        sample_every,
        timeline_dt,
        threads,
        Some(&trace_path),
        Some(&timeline_path),
    )?;
    print_single_run(rate, &out);
    Ok(())
}

/// Run one instrumented simulation and write the requested artifacts.
/// The arrival stream is the one `repro cluster`'s first sweep point
/// uses (same seed derivation), so the outcomes line up exactly.
#[allow(clippy::too_many_arguments)]
fn run_traced(
    cfg: &ClusterConfig,
    rate: f64,
    requests: usize,
    bench: Benchmark,
    sample_every: usize,
    timeline_dt: f64,
    threads: usize,
    trace_path: Option<&Path>,
    timeline_path: Option<&Path>,
) -> anyhow::Result<ClusterOutcome> {
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: rate }.generate(requests, bench, cfg.seed);
    let mut sim = ClusterSim::new(cfg)?;
    let mut probe = (
        ChromeTracer::with_sample_every(sample_every),
        TimelineSampler::new((timeline_dt * 1e9) as u64),
    );
    // Sharded when threads and the handover policy allow it, serial
    // otherwise — byte-identical artifacts either way.
    let out = sim.run_sharded_probed(&arrivals, threads, &mut probe);
    let (tracer, sampler) = probe;
    if let Some(p) = trace_path {
        if let Some(dir) = p.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(p, tracer.to_json().to_string())?;
        println!("  trace ({} events) -> {}", tracer.len(), p.display());
    }
    if let Some(p) = timeline_path {
        if let Some(dir) = p.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(p, sampler.to_csv())?;
        println!(
            "  timeline ({} samples) -> {}",
            sampler.rows().len(),
            p.display()
        );
    }
    Ok(out)
}

/// Human-readable detail for a single DES run: outcome counters plus
/// the per-cell control-plane activity and aggregated P3 solver cost
/// the sweep CSVs only carry as totals.
fn print_single_run(rate: f64, out: &ClusterOutcome) {
    println!(
        "single run @ {rate} rps: {} arrived, {} completed, {} dropped, \
         makespan {:.3} s, p95 {:.2} ms",
        out.arrived,
        out.completed,
        out.dropped,
        out.makespan_s,
        out.p95_ms()
    );
    for (ci, ctl) in out.control.iter().enumerate() {
        println!(
            "  cell {ci}: resolves {}, placement updates {}, churn {:.3}",
            ctl.resolves, ctl.placement_updates, ctl.churn_frac
        );
    }
    let s = &out.solver;
    if s.solves > 0 {
        println!(
            "  solver: {} solves ({} warm / {} cold), iterations mean {:.1} max {}, \
             {} converged",
            s.solves,
            s.warm,
            s.cold,
            out.solver_iters_mean(),
            s.iterations_max,
            s.converged
        );
    } else {
        println!("  solver: no P3 solves (static-uniform plane)");
    }
    if out.energy_j > 0.0 {
        println!(
            "  energy: {:.1} J total ({:.4} J/token), fleet lifetime {:.3} s, \
             {} depleted device(s)",
            out.energy_j,
            out.joules_per_token(),
            out.fleet_lifetime_s(),
            out.depleted_devices()
        );
        for (ci, &j) in out.energy_cells.iter().enumerate() {
            let devices = out.utilization.get(ci).map_or(0, Vec::len);
            let depleted = out.depleted_cells.get(ci).copied().unwrap_or(0);
            println!(
                "    cell {ci}: {:.1} J, {}/{} devices never depleted",
                j,
                devices.saturating_sub(depleted),
                devices
            );
        }
    }
}

/// `repro sweep` — a typed experiment grid over any set of axes.
fn sweep_cmd(args: &Args) -> anyhow::Result<()> {
    let mut cfg = cluster_base_config(args)?;
    if let Some(c) = rest_opt(&args.rest, "--control") {
        cfg.control = ControlKind::parse(&c)?; // base plane; sweep planes via --axis control=…
    }
    let bench = bench_arg(&args.rest)?;
    let requests: usize = rest_opt(&args.rest, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(if args.quick { 60 } else { 200 });
    let threads: usize = rest_opt(&args.rest, "--threads")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let specs = rest_all(&args.rest, "--axis")?;
    anyhow::ensure!(
        !specs.is_empty(),
        "repro sweep needs at least one --axis NAME=SPEC \
         (e.g. --axis rate=0.5,1,2 or --axis queue_limit=0:0.5:2)"
    );
    let mut grid = Grid::new(Scenario::new(cfg, requests, bench));
    for s in &specs {
        grid = grid.axis_spec(AxisSpec::parse(s)?);
    }
    println!(
        "experiment grid: {} points over {} axes ({}), {} x {} requests, {} workers",
        grid.len(),
        grid.axes().len(),
        grid.axes()
            .iter()
            .map(|(a, vs)| format!("{}[{}]", a.as_str(), vs.len()))
            .collect::<Vec<_>>()
            .join(" x "),
        bench.name(),
        requests,
        wdmoe::exec::resolve_threads(threads)
    );
    let result = grid.run(threads)?;
    let table = result.table(&format!("Experiment grid — {}", bench.name()))?;
    println!("{}", table.render());
    let p = table.write_csv(&args.out)?;
    println!("  -> {}\n", p.display());
    if args.rest.iter().any(|a| a == "--json") {
        std::fs::create_dir_all(&args.out)?;
        let jp = args.out.join("experiment_grid.json");
        std::fs::write(&jp, result.to_json().to_string())?;
        println!("  -> {}", jp.display());
    }
    Ok(())
}

/// `repro bench` — named performance harnesses with optional JSON
/// output, seeding the perf trajectory with comparable numbers.
fn bench_cmd(args: &Args) -> anyhow::Result<()> {
    let json = args.rest.iter().any(|a| a == "--json");
    let smoke = args.rest.iter().any(|a| a == "--smoke");
    let suite = wdmoe::repro::benchsuite::run_suite(smoke);
    if json {
        let path = std::path::Path::new("BENCH_cluster.json");
        std::fs::write(path, suite.to_json().to_string())?;
        println!("  -> {}", path.display());
    }
    Ok(())
}

/// End-to-end serving: router + batcher + PJRT model + wireless sim.
#[cfg(feature = "pjrt")]
fn serve(
    artifacts: &PathBuf,
    cfg: SystemConfig,
    bench: Benchmark,
    kind: wdmoe::config::PolicyKind,
    requests: usize,
    seed: u64,
) -> anyhow::Result<()> {
    use wdmoe::config::PolicyKind;
    use wdmoe::coordinator::batcher::BatcherConfig;
    use wdmoe::coordinator::router::{spawn_router, InferenceRequest};
    use wdmoe::model::{ServingEngine, ServingModel};
    use wdmoe::moe::selection::make_policy;
    use wdmoe::wireless::bandwidth::{BandwidthAllocator, OptimalAllocator, UniformAllocator};
    use wdmoe::workload::WorkloadGen;

    let n_dev = cfg.n_devices();
    let policy = make_policy(kind, &cfg.policy, n_dev, seed);
    let allocator: Box<dyn BandwidthAllocator> = match kind {
        PolicyKind::VanillaTopK | PolicyKind::Random => Box::new(UniformAllocator),
        _ => Box::new(OptimalAllocator::default()),
    };
    // The AOT seq_len/vocab come from the manifest the model will load.
    let manifest = wdmoe::runtime::Manifest::load(artifacts)?;
    let seq_len = manifest.config.seq_len;
    let vocab = manifest.config.vocab;
    println!(
        "serving {} ({:.1}M params), policy={}, {} devices",
        artifacts.display(),
        manifest.config.total_params as f64 / 1e6,
        kind.as_str(),
        n_dev
    );
    let artifacts_cl = artifacts.clone();
    let handle = spawn_router(
        move || {
            let model = ServingModel::load(&artifacts_cl, cfg)?;
            Ok(ServingEngine {
                model,
                policy,
                allocator,
            })
        },
        BatcherConfig {
            max_tokens: seq_len,
            max_prompts: 64,
            max_wait: std::time::Duration::from_millis(10),
        },
    );
    let mut wl = WorkloadGen::new(seed, vocab);
    // Sanctioned wall-clock read: CLI-level elapsed-time report.
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..requests {
        let batch = wl.batch(bench);
        let len = batch.prompt_lens[0].min(seq_len);
        let ids = batch.token_ids[..len].to_vec();
        rxs.push(handle.infer_async(InferenceRequest { token_ids: ids })?);
    }
    let mut sim_lat = wdmoe::metrics::Summary::new();
    let mut compute = wdmoe::metrics::Summary::new();
    for rx in rxs {
        let r = rx.recv()??;
        sim_lat.record(r.batch_latency_ms);
        compute.record(r.batch_compute_ms);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {requests} requests in {wall:.2}s wall ({:.1} req/s)",
        requests as f64 / wall
    );
    println!(
        "simulated wireless latency/batch: mean {:.2} ms  p50 {:.2}  p95 {:.2}",
        sim_lat.mean(),
        sim_lat.percentile(50.0),
        sim_lat.percentile(95.0)
    );
    println!(
        "PJRT compute/batch: mean {:.1} ms  p95 {:.1} ms",
        compute.mean(),
        compute.percentile(95.0)
    );
    Ok(())
}
