//! Capability probes — paper Tables I and III.
//!
//! The paper scores Mixtral-with-WDMoE-routing on eight public benchmarks
//! (OpenCompass). We cannot re-run 47B-parameter Mixtral; what the tables
//! actually establish is that **WDMoE's latency-aware selection does not
//! degrade model capability vs vanilla top-2 routing**. That claim is
//! measurable on our AOT model directly: run the same token batches
//! through the PJRT model under both routings and measure (a) argmax
//! next-token agreement and (b) mean KL divergence of the output
//! distributions. Agreement ≈ 100% and KL ≈ 0 reproduce "no capability
//! deterioration"; the paper's absolute benchmark scores are printed
//! alongside as the published reference.

use super::ReproContext;
use crate::config::PolicyKind;
#[cfg(feature = "pjrt")]
use crate::config::SystemConfig;
use crate::metrics::Table;
#[cfg(feature = "pjrt")]
use crate::model::ServingModel;
#[cfg(feature = "pjrt")]
use crate::moe::selection::make_policy;
#[cfg(feature = "pjrt")]
use crate::wireless::bandwidth::{OptimalAllocator, UniformAllocator};
use crate::workload::Benchmark;
#[cfg(feature = "pjrt")]
use crate::workload::WorkloadGen;

/// Paper Table I reference scores (%): rows are models, columns the eight
/// benchmarks in paper order.
pub const TABLE1_PAPER: [(&str, [f64; 8]); 6] = [
    //                 MMLU  PIQA  ARC-E ARC-C Heval GSM8K BoolQ MBPP
    ("Llama 2 7B", [46.8, 78.3, 56.1, 40.3, 12.8, 16.7, 74.9, 14.8]),
    ("Llama 2 13B", [55.0, 79.8, 71.8, 60.3, 18.9, 29.6, 82.4, 26.8]),
    ("Llama 2 70B", [69.7, 82.5, 85.9, 78.3, 26.2, 63.5, 87.7, 39.6]),
    ("Mistral 7B-v0.1", [64.1, 81.6, 83.6, 74.2, 22.6, 47.5, 84.1, 32.0]),
    ("Mixtral 8x7B-Instruct", [70.9, 83.2, 92.8, 84.8, 47.6, 70.0, 88.72, 35.2]),
    ("WDMoE (paper)", [68.98, 83.2, 92.8, 86.78, 48.17, 71.29, 88.87, 35.2]),
];

/// Paper Table III reference (testbed accuracy, %).
pub const TABLE3_PAPER: [(&str, [f64; 4]); 2] = [
    ("Mixtral", [92.42, 86.1, 37.8, 83.41]),
    ("WDMoE-testbed", [92.95, 87.12, 38.8, 83.51]),
];

/// Outcome of comparing a policy against the vanilla top-2 baseline.
///
/// Note on metrics: our AOT model is random-init, so its logits are flat
/// across the vocabulary and argmax is hypersensitive — argmax agreement
/// is a pessimistic lower bound. KL divergence and logit cosine measure
/// the actual distributional shift (a trained model's peaked logits would
/// push argmax agreement toward 100% at the same KL).
#[cfg(feature = "pjrt")]
pub struct ProbeResult {
    /// Fraction of positions whose argmax next-token matches baseline.
    pub argmax_agreement: f64,
    /// Fraction of positions where the policies' top-5 sets intersect.
    pub top5_overlap: f64,
    /// Mean KL(baseline ‖ policy) over positions (nats).
    pub mean_kl: f64,
    /// Mean cosine similarity between logit vectors.
    pub logit_cosine: f64,
}

#[cfg(feature = "pjrt")]
fn top_k_set(row: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(feature = "pjrt")]
fn cosine32(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

#[cfg(feature = "pjrt")]
fn softmax(row: &[f32]) -> Vec<f64> {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = row.iter().map(|&l| ((l as f64) - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.iter().map(|e| e / sum).collect()
}

/// Compare `policy_kind` (+ optimal bandwidth) against vanilla top-2
/// (+ uniform bandwidth) on `n_batches` of `bench`-scale token batches.
#[cfg(feature = "pjrt")]
pub fn probe(
    model: &mut ServingModel,
    bench: Benchmark,
    policy_kind: PolicyKind,
    seed: u64,
    n_batches: usize,
) -> anyhow::Result<ProbeResult> {
    let vocab = model.vocab();
    let j = model.seq_len();
    // Salt the workload seed per benchmark so each row probes distinct
    // token streams.
    let salt = Benchmark::ALL.iter().position(|&b| b == bench).unwrap_or(0) as u64;
    let mut wl = WorkloadGen::new(seed ^ (salt.wrapping_mul(0x9E37_79B9)), vocab);
    let mut agree = 0usize;
    let mut top5 = 0usize;
    let mut total = 0usize;
    let mut kl_sum = 0.0f64;
    let mut cos_sum = 0.0f64;
    for _ in 0..n_batches {
        let batch = wl.batch(bench);
        let ids: Vec<i32> = batch.token_ids.iter().copied().take(j).collect();
        let n_active = ids.len().min(j);
        let mut pv = make_policy(PolicyKind::VanillaTopK, &model.cfg.policy, model.cfg.n_devices(), seed);
        let base = model.forward(&ids, pv.as_mut(), &UniformAllocator)?;
        let mut pp = make_policy(policy_kind, &model.cfg.policy, model.cfg.n_devices(), seed);
        let out = model.forward(&ids, pp.as_mut(), &OptimalAllocator::default())?;
        for pos in 0..n_active {
            let a = model.argmax_at(&base.logits, pos);
            let b = model.argmax_at(&out.logits, pos);
            if a == b {
                agree += 1;
            }
            let rb = &base.logits[pos * vocab..(pos + 1) * vocab];
            let ro = &out.logits[pos * vocab..(pos + 1) * vocab];
            let sb = top_k_set(rb, 5);
            let so = top_k_set(ro, 5);
            if sb.iter().any(|x| so.contains(x)) {
                top5 += 1;
            }
            cos_sum += cosine32(rb, ro);
            total += 1;
            let p = softmax(rb);
            let q = softmax(ro);
            kl_sum += p
                .iter()
                .zip(&q)
                .map(|(&pi, &qi)| if pi > 0.0 { pi * (pi / qi.max(1e-12)).ln() } else { 0.0 })
                .sum::<f64>();
        }
    }
    Ok(ProbeResult {
        argmax_agreement: agree as f64 / total as f64,
        top5_overlap: top5 as f64 / total as f64,
        mean_kl: kl_sum / total as f64,
        logit_cosine: cos_sum / total as f64,
    })
}

#[cfg(feature = "pjrt")]
fn load_model(ctx: &ReproContext) -> Option<ServingModel> {
    let dir = ctx.artifacts_dir.clone()?;
    match ServingModel::load(&dir, SystemConfig::artifact_serving()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("capability probe skipped (artifacts unavailable): {e}");
            None
        }
    }
}

/// Measured fidelity rows for the given policy, one per benchmark.
/// Without the `pjrt` feature (or without artifacts) measurement is
/// skipped and only the paper-reference tables are emitted.
#[cfg(feature = "pjrt")]
fn probe_rows(
    ctx: &ReproContext,
    kind: PolicyKind,
    benches: &[Benchmark],
) -> anyhow::Result<Vec<(String, Vec<f64>)>> {
    let Some(mut model) = load_model(ctx) else {
        println!("(measurement skipped: build artifacts with `make artifacts`)");
        return Ok(vec![]);
    };
    let mut rows = Vec::new();
    for &bench in benches {
        let r = probe(&mut model, bench, kind, ctx.seed, 1)?;
        rows.push((
            bench.name().to_string(),
            vec![
                r.argmax_agreement * 100.0,
                r.top5_overlap * 100.0,
                r.mean_kl,
                r.logit_cosine,
            ],
        ));
    }
    Ok(rows)
}

#[cfg(not(feature = "pjrt"))]
fn probe_rows(
    _ctx: &ReproContext,
    _kind: PolicyKind,
    _benches: &[Benchmark],
) -> anyhow::Result<Vec<(String, Vec<f64>)>> {
    println!("(measurement skipped: PJRT disabled — rebuild with `--features pjrt`)");
    Ok(vec![])
}

/// Table I: capability under WDMoE routing (Algorithm 1).
pub fn table1(ctx: &ReproContext) -> anyhow::Result<Table> {
    let mut ref_t = Table::new(
        "Table I — benchmark scores, paper reference (%)",
        &["MMLU", "PIQA", "ARC-E", "ARC-C", "Humaneval", "GSM-8K", "BoolQ", "MBPP"],
    );
    for (label, vals) in TABLE1_PAPER {
        ref_t.row(label, vals.to_vec());
    }
    ctx.emit(&ref_t)?;

    let mut t = Table::new(
        "Table I — measured routing fidelity: WDMoE (Alg 1) vs vanilla top-2",
        &["argmax_agreement_pct", "top5_overlap_pct", "mean_kl_nats", "logit_cosine"],
    );
    t.precision = 4;
    for (label, vals) in probe_rows(ctx, PolicyKind::Wdmoe, &Benchmark::ALL)? {
        t.row(&label, vals);
    }
    ctx.emit(&t)?;
    Ok(t)
}

/// Table III: capability under the testbed policy (Algorithm 2).
pub fn table3(ctx: &ReproContext) -> anyhow::Result<Table> {
    let mut ref_t = Table::new(
        "Table III — testbed accuracy, paper reference (%)",
        &["ARC-E", "ARC-C", "MBPP", "PIQA"],
    );
    for (label, vals) in TABLE3_PAPER {
        ref_t.row(label, vals.to_vec());
    }
    ctx.emit(&ref_t)?;

    let mut t = Table::new(
        "Table III — measured routing fidelity: WDMoE-testbed (Alg 2) vs vanilla top-2",
        &["argmax_agreement_pct", "top5_overlap_pct", "mean_kl_nats", "logit_cosine"],
    );
    t.precision = 4;
    let testbed_benches = [
        Benchmark::ArcEasy,
        Benchmark::ArcChallenge,
        Benchmark::Mbpp,
        Benchmark::Piqa,
    ];
    for (label, vals) in probe_rows(ctx, PolicyKind::Testbed, &testbed_benches)? {
        t.row(&label, vals);
    }
    ctx.emit(&t)?;
    Ok(t)
}
