//! Reproduction harnesses — one per paper table and figure.
//!
//! Each function regenerates the corresponding result with this repo's
//! substrate (see DESIGN.md §Substitutions), prints the same rows/series
//! the paper reports (with the paper's numbers alongside as reference),
//! and writes CSVs into the output directory. Absolute magnitudes depend
//! on the simulated testbed; the *shape* — who wins, by what factor,
//! where crossovers fall — is the reproduction target (EXPERIMENTS.md).

pub mod ablations;
pub mod benchsuite;
pub mod capability;

use crate::config::SystemConfig;
use crate::coordinator::sim::{Simulator, Variant};
use crate::metrics::{Summary, Table};
use crate::moe::selection::make_policy;
use crate::moe::stats::max_same_selection_ratio;
use crate::testbed::TestbedSim;
use crate::workload::{Benchmark, WorkloadGen};
use std::path::PathBuf;

/// Shared harness context.
pub struct ReproContext {
    /// Where CSVs land.
    pub out_dir: PathBuf,
    /// AOT artifacts (needed by the capability probes, Tables I/III).
    pub artifacts_dir: Option<PathBuf>,
    /// Fewer batches / coarser sweeps for CI.
    pub quick: bool,
    /// Base seed.
    pub seed: u64,
}

impl ReproContext {
    pub fn batches(&self) -> usize {
        if self.quick {
            1
        } else {
            3
        }
    }

    fn emit(&self, t: &Table) -> anyhow::Result<()> {
        println!("{}", t.render());
        let p = t.write_csv(&self.out_dir)?;
        println!("  -> {}\n", p.display());
        Ok(())
    }
}

/// Fresh simulator with a derived seed (same seed ⇒ same gate stream, so
/// variants compare on identical routing).
fn fresh_sim(seed: u64) -> Simulator {
    let mut cfg = SystemConfig::paper_simulation();
    cfg.seed = seed;
    Simulator::new(cfg)
}

/// Mean latency (ms) of `variant` on `bench` over `batches` batches.
fn mean_latency_ms(bench: Benchmark, variant: Variant, seed: u64, batches: usize) -> f64 {
    let mut s = Summary::new();
    for b in 0..batches {
        let run_seed = seed.wrapping_add(b as u64 * 1009);
        let mut wl = WorkloadGen::new(run_seed, 32000);
        let tokens = wl.batch(bench).total_tokens();
        let mut sim = fresh_sim(run_seed);
        s.record(sim.run_variant(tokens, variant).latency_ms());
    }
    s.mean()
}

// ---------------------------------------------------------------- Fig. 5

/// Fig. 5: latency per batch vs total bandwidth (ARC-C dataset).
pub fn fig5(ctx: &ReproContext) -> anyhow::Result<Table> {
    let sweep_mhz: Vec<f64> = if ctx.quick {
        vec![20.0, 60.0, 100.0, 140.0, 180.0]
    } else {
        (2..=20).map(|i| i as f64 * 10.0).collect()
    };
    let mut t = Table::new(
        "Fig 5 — Latency per batch vs total bandwidth, ARC-C (ms)",
        &["bandwidth_mhz", "mixtral_based_ms", "wdmoe_ms"],
    );
    for &mhz in &sweep_mhz {
        let mut lat = [0.0f64; 2];
        for (vi, v) in [Variant::mixtral_based(), Variant::wdmoe_full()]
            .into_iter()
            .enumerate()
        {
            let mut total = 0.0;
            for b in 0..ctx.batches() {
                let run_seed = ctx.seed.wrapping_add(b as u64 * 1009);
                let mut wl = WorkloadGen::new(run_seed, 32000);
                let tokens = wl.batch(Benchmark::ArcChallenge).total_tokens();
                let mut cfg = SystemConfig::paper_simulation();
                cfg.seed = run_seed;
                cfg.channel.total_bandwidth_hz = mhz * 1e6;
                let mut sim = Simulator::new(cfg);
                total += sim.run_variant(tokens, v).latency_ms();
            }
            lat[vi] = total / ctx.batches() as f64;
        }
        t.row(&format!("B={mhz:.0}MHz"), vec![mhz, lat[0], lat[1]]);
    }
    ctx.emit(&t)?;
    Ok(t)
}

// ---------------------------------------------------------------- Fig. 6

/// Paper Fig. 6 reference reductions (% latency vs Mixtral-based).
pub const FIG6_PAPER_REDUCTION: [(Benchmark, f64); 8] = [
    (Benchmark::Humaneval, 41.40),
    (Benchmark::Mbpp, 47.14),
    (Benchmark::Gsm8k, 41.96),
    (Benchmark::Mmlu, 40.41),
    (Benchmark::Piqa, 42.03),
    (Benchmark::ArcEasy, 45.14),
    (Benchmark::ArcChallenge, 47.50),
    (Benchmark::Boolq, 42.19),
];

/// Fig. 6: average latency per batch across all eight datasets.
pub fn fig6(ctx: &ReproContext) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 6 — Avg latency per batch by dataset (ms)",
        &["mixtral_based_ms", "wdmoe_ms", "reduction_pct", "paper_reduction_pct"],
    );
    for (bench, paper_red) in FIG6_PAPER_REDUCTION {
        let m = mean_latency_ms(bench, Variant::mixtral_based(), ctx.seed, ctx.batches());
        let w = mean_latency_ms(bench, Variant::wdmoe_full(), ctx.seed, ctx.batches());
        let red = (1.0 - w / m) * 100.0;
        t.row(bench.name(), vec![m, w, red, paper_red]);
    }
    ctx.emit(&t)?;
    Ok(t)
}

// ---------------------------------------------------------------- Fig. 7

/// Fig. 7: ablation — latency vs number of tokens (ARC-C-scale), 4 arms.
pub fn fig7(ctx: &ReproContext) -> anyhow::Result<Table> {
    let token_sweep: Vec<usize> = if ctx.quick {
        vec![500, 2000, 4000]
    } else {
        vec![250, 500, 1000, 2000, 3000, 4000, 5000, 6000]
    };
    let mut t = Table::new(
        "Fig 7 — Ablation latency vs tokens, ARC-C (ms)",
        &[
            "mixtral_based",
            "wdmoe_wo_bandwidth",
            "wdmoe_wo_selection",
            "wdmoe",
        ],
    );
    for &n in &token_sweep {
        let vals: Vec<f64> = [
            Variant::mixtral_based(),
            Variant::wdmoe_no_bandwidth(),
            Variant::wdmoe_no_selection(),
            Variant::wdmoe_full(),
        ]
        .into_iter()
        .map(|v| fresh_sim(ctx.seed).run_variant(n, v).latency_ms())
        .collect();
        t.row(&format!("J={n}"), vals);
    }
    ctx.emit(&t)?;
    Ok(t)
}

// --------------------------------------------------------------- Table II

/// Paper Table II reference values (Latency/batch, ms).
pub const TABLE2_PAPER: [(Benchmark, [f64; 4]); 8] = [
    (Benchmark::Mmlu, [298813.6, 258884.0, 195383.3, 172743.9]),
    (Benchmark::Piqa, [37183.1, 33861.6, 22114.1, 19522.2]),
    (Benchmark::ArcEasy, [36401.5, 35043.3, 22774.5, 21692.0]),
    (Benchmark::ArcChallenge, [40367.1, 37584.2, 25598.4, 23400.0]),
    (Benchmark::Humaneval, [572.6, 527.3, 335.2, 305.9]),
    (Benchmark::Gsm8k, [1661.6, 1491.5, 1066.0, 964.5]),
    (Benchmark::Boolq, [109957.8, 106806.9, 66684.0, 63991.0]),
    (Benchmark::Mbpp, [847.9, 700.9, 538.1, 448.2]),
];

/// Table II: latency/batch for all four component arms on every dataset.
pub fn table2(ctx: &ReproContext) -> anyhow::Result<Table> {
    let arms = [
        Variant::mixtral_based(),
        Variant::wdmoe_no_bandwidth(),
        Variant::wdmoe_no_selection(),
        Variant::wdmoe_full(),
    ];
    let mut t = Table::new(
        "Table II — Latency per batch (ms), measured",
        &["MMLU", "PIQA", "ARC-E", "ARC-C", "Humaneval", "GSM-8K", "BoolQ", "MBPP"],
    );
    let order = [
        Benchmark::Mmlu,
        Benchmark::Piqa,
        Benchmark::ArcEasy,
        Benchmark::ArcChallenge,
        Benchmark::Humaneval,
        Benchmark::Gsm8k,
        Benchmark::Boolq,
        Benchmark::Mbpp,
    ];
    for v in arms {
        let vals: Vec<f64> = order
            .iter()
            .map(|&b| mean_latency_ms(b, v, ctx.seed, ctx.batches()))
            .collect();
        t.row(v.label(), vals);
    }
    ctx.emit(&t)?;

    // Side-by-side paper reference.
    let mut p = Table::new(
        "Table II — Latency per batch (ms), paper reference",
        &["MMLU", "PIQA", "ARC-E", "ARC-C", "Humaneval", "GSM-8K", "BoolQ", "MBPP"],
    );
    for (ai, v) in arms.iter().enumerate() {
        let vals: Vec<f64> = order
            .iter()
            .map(|&b| {
                TABLE2_PAPER
                    .iter()
                    .find(|(bb, _)| *bb == b)
                    .map(|(_, vals)| vals[ai])
                    .unwrap()
            })
            .collect();
        p.row(v.label(), vals);
    }
    ctx.emit(&p)?;
    Ok(t)
}

// ---------------------------------------------------------------- Fig. 8

/// Fig. 8: max ratio of identical expert selection, blocks 1/16/32.
pub fn fig8(ctx: &ReproContext) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 8 — Max same-expert-selection ratio per batch",
        &["layer_1", "layer_16", "layer_32"],
    );
    for bench in Benchmark::ALL {
        let mut wl = WorkloadGen::new(ctx.seed, 32000);
        let tokens = wl.batch(bench).total_tokens();
        let mut sim = fresh_sim(ctx.seed);
        let out = sim.run_variant(tokens, Variant::wdmoe_full());
        let ratio = |i: usize| max_same_selection_ratio(&out.selections[i]);
        t.precision = 3;
        t.row(bench.name(), vec![ratio(0), ratio(15), ratio(31)]);
    }
    ctx.emit(&t)?;
    Ok(t)
}

// ------------------------------------------------------- Fig. 10/Table IV

/// Fig. 10: testbed latency per layer vs number of tokens (mean + band).
pub fn fig10(ctx: &ReproContext) -> anyhow::Result<Table> {
    let token_sweep: Vec<usize> = if ctx.quick {
        vec![20, 60, 120]
    } else {
        vec![10, 20, 40, 60, 80, 120, 160, 200]
    };
    let mut t = Table::new(
        "Fig 10 — Testbed latency per layer vs tokens (ms)",
        &[
            "mixtral_mean",
            "mixtral_min",
            "mixtral_max",
            "wdmoe_mean",
            "wdmoe_min",
            "wdmoe_max",
        ],
    );
    for &n in &token_sweep {
        let mut vals = Vec::new();
        for kind in [crate::config::PolicyKind::VanillaTopK, crate::config::PolicyKind::Testbed] {
            let cfg = SystemConfig::paper_testbed();
            let mut sim = TestbedSim::with_seed(cfg.clone(), ctx.seed);
            let mut policy = make_policy(kind, &cfg.policy, cfg.n_devices(), ctx.seed);
            // Warm the history estimator, then measure.
            let mut mean = Summary::new();
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for b in 0..(ctx.batches() + 2) {
                let out = sim.run_batch(n, policy.as_mut());
                if b >= 2 {
                    mean.record(out.mean_layer_ms);
                    lo = lo.min(out.min_layer_ms);
                    hi = hi.max(out.max_layer_ms);
                }
            }
            vals.extend([mean.mean(), lo, hi]);
        }
        t.precision = 3;
        t.row(&format!("J={n}"), vals);
    }
    ctx.emit(&t)?;
    Ok(t)
}

/// Paper Table IV reference (latency/batch ms, three runs each).
pub const TABLE4_PAPER: [(&str, [f64; 4]); 7] = [
    ("Mixtral-based method-1", [532.8, 1625.0, 38.77, 616.7]),
    ("WDMoE-testbed-1", [468.3, 1228.0, 37.96, 414.3]),
    ("Mixtral-based method-2", [418.1, 2583.0, 33.47, 1380.0]),
    ("WDMoE-testbed-2", [372.6, 1530.0, 29.49, 436.9]),
    ("Mixtral-based method-3", [383.5, 1406.0, 30.72, 519.4]),
    ("WDMoE-testbed-3", [361.9, 656.6, 28.33, 332.0]),
    ("Average Gain (%)", [9.536, 39.523, 7.246, 45.750]),
];

/// Table IV: testbed latency/batch, three seeded runs × four datasets.
pub fn table4(ctx: &ReproContext) -> anyhow::Result<Table> {
    let datasets = [
        Benchmark::ArcEasy,
        Benchmark::ArcChallenge,
        Benchmark::Mbpp,
        Benchmark::Piqa,
    ];
    let mut t = Table::new(
        "Table IV — Testbed latency per batch (ms), measured",
        &["ARC-E", "ARC-C", "MBPP", "PIQA"],
    );
    let mut gains = vec![Summary::new(); 4];
    for run in 1..=3u64 {
        let mut rows: Vec<Vec<f64>> = vec![vec![], vec![]];
        for (di, &bench) in datasets.iter().enumerate() {
            // Testbed batches are single-prompt scale (§VI): one prompt.
            let tokens = bench.mean_prompt_tokens();
            let mut lat = [0.0f64; 2];
            for (pi, kind) in
                [crate::config::PolicyKind::VanillaTopK, crate::config::PolicyKind::Testbed]
                    .into_iter()
                    .enumerate()
            {
                let cfg = SystemConfig::paper_testbed();
                let mut sim = TestbedSim::with_seed(cfg.clone(), ctx.seed.wrapping_add(run * 7919));
                let mut policy =
                    make_policy(kind, &cfg.policy, cfg.n_devices(), ctx.seed.wrapping_add(run));
                // Warm-up batches build Alg-2 history, then measure.
                let mut total = 0.0;
                let reps = 3 + ctx.batches();
                for b in 0..reps {
                    let out = sim.run_batch(tokens, policy.as_mut());
                    if b >= 3 {
                        total += out.per_block.iter().map(|x| x.waiting).sum::<f64>() * 1e3;
                    }
                }
                lat[pi] = total / ctx.batches() as f64;
            }
            rows[0].push(lat[0]);
            rows[1].push(lat[1]);
            gains[di].record((1.0 - lat[1] / lat[0]) * 100.0);
        }
        t.row(&format!("Mixtral-based method-{run}"), rows[0].clone());
        t.row(&format!("WDMoE-testbed-{run}"), rows[1].clone());
    }
    t.row(
        "Average Gain (%)",
        gains.iter().map(|g| g.mean()).collect(),
    );
    ctx.emit(&t)?;

    let mut p = Table::new(
        "Table IV — paper reference",
        &["ARC-E", "ARC-C", "MBPP", "PIQA"],
    );
    for (label, vals) in TABLE4_PAPER {
        p.row(label, vals.to_vec());
    }
    ctx.emit(&p)?;
    Ok(t)
}

/// Run everything (CLI `repro all`).
pub fn all(ctx: &ReproContext) -> anyhow::Result<()> {
    fig5(ctx)?;
    fig6(ctx)?;
    fig7(ctx)?;
    table2(ctx)?;
    fig8(ctx)?;
    fig10(ctx)?;
    table4(ctx)?;
    capability::table1(ctx)?;
    capability::table3(ctx)?;
    ablations::all(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ReproContext {
        ReproContext {
            out_dir: crate::util::temp_dir("repro"),
            artifacts_dir: None,
            quick: true,
            seed: 0,
        }
    }

    #[test]
    fn fig5_latency_decreases_with_bandwidth_and_wdmoe_wins() {
        let t = fig5(&ctx()).unwrap();
        let rows = &t.rows;
        // decreasing in bandwidth
        assert!(rows.first().unwrap().1[1] > rows.last().unwrap().1[1]);
        // WDMoE below Mixtral at every bandwidth
        for (_, v) in rows {
            assert!(v[2] < v[1], "WDMoE {} not below Mixtral {}", v[2], v[1]);
        }
    }

    #[test]
    fn fig7_ablation_ordering() {
        let t = fig7(&ctx()).unwrap();
        for (_, v) in &t.rows {
            assert!(v[3] <= v[0], "full WDMoE must beat Mixtral baseline");
            assert!(v[2] <= v[1], "bandwidth lever bigger than selection lever");
        }
    }

    #[test]
    fn fig8_ratios_in_unit_interval() {
        let t = fig8(&ctx()).unwrap();
        for (_, v) in &t.rows {
            for &r in v {
                assert!((0.0..=1.0).contains(&r));
            }
        }
    }

    #[test]
    fn table4_wdmoe_rows_beat_mixtral_rows() {
        let t = table4(&ctx()).unwrap();
        // final row is average gain; must be positive for every dataset
        let (label, gains) = t.rows.last().unwrap();
        assert!(label.contains("Gain"));
        for &g in gains {
            assert!(g > 0.0, "average gain should be positive, got {g}");
        }
    }
}
