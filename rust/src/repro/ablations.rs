//! Ablations for the design decisions DESIGN.md calls out — beyond the
//! paper's own Fig. 7 arms:
//!
//! 1. **per-block vs global bandwidth allocation** — the interpretation
//!    note behind our P3 implementation (global allocation cannot track
//!    per-block hot experts);
//! 2. **router popularity-bias sensitivity** — how the headline reduction
//!    depends on trained-router load imbalance (the one free calibration
//!    parameter);
//! 3. **Algorithm-1 threshold schedule** — θ_init / WLR-guard sweep, the
//!    latency-vs-fidelity trade-off the paper discusses in §IV-A.

use super::ReproContext;
use crate::config::SystemConfig;
use crate::control::LinkState;
use crate::coordinator::sim::{Simulator, Variant};
use crate::metrics::Table;
use crate::optim::SolverOptions;
use crate::wireless::ChannelSimulator;

/// Ablation 1: re-run the ARC-C-scale batch with one global allocation
/// (solve P3 over all 32 blocks jointly) vs the per-block default.
pub fn global_vs_per_block(ctx: &ReproContext) -> anyhow::Result<Table> {
    let tokens = 3600;
    let mut t = Table::new(
        "Ablation — bandwidth allocation granularity (ARC-C scale, ms)",
        &["latency_ms", "reduction_vs_uniform_pct"],
    );
    // Uniform baseline + per-block optimal from the standard simulator.
    let mut sim = Simulator::new(SystemConfig::paper_simulation());
    let uni = sim.run_variant(tokens, Variant::mixtral_based());
    let mut sim = Simulator::new(SystemConfig::paper_simulation());
    let per_block = sim.run_variant(tokens, Variant::wdmoe_no_selection());

    // Global: take the per-block loads the vanilla policy produced and
    // solve one joint P3, then re-price every block at that split.
    let mut sim = Simulator::new(SystemConfig::paper_simulation());
    let base = sim.run_variant(tokens, Variant::mixtral_based());
    let cfg = SystemConfig::paper_simulation();
    let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, cfg.seed);
    let real = chan.expected_realization();
    let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
    let t_comp: Vec<f64> = cfg.devices.iter().map(|d| l_comp / d.compute_flops).collect();
    let loads: Vec<crate::optim::PerBlockLoad> = base
        .report
        .per_block
        .iter()
        .map(|b| crate::optim::PerBlockLoad {
            tokens: b.tokens_per_device.clone(),
        })
        .collect();
    let state = LinkState::new(
        &cfg.channel,
        &real,
        &t_comp,
        cfg.model.l_comm_bits(cfg.channel.quant_bits),
    );
    let global = state.solve(&loads, &SolverOptions::default(), None);
    let global_ms = global.objective * 1e3;

    let red = |ms: f64| (1.0 - ms / uni.latency_ms()) * 100.0;
    t.row("uniform (baseline)", vec![uni.latency_ms(), 0.0]);
    t.row("global P3 (one split for all blocks)", vec![global_ms, red(global_ms)]);
    t.row("per-block P3 (ours / paper Fig. 4)", vec![per_block.latency_ms(), red(per_block.latency_ms())]);
    ctx.emit(&t)?;
    Ok(t)
}

/// Ablation 2: headline reduction vs router popularity bias.
pub fn bias_sensitivity(ctx: &ReproContext) -> anyhow::Result<Table> {
    let tokens = 3600;
    let mut t = Table::new(
        "Ablation — WDMoE reduction vs router load-imbalance bias",
        &["baseline_ms", "wdmoe_ms", "reduction_pct"],
    );
    for bias in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let run = |v: Variant| {
            let mut sim = Simulator::new(SystemConfig::paper_simulation());
            sim.gate_bias = bias;
            sim.run_variant(tokens, v).latency_ms()
        };
        let m = run(Variant::mixtral_based());
        let w = run(Variant::wdmoe_full());
        t.row(&format!("bias={bias:.1}"), vec![m, w, (1.0 - w / m) * 100.0]);
    }
    ctx.emit(&t)?;
    Ok(t)
}

/// Ablation 3: Algorithm-1 θ_init sweep — load shed vs latency.
pub fn theta_sweep(ctx: &ReproContext) -> anyhow::Result<Table> {
    let tokens = 3600;
    let mut t = Table::new(
        "Ablation — Algorithm 1 threshold schedule (theta_init)",
        &["latency_ms", "transmissions", "wlr_total"],
    );
    for theta in [0.3, 0.5, 0.7, 0.9] {
        let mut cfg = SystemConfig::paper_simulation();
        cfg.policy.theta_init = theta;
        let mut sim = Simulator::new(cfg);
        let out = sim.run_variant(tokens, Variant::wdmoe_full());
        t.row(
            &format!("theta={theta:.1}"),
            vec![
                out.latency_ms(),
                out.report.total_token_transmissions(),
                out.wlr_total,
            ],
        );
    }
    ctx.emit(&t)?;
    Ok(t)
}

/// All three ablations (CLI `repro ablate`).
pub fn all(ctx: &ReproContext) -> anyhow::Result<()> {
    global_vs_per_block(ctx)?;
    bias_sensitivity(ctx)?;
    theta_sweep(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ReproContext {
        ReproContext {
            out_dir: crate::util::temp_dir("ablate"),
            artifacts_dir: None,
            quick: true,
            seed: 0,
        }
    }

    #[test]
    fn per_block_beats_global_beats_uniform() {
        let t = global_vs_per_block(&ctx()).unwrap();
        let uni = t.rows[0].1[0];
        let global = t.rows[1].1[0];
        let per_block = t.rows[2].1[0];
        assert!(global <= uni, "global P3 must not lose to uniform");
        assert!(
            per_block < global,
            "per-block allocation must beat global ({per_block} vs {global})"
        );
    }

    #[test]
    fn reduction_grows_with_bias() {
        let t = bias_sensitivity(&ctx()).unwrap();
        let first = t.rows.first().unwrap().1[2];
        let last = t.rows.last().unwrap().1[2];
        assert!(
            last > first,
            "more load imbalance should grow the allocation win ({first} -> {last})"
        );
    }

    #[test]
    fn higher_theta_sheds_more_load() {
        let t = theta_sweep(&ctx()).unwrap();
        let tx_low = t.rows.first().unwrap().1[1];
        let tx_high = t.rows.last().unwrap().1[1];
        assert!(
            tx_high <= tx_low,
            "higher theta must not increase transmissions ({tx_low} -> {tx_high})"
        );
    }
}
