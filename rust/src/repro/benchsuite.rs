//! `repro bench` — named performance harnesses with JSON output.
//!
//! The single home of the hot-path harnesses: the P3 solver cold vs warm
//! (through the zero-allocation [`SolverWorkspace`] entry point the
//! control plane uses), the adaptive plane's full epoch tick, a
//! load-aware dispatch decision, and whole-DES throughput in simulated
//! events per wall second (the 2-cell run with and without a no-op
//! probe, the same run with an empty fault plan and with the energy
//! model off/on — the off contracts all say "free when unused" — plus
//! the 8-cell serial/sharded twin pair whose
//! events/sec ratio is the sharding speedup). The `cargo bench` binaries
//! (`rust/benches/control.rs`, `rust/benches/cluster.rs`) call these
//! same functions, so the interactive numbers and the
//! `BENCH_cluster.json` CI artifact can never drift apart. `repro bench
//! --json` writes the results to `BENCH_cluster.json` at the repo root,
//! seeding the perf trajectory with named, comparable numbers; the CI
//! smoke run keeps the harnesses from rotting.

use crate::cluster::{ClusterSim, Dispatcher, EnergyScore};
use crate::telemetry::NullProbe;
use crate::config::{ClusterConfig, ControlKind, DispatchKind, SystemConfig};
use crate::control::LinkState;
use crate::devices::Fleet;
use crate::optim::{PerBlockLoad, SolverOptions, SolverWorkspace};
use crate::util::bench::{bench, bench_quiet, default_budget, smoke_budget, BenchResult};
use crate::util::Json;
use crate::wireless::ChannelSimulator;
use crate::workload::{ArrivalProcess, Benchmark};
use std::time::Duration;

/// Results of one `repro bench` run.
pub struct BenchSuite {
    pub smoke: bool,
    pub budget_ms: u64,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    /// The `BENCH_cluster.json` document. A suite written by an actual
    /// run is by definition *measured*, so it carries
    /// `"provisional": false` — `scripts/bench_gate.py` arms its
    /// regression gate against any baseline without the provisional
    /// flag (the hand-seeded pre-measurement baseline set it to true).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("wdmoe-bench-v1")),
            ("provisional", Json::Bool(false)),
            ("smoke", Json::Bool(self.smoke)),
            ("budget_ms", Json::Num(self.budget_ms as f64)),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// The §V 8-device cell every solver harness runs against.
pub fn paper_link_state() -> LinkState {
    let cfg = SystemConfig::paper_simulation();
    let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
    let real = chan.expected_realization();
    let fleet = Fleet::new(&cfg.devices, 0);
    let t_comp = fleet.t_comp_nominal(cfg.model.l_comp_flops(cfg.activation_eta));
    LinkState::new(
        &cfg.channel,
        &real,
        &t_comp,
        cfg.model.l_comm_bits(cfg.channel.quant_bits),
    )
}

/// The 8-device load vector the solver harnesses share.
pub fn solver_load() -> [PerBlockLoad; 1] {
    [PerBlockLoad {
        tokens: (0..8).map(|k| (20 + k * 7) as f64).collect(),
    }]
}

/// P3 solver, cold and warm, through the zero-allocation workspace —
/// the exact path the adaptive plane pays at every epoch tick.
pub fn solver_harnesses(budget: Duration) -> Vec<BenchResult> {
    let state = paper_link_state();
    let opts = SolverOptions::default();
    let loads = solver_load();
    let mut ws = SolverWorkspace::new();
    let mut out = Vec::new();
    let mut results = Vec::new();
    results.push(bench("solver/cold_8dev_ws", budget, || {
        state.solve_into(&loads, &opts, None, &mut ws, &mut out).objective
    }));
    // Warm solve: previous optimum, loads shifted 10% (the epoch case).
    let cold = state.solve(&loads, &opts, None);
    let perturbed = [PerBlockLoad {
        tokens: loads[0].tokens.iter().map(|q| q * 1.1).collect(),
    }];
    results.push(bench("solver/warm_8dev_ws", budget, || {
        state
            .solve_into(&perturbed, &opts, Some(&cold.bandwidth), &mut ws, &mut out)
            .objective
    }));
    results
}

/// Full adaptive epoch tick (re-solve + placement re-balance) inside a
/// live simulator. Demand alternates so hysteresis never suppresses the
/// re-solve.
pub fn epoch_tick_harness(budget: Duration) -> BenchResult {
    let mut ccfg = ClusterConfig::single_cell();
    ccfg.control = ControlKind::Adaptive;
    ccfg.model.n_blocks = 4;
    let mut sim = ClusterSim::new(&ccfg).expect("preset config is valid");
    let experts: Vec<f64> = (0..8).map(|k| 5.0 + k as f64).collect();
    let mut demand = vec![0.0f64; 8];
    let mut flip = false;
    bench("control/epoch_tick_adaptive_8dev", budget, || {
        flip = !flip;
        for (k, d) in demand.iter_mut().enumerate() {
            let base = 10.0 + k as f64 * 5.0;
            *d = if (k % 2 == 0) == flip { base * 3.0 } else { base };
        }
        sim.control_epoch(0, &demand, &experts)
    })
}

/// One load-aware dispatch decision on a backlogged 16-replica fleet.
pub fn dispatch_harness(budget: Duration) -> BenchResult {
    let d = Dispatcher::new(DispatchKind::LoadAware);
    let t: Vec<f64> = (0..16).map(|k| 2e-5 * (1.0 + k as f64)).collect();
    let busy: Vec<u64> = (0..16).map(|k| k as u64 * 1_000_000).collect();
    let online = vec![true; 16];
    let replicas: Vec<usize> = (0..16).collect();
    bench("cluster/dispatch_choose_16rep", budget, || {
        d.choose(&replicas, 40.0, 500_000, &busy, &t, &online, EnergyScore::OFF)
    })
}

/// Whole-DES throughput on the two-cell preset, one reused simulator
/// (reset between runs), reported as simulated events per wall second.
pub fn des_harness(budget: Duration, requests: usize) -> BenchResult {
    let mut dcfg = ClusterConfig::edge_default();
    dcfg.model.n_blocks = 8;
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(requests, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    // The event count per run is deterministic; measure it once.
    let events_per_run = des.run(&arrivals).events;
    let mut r = bench_quiet("cluster/des_run_2cell", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run(&arrivals).completed
    });
    let events_per_sec = events_per_run as f64 * 1e9 / r.mean_ns;
    r.throughput = Some(("sim_events_per_sec".to_string(), events_per_sec));
    r.report();
    r
}

/// The same whole-DES run through the explicit `run_probed(NullProbe)`
/// entry point. The telemetry contract says the no-op probe
/// monomorphizes away entirely, so this harness should report the same
/// events/sec as `cluster/des_run_2cell` to within noise — a widening
/// gap in `BENCH_cluster.json` means probe hooks leaked cost onto the
/// hot path.
pub fn des_nullprobe_harness(budget: Duration, requests: usize) -> BenchResult {
    let mut dcfg = ClusterConfig::edge_default();
    dcfg.model.n_blocks = 8;
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(requests, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    let events_per_run = des.run(&arrivals).events;
    let mut r = bench_quiet("cluster/des_run_2cell_nullprobe", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run_probed(&arrivals, &mut NullProbe).completed
    });
    let events_per_sec = events_per_run as f64 * 1e9 / r.mean_ns;
    r.throughput = Some(("sim_events_per_sec".to_string(), events_per_sec));
    r.report();
    r
}

/// The same 2-cell DES with fault support compiled in but an *empty*
/// fault plan. The fault contract mirrors telemetry's: no configured
/// faults monomorphize to the exact zero-fault hot path, so this
/// harness should match `cluster/des_run_2cell` to within noise — a
/// widening gap means the fault machinery leaked cost onto runs that
/// never asked for it.
pub fn des_faultplan_empty_harness(budget: Duration, requests: usize) -> BenchResult {
    let mut dcfg = ClusterConfig::edge_default();
    dcfg.model.n_blocks = 8;
    debug_assert!(dcfg.faults.is_empty(), "edge_default must carry no faults");
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(requests, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    let events_per_run = des.run(&arrivals).events;
    let mut r = bench_quiet("cluster/des_run_2cell_faultplan_empty", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run(&arrivals).completed
    });
    let events_per_sec = events_per_run as f64 * 1e9 / r.mean_ns;
    r.throughput = Some(("sim_events_per_sec".to_string(), events_per_sec));
    r.report();
    r
}

/// The 2-cell DES with the energy model left *off* (the default
/// config). The energy contract mirrors the telemetry and fault ones:
/// an empty [`crate::config::EnergyConfig`] monomorphizes the
/// accounting away (`ENERGY = false`), so this harness should match
/// `cluster/des_run_2cell` to within noise — a widening gap means the
/// energy machinery leaked cost onto runs that never asked for it.
pub fn des_energy_off_harness(budget: Duration, requests: usize) -> BenchResult {
    let mut dcfg = ClusterConfig::edge_default();
    dcfg.model.n_blocks = 8;
    debug_assert!(dcfg.energy.is_empty(), "edge_default must carry no energy model");
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(requests, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    let events_per_run = des.run(&arrivals).events;
    let mut r = bench_quiet("cluster/des_run_2cell_energy_off", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run(&arrivals).completed
    });
    let events_per_sec = events_per_run as f64 * 1e9 / r.mean_ns;
    r.throughput = Some(("sim_events_per_sec".to_string(), events_per_sec));
    r.report();
    r
}

/// The energy-on twin: the same 2-cell run with per-token joule
/// accounting and energy-weighted dispatch armed (mains-powered — no
/// battery churn, so the event count matches the energy-off twin). The
/// gap between this harness and `cluster/des_run_2cell_energy_off` is
/// the honest per-event price of the accounting.
pub fn des_energy_on_harness(budget: Duration, requests: usize) -> BenchResult {
    let mut dcfg = ClusterConfig::edge_default();
    dcfg.model.n_blocks = 8;
    dcfg.energy.compute_j_per_token = 1e-3;
    dcfg.energy.tx_j_per_token = 2e-4;
    dcfg.energy.rx_j_per_token = 1e-4;
    dcfg.energy_weight = 0.5;
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 4.0 }.generate(requests, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    let events_per_run = des.run(&arrivals).events;
    let mut r = bench_quiet("cluster/des_run_2cell_energy_on", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run(&arrivals).completed
    });
    let events_per_sec = events_per_run as f64 * 1e9 / r.mean_ns;
    r.throughput = Some(("sim_events_per_sec".to_string(), events_per_sec));
    r.report();
    r
}

/// The serial / sharded twin pair on an 8-cell cluster: the same config,
/// the same arrival stream, one harness through the serial event loop
/// and one through `run_sharded` on the worker pool (0 = one worker per
/// core, capped at the cell count). Their events/sec ratio is the
/// sharding speedup the bench gate watches; the outcomes themselves are
/// byte-identical by the sharded engine's determinism contract.
pub fn des_8cell_harnesses(budget: Duration, requests: usize) -> Vec<BenchResult> {
    let mut dcfg = ClusterConfig::edge_default().with_n_cells(8);
    dcfg.model.n_blocks = 8;
    // 4x the 2-cell harness volume so each of the 8 shards carries the
    // per-cell load the 2-cell harness gives its cells.
    let arrivals =
        ArrivalProcess::Poisson { rate_rps: 16.0 }.generate(requests * 4, Benchmark::Piqa, 0);
    let mut des = ClusterSim::new(&dcfg).expect("preset config is valid");
    let events_per_run = des.run(&arrivals).events;
    let mut serial = bench_quiet("cluster/des_run_8cell", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run(&arrivals).completed
    });
    serial.throughput = Some((
        "sim_events_per_sec".to_string(),
        events_per_run as f64 * 1e9 / serial.mean_ns,
    ));
    serial.report();
    let mut sharded = bench_quiet("cluster/des_run_8cell_sharded", budget, || {
        des.reset().expect("reset of a valid sim cannot fail");
        des.run_sharded(&arrivals, 0).completed
    });
    sharded.throughput = Some((
        "sim_events_per_sec".to_string(),
        events_per_run as f64 * 1e9 / sharded.mean_ns,
    ));
    sharded.report();
    println!(
        "  sharding speedup: {:.2}x events/sec over the serial twin",
        serial.mean_ns / sharded.mean_ns
    );
    vec![serial, sharded]
}

/// Run the full suite (tiny budgets when `smoke`), printing each result.
pub fn run_suite(smoke: bool) -> BenchSuite {
    let budget = if smoke { smoke_budget() } else { default_budget() };
    let requests = if smoke { 12 } else { 60 };
    let mut results = solver_harnesses(budget);
    results.push(epoch_tick_harness(budget));
    results.push(dispatch_harness(budget));
    results.push(des_harness(budget, requests));
    results.push(des_nullprobe_harness(budget, requests));
    results.push(des_faultplan_empty_harness(budget, requests));
    results.push(des_energy_off_harness(budget, requests));
    results.push(des_energy_on_harness(budget, requests));
    results.extend(des_8cell_harnesses(budget, requests));
    BenchSuite {
        smoke,
        budget_ms: budget.as_millis() as u64,
        results,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_suite_runs_and_serializes() {
        let suite = run_suite(true);
        let names: Vec<&str> = suite.results.iter().map(|r| r.name.as_str()).collect();
        for expect in [
            "solver/cold_8dev_ws",
            "solver/warm_8dev_ws",
            "control/epoch_tick_adaptive_8dev",
            "cluster/dispatch_choose_16rep",
            "cluster/des_run_2cell",
            "cluster/des_run_2cell_nullprobe",
            "cluster/des_run_2cell_faultplan_empty",
            "cluster/des_run_2cell_energy_off",
            "cluster/des_run_2cell_energy_on",
            "cluster/des_run_8cell",
            "cluster/des_run_8cell_sharded",
        ] {
            assert!(names.contains(&expect), "missing harness {expect}");
        }
        let des = suite
            .results
            .iter()
            .find(|r| r.name == "cluster/des_run_2cell")
            .unwrap();
        let (unit, v) = des.throughput.as_ref().expect("DES reports throughput");
        assert_eq!(unit, "sim_events_per_sec");
        assert!(*v > 0.0);
        // The JSON document parses back and keeps every record.
        let back = Json::parse(&suite.to_json().to_string()).unwrap();
        assert_eq!(
            back.get("schema").unwrap().as_str().unwrap(),
            "wdmoe-bench-v1"
        );
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 11);
        // The sharded twin reports the same throughput unit so the
        // bench gate can ratio the pair.
        let sharded = suite
            .results
            .iter()
            .find(|r| r.name == "cluster/des_run_8cell_sharded")
            .unwrap();
        let (sunit, sv) = sharded.throughput.as_ref().expect("sharded throughput");
        assert_eq!(sunit, "sim_events_per_sec");
        assert!(*sv > 0.0);
        assert!(back.get("smoke").unwrap().as_bool().unwrap());
        // A measured run must never mark itself provisional: the CI
        // regression gate arms against it.
        assert!(!back.get("provisional").unwrap().as_bool().unwrap());
    }
}
