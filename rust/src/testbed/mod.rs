//! Hardware-testbed simulation — paper Section VI.
//!
//! The paper's testbed: four heterogeneous devices (2× Jetson AGX Orin,
//! Jetson Xavier NX, RTX-4070-Ti PC) around a WiFi AP, running Algorithm 2
//! with *measured* latency history instead of channel-state optimization
//! ("without estimating channel conditions, predicting transmission rates,
//! or allocating communication bandwidth", §VI-C).
//!
//! Our substitute (DESIGN.md): the same fleet with published-TFLOPS
//! capacities, per-block Rayleigh fading at 5 GHz/80 MHz WiFi-like
//! parameters, and multiplicative compute jitter — producing the latency
//! variance Algorithm 2's history estimator is designed to absorb.

use crate::config::SystemConfig;
use crate::control::LinkState;
use crate::devices::Fleet;
use crate::latency::{block_latency, BlockLatency};
use crate::moe::selection::{SelectionContext, SelectionPolicy};
use crate::moe::GateWeights;
use crate::wireless::ChannelSimulator;
use crate::workload::WorkloadGen;

/// Outcome of one batch on the testbed: per-block (per-layer) latencies,
/// matching Fig. 10's "latency per batch in a layer".
#[derive(Debug, Clone)]
pub struct TestbedOutcome {
    pub per_block: Vec<BlockLatency>,
    /// Mean per-layer attention waiting latency (ms) — Fig. 10's y-axis.
    pub mean_layer_ms: f64,
    pub max_layer_ms: f64,
    pub min_layer_ms: f64,
    /// Total tokens transmitted (load metric).
    pub transmissions: f64,
}

/// The testbed simulator: per-block fading + compute jitter, uniform
/// bandwidth, measured-latency feedback into the policy.
pub struct TestbedSim {
    pub cfg: SystemConfig,
    channel: ChannelSimulator,
    fleet: Fleet,
    gates: WorkloadGen,
    pub gate_sharpness: f64,
}

impl TestbedSim {
    /// Build from the Section-VI preset (or any config with fading/jitter).
    pub fn new(mut cfg: SystemConfig) -> Self {
        if cfg.channel.fading_blocks == 0 {
            cfg.channel.fading_blocks = 1; // testbed always sees variation
        }
        cfg.validate().expect("invalid testbed config");
        let channel = ChannelSimulator::new(&cfg.channel, &cfg.devices, cfg.seed);
        let fleet = Fleet::new(&cfg.devices, cfg.seed);
        let gates = WorkloadGen::new(cfg.seed.wrapping_add(2), cfg.model.vocab);
        Self {
            cfg,
            channel,
            fleet,
            gates,
            gate_sharpness: 1.5,
        }
    }

    pub fn paper() -> Self {
        Self::new(SystemConfig::paper_testbed())
    }

    /// Reseed (the paper runs "three experiments ... under the same
    /// environmental settings", Table IV).
    pub fn with_seed(mut cfg: SystemConfig, seed: u64) -> Self {
        cfg.seed = seed;
        Self::new(cfg)
    }

    /// Access the fleet (failure injection in demos/tests).
    pub fn fleet_mut(&mut self) -> &mut Fleet {
        &mut self.fleet
    }

    /// Run one batch of `n_tokens` through all blocks.
    ///
    /// Per block: draw the fading + jitter realization, compute the true
    /// per-token latencies under the uniform split, let the policy select
    /// (it sees only its history + the cold-start estimate), measure, and
    /// feed the measurement back (`observe`, Eq. (30)).
    pub fn run_batch(
        &mut self,
        n_tokens: usize,
        policy: &mut dyn SelectionPolicy,
    ) -> TestbedOutcome {
        let u = self.cfg.n_devices();
        let blocks = self.cfg.model.n_blocks;
        let l_comp = self.cfg.model.l_comp_flops(self.cfg.activation_eta);
        let l_comm = self.cfg.model.l_comm_bits(self.cfg.channel.quant_bits);
        let total_bw = self.cfg.channel.total_bandwidth_hz;
        let uniform = vec![total_bw / u as f64; u];
        let online = self.fleet.online_mask();

        let mut per_block = Vec::with_capacity(blocks);
        let mut transmissions = 0.0;
        for _ in 0..blocks {
            // True (this block's) conditions — hidden from the policy.
            // Link assembly goes through the shared control layer.
            let realization = self.channel.realization().clone();
            let t_comp = self.fleet.t_comp_per_token(l_comp); // jittered
            let truth = LinkState::new(&self.cfg.channel, &realization, &t_comp, l_comm)
                .token_latencies(&uniform);

            // Cold-start estimate: nominal (jitter-free) mean-channel view.
            let nominal_t_comp = self.fleet.t_comp_nominal(l_comp);
            let mean_real = self.channel.expected_realization();
            let est = LinkState::new(&self.cfg.channel, &mean_real, &nominal_t_comp, l_comm)
                .token_latencies(&uniform);

            let gate = GateWeights::new(self.gates.synthetic_gate_weights(
                n_tokens,
                u,
                self.gate_sharpness,
            ));
            let ctx = SelectionContext {
                latencies: &est,
                top_k: self.cfg.model.top_k,
                online: &online,
            };
            let sel = policy.select(&gate, &ctx);
            let counts = sel.tokens_per_device();
            let bl = block_latency(&truth, &counts);
            // Feedback: the server records measured per-token latency.
            for k in 0..u {
                if counts[k] > 0.0 {
                    policy.observe(k, truth.per_token[k]);
                }
            }
            transmissions += counts.iter().sum::<f64>();
            per_block.push(bl);
            self.channel.advance_block();
        }

        let ms: Vec<f64> = per_block.iter().map(|b| b.waiting * 1e3).collect();
        let mean = ms.iter().sum::<f64>() / ms.len() as f64;
        TestbedOutcome {
            mean_layer_ms: mean,
            max_layer_ms: ms.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            min_layer_ms: ms.iter().copied().fold(f64::INFINITY, f64::min),
            per_block,
            transmissions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyConfig, PolicyKind};
    use crate::moe::selection::make_policy;

    fn run(policy_kind: PolicyKind, seed: u64, tokens: usize, batches: usize) -> f64 {
        let mut cfg = SystemConfig::paper_testbed();
        cfg.seed = seed;
        let mut sim = TestbedSim::new(cfg.clone());
        let mut policy = make_policy(policy_kind, &cfg.policy, cfg.n_devices(), seed);
        let mut total = 0.0;
        for _ in 0..batches {
            total += sim.run_batch(tokens, policy.as_mut()).mean_layer_ms;
        }
        total / batches as f64
    }

    #[test]
    fn testbed_runs_and_reports() {
        let mut sim = TestbedSim::paper();
        let mut p = make_policy(
            PolicyKind::Testbed,
            &PolicyConfig::default(),
            4,
            0,
        );
        let out = sim.run_batch(500, p.as_mut());
        assert_eq!(out.per_block.len(), 32);
        assert!(out.mean_layer_ms > 0.0);
        assert!(out.max_layer_ms >= out.mean_layer_ms);
        assert!(out.min_layer_ms <= out.mean_layer_ms);
    }

    #[test]
    fn alg2_beats_vanilla_on_average() {
        // The Section-VI headline: WDMoE-testbed (Alg 2) reduces latency
        // vs the Mixtral-based method. Averaged over several batches so
        // the history estimator has warmed up.
        let v = run(PolicyKind::VanillaTopK, 1, 600, 6);
        let t = run(PolicyKind::Testbed, 1, 600, 6);
        assert!(
            t < v,
            "Alg2 mean layer latency {t:.2}ms should beat vanilla {v:.2}ms"
        );
    }

    #[test]
    fn latency_variance_exists() {
        // Fig. 10 shades a min–max band: fading+jitter must make layers differ.
        let mut sim = TestbedSim::paper();
        let mut p = make_policy(PolicyKind::VanillaTopK, &PolicyConfig::default(), 4, 0);
        let out = sim.run_batch(400, p.as_mut());
        assert!(out.max_layer_ms > out.min_layer_ms * 1.05);
    }

    #[test]
    fn seeds_reproduce() {
        let a = run(PolicyKind::Testbed, 7, 300, 2);
        let b = run(PolicyKind::Testbed, 7, 300, 2);
        assert_eq!(a, b);
        let c = run(PolicyKind::Testbed, 8, 300, 2);
        assert_ne!(a, c);
    }
}
