//! Metrics: latency recording, summary statistics, and the table/figure
//! formatting shared by the `repro` harnesses.

/// Online summary of a scalar series (latencies, loads, …).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Smallest value, 0 on an empty series. Folding from `±inf` let a
    /// fully-saturated sweep point (zero steady-state completions) leak
    /// `inf` into reports; an empty series reports 0 like `mean()`.
    pub fn min(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest value, 0 on an empty series (see [`Self::min`]).
    pub fn max(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile with linear interpolation, p in [0, 100].
    ///
    /// Sorts with [`f64::total_cmp`] (never panics, even on NaN input).
    /// For several percentiles of one series use [`Self::percentiles`],
    /// which sorts once instead of once per call.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut v = self.values.clone();
        v.sort_by(f64::total_cmp);
        Self::percentile_of_sorted(&v, p)
    }

    /// Several percentiles from a single clone-and-sort of the series —
    /// the sweep tables' p50/p95/p99 columns cost one sort per row, not
    /// three. Returns 0 for every requested point on an empty series.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.values.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut v = self.values.clone();
        v.sort_by(f64::total_cmp);
        ps.iter().map(|&p| Self::percentile_of_sorted(&v, p)).collect()
    }

    fn percentile_of_sorted(v: &[f64], p: f64) -> f64 {
        let pos = (p / 100.0) * (v.len() as f64 - 1.0);
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
        }
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Steady-state accumulator: a [`Summary`] that discards a warm-up
/// prefix before reporting.
///
/// Open-loop serving sweeps (the `cluster` subsystem) start from an empty
/// system, so the first completions see artificially short queues. Values
/// are recorded in completion order; `steady()` drops the first
/// `warmup_frac` fraction and summarises the rest, which is what the
/// p50/p95/p99 columns of `repro cluster` report.
#[derive(Debug, Clone)]
pub struct SteadyState {
    warmup_frac: f64,
    values: Vec<f64>,
}

impl SteadyState {
    /// `warmup_frac` in [0, 1): fraction of leading samples to discard.
    pub fn new(warmup_frac: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&warmup_frac),
            "warmup_frac must be in [0,1), got {warmup_frac}"
        );
        Self {
            warmup_frac,
            values: Vec::new(),
        }
    }

    /// [`Self::new`] with the sample buffer pre-sized, so a run that
    /// knows its completion count up front records without reallocating.
    pub fn with_capacity(warmup_frac: f64, cap: usize) -> Self {
        let mut s = Self::new(warmup_frac);
        s.values.reserve(cap);
        s
    }

    pub fn record(&mut self, v: f64) {
        self.values.push(v);
    }

    /// Samples recorded, including warm-up.
    pub fn total_count(&self) -> usize {
        self.values.len()
    }

    /// Post-warm-up samples (completion order preserved).
    pub fn steady_values(&self) -> &[f64] {
        let skip = ((self.values.len() as f64) * self.warmup_frac).floor() as usize;
        // Keep at least one sample when anything was recorded.
        let skip = skip.min(self.values.len().saturating_sub(1));
        &self.values[skip..]
    }

    /// Summary over the post-warm-up window.
    pub fn steady(&self) -> Summary {
        let mut s = Summary::new();
        for &v in self.steady_values() {
            s.record(v);
        }
        s
    }
}

/// Busy-time utilization tracker for one resource.
///
/// `busy / horizon` with busy time accumulated as work is scheduled; the
/// cluster simulator keeps one per device so utilization *emerges* from
/// load rather than being assumed.
#[derive(Debug, Clone, Copy, Default)]
pub struct Utilization {
    busy_s: f64,
}

impl Utilization {
    pub fn add_busy(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        self.busy_s += seconds;
    }

    pub fn busy_seconds(&self) -> f64 {
        self.busy_s
    }

    /// Fraction of `horizon_s` spent busy (0 when the horizon is empty).
    pub fn fraction(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            0.0
        } else {
            self.busy_s / horizon_s
        }
    }
}

/// Counters a control plane exposes so re-optimization activity is
/// observable alongside latency: how often P3 was re-solved, how often
/// the expert placement changed, and how much spectrum moved.
///
/// `churn_frac` accumulates, per re-solve, the fraction of the cell's
/// total bandwidth that changed hands (half the L1 distance between the
/// old and new splits, normalised by the budget) — a run that never
/// re-allocates reports 0, one that flips the whole spectrum every epoch
/// reports ~1 per epoch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ControlStats {
    /// P3 (bandwidth) re-solves performed.
    pub resolves: usize,
    /// Placement re-optimizations that actually changed the replica map.
    pub placement_updates: usize,
    /// Accumulated fraction of total bandwidth moved across re-solves.
    pub churn_frac: f64,
}

impl ControlStats {
    /// Fold another plane's counters in (aggregating across cells).
    pub fn absorb(&mut self, other: &ControlStats) {
        self.resolves += other.resolves;
        self.placement_updates += other.placement_updates;
        self.churn_frac += other.churn_frac;
    }
}

/// A rendered results table: the `repro` harness prints these in the same
/// row/column layout as the paper and also dumps CSV next to them.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// Formatting precision per value.
    pub precision: usize,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            precision: 1,
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) -> &mut Self {
        assert_eq!(values.len(), self.columns.len(), "row arity mismatch");
        self.rows.push((label.to_string(), values));
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap_or(8)
            + 2;
        let col_w = self
            .columns
            .iter()
            .map(|c| c.len().max(12))
            .collect::<Vec<_>>();
        out.push_str(&format!("{:label_w$}", ""));
        for (c, w) in self.columns.iter().zip(&col_w) {
            out.push_str(&format!("{c:>w$}  ", w = w));
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("{label:label_w$}"));
            for (v, w) in vals.iter().zip(&col_w) {
                out.push_str(&format!("{v:>w$.p$}  ", w = w, p = self.precision));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("label,{}\n", self.columns.join(",")));
        for (label, vals) in &self.rows {
            let vs: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
            out.push_str(&format!("{label},{}\n", vs.join(",")));
        }
        out
    }

    /// Write CSV into `dir/<slug>.csv` (slug from the title).
    pub fn write_csv(&self, dir: &std::path::Path) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_stats() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.record(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.count(), 4);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for v in [10.0, 20.0, 30.0, 40.0] {
            s.record(v);
        }
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 40.0);
        assert_eq!(s.percentile(50.0), 25.0);
        assert_eq!(s.percentile(75.0), 32.5);
    }

    #[test]
    fn empty_summary_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.std(), 0.0);
        // Regression: these folded from ±inf and leaked `inf` into
        // reports for fully-saturated sweep points.
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.percentiles(&[50.0, 95.0, 99.0]), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn percentiles_match_individual_percentile_calls() {
        // The single-sort batch path must agree bit-for-bit with the
        // per-call path — sweep CSV bytes cannot change.
        let mut s = Summary::new();
        let mut x = 0.37f64;
        for _ in 0..101 {
            x = (x * 997.0 + 0.123).fract() * 50.0;
            s.record(x);
        }
        let ps = [0.0, 12.5, 50.0, 75.0, 95.0, 99.0, 100.0];
        let batch = s.percentiles(&ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i].to_bits(), s.percentile(p).to_bits(), "p{p}");
        }
    }

    #[test]
    fn percentile_tolerates_nan_without_panicking() {
        // partial_cmp().unwrap() used to panic here; total_cmp sorts
        // NaNs to the top instead.
        let mut s = Summary::new();
        for v in [3.0, f64::NAN, 1.0, 2.0] {
            s.record(v);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert!(s.percentile(100.0).is_nan());
        let b = s.percentiles(&[0.0, 50.0]);
        assert_eq!(b[0], 1.0);
        assert_eq!(b[1], 2.5);
    }

    #[test]
    fn steady_state_discards_warmup_prefix() {
        let mut s = SteadyState::new(0.25);
        // 4 warm-up-ish low values then 12 steady ones
        for v in [1.0, 1.0, 1.0, 1.0] {
            s.record(v);
        }
        for _ in 0..12 {
            s.record(10.0);
        }
        assert_eq!(s.total_count(), 16);
        assert_eq!(s.steady_values().len(), 12);
        assert_eq!(s.steady().mean(), 10.0);
        assert_eq!(s.steady().percentile(99.0), 10.0);
    }

    #[test]
    fn steady_state_zero_warmup_keeps_all() {
        let mut s = SteadyState::new(0.0);
        s.record(1.0);
        s.record(2.0);
        assert_eq!(s.steady().count(), 2);
    }

    #[test]
    fn steady_state_keeps_at_least_one_sample() {
        let mut s = SteadyState::new(0.9);
        s.record(5.0);
        assert_eq!(s.steady_values(), &[5.0]);
    }

    #[test]
    fn steady_state_empty_is_safe() {
        let s = SteadyState::new(0.5);
        assert_eq!(s.steady().count(), 0);
        assert_eq!(s.steady().percentile(99.0), 0.0);
    }

    #[test]
    #[should_panic(expected = "warmup_frac")]
    fn steady_state_rejects_bad_frac() {
        let _ = SteadyState::new(1.0);
    }

    #[test]
    fn control_stats_absorb_sums() {
        let mut a = ControlStats {
            resolves: 2,
            placement_updates: 1,
            churn_frac: 0.25,
        };
        let b = ControlStats {
            resolves: 3,
            placement_updates: 0,
            churn_frac: 0.5,
        };
        a.absorb(&b);
        assert_eq!(a.resolves, 5);
        assert_eq!(a.placement_updates, 1);
        assert!((a.churn_frac - 0.75).abs() < 1e-12);
    }

    #[test]
    fn utilization_fraction() {
        let mut u = Utilization::default();
        u.add_busy(2.0);
        u.add_busy(3.0);
        assert_eq!(u.busy_seconds(), 5.0);
        assert_eq!(u.fraction(10.0), 0.5);
        assert_eq!(u.fraction(0.0), 0.0);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("Latency/batch (ms)", &["ARC-E", "ARC-C"]);
        t.row("Mixtral-based", vec![532.8, 1625.0]);
        t.row("WDMoE", vec![468.3, 1228.0]);
        let text = t.render();
        assert!(text.contains("Mixtral-based"));
        assert!(text.contains("ARC-C"));
        let csv = t.to_csv();
        assert!(csv.starts_with("label,ARC-E,ARC-C\n"));
        assert!(csv.contains("WDMoE,468.3,1228\n"));
    }

    #[test]
    fn table_csv_roundtrip_to_disk() {
        let dir = crate::util::temp_dir("csv");
        let mut t = Table::new("Fig 5", &["x"]);
        t.row("r", vec![1.0]);
        let p = t.write_csv(&dir).unwrap();
        assert!(p.exists());
        assert!(std::fs::read_to_string(p).unwrap().contains("r,1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row("x", vec![1.0]);
    }
}
