//! Live per-cell load summaries — the signal the cluster-level dispatch
//! layer ([`crate::cluster::handover`]) reads before moving work across
//! cells.
//!
//! The control plane owns a cell's *allocation* state; the DES owns its
//! *queue* state (`busy_until`). [`CellLoad`] is the bridge: a cheap,
//! allocation-free snapshot of a cell's outstanding backlog at a virtual
//! instant, comparable across cells of different sizes via
//! [`CellLoad::score`]. Arrival re-homing picks the cell with the lowest
//! score; expert borrowing ranks neighbor cells by it.

use crate::cluster::event::{secs_from_nanos, Nanos};

/// Snapshot of one cell's queue backlog at a virtual instant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CellLoad {
    /// Summed backlog seconds over online devices.
    pub backlog_s_total: f64,
    /// Worst single-device backlog seconds (online devices only).
    pub backlog_s_max: f64,
    /// Devices currently online.
    pub online_devices: usize,
}

impl CellLoad {
    /// Observe a cell's committed queue state: `busy_until[k]` is the
    /// instant device `k`'s FIFO drains, `online[k]` its availability.
    /// Runs on the arrival hot path — a single pass over borrowed
    /// slices, no allocation.
    pub fn observe(now: Nanos, busy_until: &[Nanos], online: &[bool]) -> Self {
        debug_assert_eq!(busy_until.len(), online.len());
        let mut load = CellLoad::default();
        for (&busy, &on) in busy_until.iter().zip(online) {
            if !on {
                continue;
            }
            load.online_devices += 1;
            let backlog_s = secs_from_nanos(busy.saturating_sub(now));
            load.backlog_s_total += backlog_s;
            if backlog_s > load.backlog_s_max {
                load.backlog_s_max = backlog_s;
            }
        }
        load
    }

    /// Cross-cell comparison score: mean backlog seconds per online
    /// device (cells with more devices absorb more work before looking
    /// loaded). A cell with no online device scores infinite — it can
    /// never win a re-home or a borrow.
    pub fn score(&self) -> f64 {
        if self.online_devices == 0 {
            f64::INFINITY
        } else {
            self.backlog_s_total / self.online_devices as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_sums_online_backlog_only() {
        // now = 1 s; device 0 drains at 3 s (2 s backlog), device 1 is
        // already idle, device 2 is offline with a huge queue.
        let busy = [3_000_000_000u64, 500_000_000, 9_000_000_000];
        let online = [true, true, false];
        let load = CellLoad::observe(1_000_000_000, &busy, &online);
        assert_eq!(load.online_devices, 2);
        assert!((load.backlog_s_total - 2.0).abs() < 1e-12);
        assert!((load.backlog_s_max - 2.0).abs() < 1e-12);
        assert!((load.score() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn idle_cell_scores_zero_dead_cell_scores_infinite() {
        let idle = CellLoad::observe(5_000_000_000, &[0, 0], &[true, true]);
        assert_eq!(idle.score(), 0.0);
        let dead = CellLoad::observe(0, &[0, 0], &[false, false]);
        assert!(dead.score().is_infinite());
    }

    #[test]
    fn score_normalizes_by_online_device_count() {
        // Same total backlog, twice the devices: half the score.
        let small = CellLoad {
            backlog_s_total: 4.0,
            backlog_s_max: 4.0,
            online_devices: 2,
        };
        let big = CellLoad {
            backlog_s_total: 4.0,
            backlog_s_max: 1.0,
            online_devices: 4,
        };
        assert!(big.score() < small.score());
    }
}
