//! # `control` — the shared control plane
//!
//! The paper's core contribution is the *joint* optimization of expert
//! selection and bandwidth allocation (problem P3). Before this layer,
//! the two simulators split that responsibility inconsistently: the
//! analytic [`crate::coordinator::sim::Simulator`] re-solved P3 per
//! block but rebuilt its link inputs by hand, while the DES
//! ([`crate::cluster::sim::ClusterSim`]) froze per-device service times
//! at construction under the uniform split and never revisited them.
//!
//! This module owns the `(bandwidth allocation, expert placement,
//! t_per_token)` state per cell and is consumed by **both** simulators:
//!
//! * [`LinkState`] — the single home of the per-device link assembly
//!   (channel gains + compute + payload → [`DeviceLink`]s) and of the
//!   split → service-time mapping, replacing the duplicated
//!   `AllocationInput` construction.
//! * [`ControlPlane`] — the trait both simulators program against, with
//!   three implementations selected by [`crate::config::ControlKind`]:
//!   static-uniform (open loop, even split), static-optimal (one-shot P3
//!   pre-solve) and adaptive (epoch-cadence re-solve from observed queue
//!   backlog, warm-started, plus replica autoscaling from observed
//!   per-expert token counts).
//!
//! * [`CellLoad`] — a live per-cell queue-backlog summary the
//!   cluster-level dispatch layer ([`crate::cluster::handover`]) reads
//!   when re-homing arrivals or ranking neighbor cells for expert
//!   borrowing.
//!
//! Re-solve counts and allocation churn are reported through
//! [`crate::metrics::ControlStats`] so closed-loop activity shows up in
//! the `repro cluster` CSVs next to latency; per-solve cost (iterations,
//! warm-vs-cold, convergence) is aggregated in [`SolverIntrospection`]
//! and surfaced as `solver_iters_mean` / `solver_iters_max` metric
//! columns and through the telemetry layer's `ControlResolve` events.
//!
//! [`DeviceLink`]: crate::optim::solver::DeviceLink

pub mod load;
pub mod plane;
pub mod state;

pub use load::CellLoad;
pub use plane::{
    make_plane, AdaptivePlane, ControlOptions, ControlPlane, SolverIntrospection, StaticPlane,
};
pub use state::LinkState;
