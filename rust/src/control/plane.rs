//! Control planes: who owns `(bandwidth allocation, expert placement,
//! t_per_token)` for a cell, and when it changes.
//!
//! * [`StaticPlane`] — the open-loop arms. `StaticUniform` freezes the
//!   even split (the PR-1 cluster baseline and the paper's "Mixtral-based"
//!   allocation); `StaticOptimal` freezes a one-shot P3 pre-solve under an
//!   equal-expected-load assumption. Both still serve per-block solves to
//!   the coordinator via [`ControlPlane::allocate_into`].
//! * [`AdaptivePlane`] — the paper's closed loop inside the DES: on an
//!   epoch cadence it re-solves P3 from *observed* per-device demand
//!   (queue backlog + recently served tokens), warm-starting from the
//!   previous split so the re-solve stays cheap, and re-optimizes the
//!   expert placement from observed per-expert token counts (replica
//!   autoscaling). A hysteresis knob suppresses re-solves when the demand
//!   share barely moved.
//!
//! Epoch ticks and per-block solves run inside the DES event loop, so
//! each plane owns a [`SolverWorkspace`] plus staging buffers: after
//! construction, a tick (re-solve + service-time refresh + hysteresis
//! bookkeeping) performs no heap allocation on the solver path.
//!
//! The planes are energy-agnostic by design: with
//! [`crate::config::ClusterConfig::energy_weight`] > 0 the DES biases
//! the *demand vector* it hands an adaptive tick away from devices with
//! drained batteries (see [`crate::cluster::energy`]) before calling
//! in here, so the P3 re-solve shifts bandwidth and placement toward
//! charged devices without the solver itself learning a joule term.

use super::state::LinkState;
use crate::cluster::placement::Placement;
use crate::config::ControlKind;
use crate::metrics::ControlStats;
use crate::optim::{PerBlockLoad, SolveStats, SolverOptions, SolverWorkspace};

/// Knobs shared by every plane (only the adaptive one reads them all).
#[derive(Debug, Clone)]
pub struct ControlOptions {
    /// Adaptive re-solve cadence in virtual seconds.
    pub epoch_s: f64,
    /// Minimum relative L1 shift of the demand share since the last
    /// solve before re-solving (0 = always re-solve on demand).
    pub hysteresis: f64,
    /// P3 solver hyper-parameters.
    pub solver: SolverOptions,
}

impl Default for ControlOptions {
    fn default() -> Self {
        Self {
            epoch_s: 0.25,
            hysteresis: 0.05,
            solver: SolverOptions::default(),
        }
    }
}

/// Aggregated P3 solver cost — every [`SolveStats`] a plane would
/// otherwise drop on the floor, folded into one summary. Accumulated at
/// each solve the plane performs (the static-optimal pre-solve,
/// per-block `allocate_into` solves, epoch and failover re-solves) and
/// surfaced per run through [`ControlPlane::solver_stats`]; the DES
/// folds cells together with [`Self::absorb`] so `solver_iters_mean` /
/// `solver_iters_max` land in the experiment [`Record`] schema.
///
/// Deliberately a *parallel* aggregate to [`ControlStats`]: the latter's
/// construction is pinned by tests and sweep-CSV schemas, so solver cost
/// rides alongside rather than inside it.
///
/// [`Record`]: crate::experiment::Record
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SolverIntrospection {
    /// Total P3 solves performed.
    pub solves: u64,
    /// Solves that were warm-started from a previous split.
    pub warm: u64,
    /// Cold solves (no warm start available).
    pub cold: u64,
    /// Sum of projected-gradient iterations over all solves (0-iteration
    /// water-filling fast-path solves count as 0).
    pub iterations_total: u64,
    /// Largest single-solve iteration count.
    pub iterations_max: u64,
    /// Solves that stopped before the iteration cap.
    pub converged: u64,
    /// Iterations of the most recent solve.
    pub last_iterations: usize,
    /// Objective of the most recent solve (seconds).
    pub last_objective: f64,
    /// Whether the most recent solve was warm-started.
    pub last_warm: bool,
    /// Whether the most recent solve converged before the cap.
    pub last_converged: bool,
}

impl SolverIntrospection {
    /// Fold one solve's [`SolveStats`] into the aggregate. `max_iters`
    /// is the solver's iteration cap; stopping strictly below it means
    /// the tolerance was reached (converged).
    pub fn record(&mut self, stats: &SolveStats, warm: bool, max_iters: usize) {
        let converged = stats.iterations < max_iters;
        self.solves += 1;
        if warm {
            self.warm += 1;
        } else {
            self.cold += 1;
        }
        self.iterations_total += stats.iterations as u64;
        self.iterations_max = self.iterations_max.max(stats.iterations as u64);
        if converged {
            self.converged += 1;
        }
        self.last_iterations = stats.iterations;
        self.last_objective = stats.objective;
        self.last_warm = warm;
        self.last_converged = converged;
    }

    /// Merge another aggregate (e.g. another cell's) into this one.
    pub fn absorb(&mut self, other: &SolverIntrospection) {
        self.solves += other.solves;
        self.warm += other.warm;
        self.cold += other.cold;
        self.iterations_total += other.iterations_total;
        self.iterations_max = self.iterations_max.max(other.iterations_max);
        self.converged += other.converged;
        if other.solves > 0 {
            self.last_iterations = other.last_iterations;
            self.last_objective = other.last_objective;
            self.last_warm = other.last_warm;
            self.last_converged = other.last_converged;
        }
    }

    /// Mean iterations per solve (0.0 when nothing was solved).
    pub fn iters_mean(&self) -> f64 {
        if self.solves == 0 {
            0.0
        } else {
            self.iterations_total as f64 / self.solves as f64
        }
    }
}

/// The contract both simulators program against.
///
/// The plane owns the cell's bandwidth split, the service-time vector
/// derived from it, and the expert placement. Consumers must read service
/// times through the plane on every use (never cache them): an epoch or
/// failover re-solve may change them mid-run.
pub trait ControlPlane: Send {
    fn kind(&self) -> ControlKind;
    /// The frozen link context (channel gains, compute, payload).
    fn state(&self) -> &LinkState;
    /// Current bandwidth split (Hz, sums to the cell budget).
    fn bandwidth(&self) -> &[f64];
    /// Current per-device service seconds per token under
    /// [`Self::bandwidth`] (infinite for devices the plane knows are
    /// offline).
    fn t_per_token(&self) -> &[f64];
    /// Current expert → replica map.
    fn placement(&self) -> &Placement;
    /// One-shot allocation for explicit per-block loads — the
    /// coordinator's "given the selection Q, solve the upper level"
    /// step. Does not change the plane's own split. The split lands in
    /// `out` (cleared first) so per-block callers can reuse one buffer.
    fn allocate_into(&mut self, loads: &[PerBlockLoad], out: &mut Vec<f64>);
    /// Allocating convenience wrapper around [`Self::allocate_into`].
    fn allocate_for(&mut self, loads: &[PerBlockLoad]) -> Vec<f64> {
        let mut out = Vec::new();
        self.allocate_into(loads, &mut out);
        out
    }
    /// Re-solve cadence for the DES (None = static plane, no ticks).
    fn epoch_s(&self) -> Option<f64>;
    /// Epoch tick: observed per-device demand (backlog + recently served
    /// tokens) and per-expert token counts since the last tick. Returns
    /// true when allocation or placement changed.
    fn on_epoch(&mut self, demand_tokens: &[f64], expert_tokens: &[f64]) -> bool;
    /// Device liveness changed (failure injection / recovery).
    fn on_topology_change(&mut self, online: &[bool]);
    fn stats(&self) -> ControlStats;
    /// Aggregated cost of every P3 solve this plane performed.
    fn solver_stats(&self) -> SolverIntrospection;
}

/// `Σ|a-b|`.
fn l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Home placement when replication is off, speed-balanced greedy
/// replication otherwise — the construction both simulators shared.
fn initial_placement(n_experts: usize, t_per_token: &[f64], cache_capacity: usize) -> Placement {
    if cache_capacity == 1 {
        Placement::home(n_experts, t_per_token.len(), 1)
    } else {
        // Popularity bias shifts per block, so construction assumes
        // uniform expert load and balances on device speed; the adaptive
        // plane later re-balances from observed counts.
        let uniform_load = vec![1.0; n_experts];
        Placement::optimize(n_experts, t_per_token, &uniform_load, cache_capacity)
    }
}

/// Build the plane for a [`ControlKind`].
pub fn make_plane(
    kind: ControlKind,
    state: LinkState,
    n_experts: usize,
    cache_capacity: usize,
    opts: ControlOptions,
) -> Box<dyn ControlPlane> {
    match kind {
        ControlKind::StaticUniform | ControlKind::StaticOptimal => {
            Box::new(StaticPlane::new(kind, state, n_experts, cache_capacity, opts))
        }
        ControlKind::Adaptive => {
            Box::new(AdaptivePlane::new(state, n_experts, cache_capacity, opts))
        }
    }
}

// ---------------------------------------------------------- StaticPlane

/// Open-loop plane: allocation and placement frozen at construction.
pub struct StaticPlane {
    kind: ControlKind,
    state: LinkState,
    bandwidth: Vec<f64>,
    t_per_token: Vec<f64>,
    placement: Placement,
    /// Warm start threaded between [`ControlPlane::allocate_into`] calls
    /// (consecutive blocks have similar loads).
    warm: Option<Vec<f64>>,
    opts: ControlOptions,
    ws: SolverWorkspace,
    stats: ControlStats,
    solver: SolverIntrospection,
}

impl StaticPlane {
    pub fn new(
        kind: ControlKind,
        state: LinkState,
        n_experts: usize,
        cache_capacity: usize,
        opts: ControlOptions,
    ) -> Self {
        debug_assert!(matches!(
            kind,
            ControlKind::StaticUniform | ControlKind::StaticOptimal
        ));
        let mut stats = ControlStats::default();
        let mut solver = SolverIntrospection::default();
        let bandwidth = match kind {
            ControlKind::StaticOptimal => {
                // One-shot pre-solve assuming every device carries equal
                // expected load — the best a cell can do before traffic.
                let loads = [PerBlockLoad {
                    tokens: vec![1.0; state.n_devices()],
                }];
                stats.resolves = 1;
                let r = state.solve(&loads, &opts.solver, None);
                solver.record(
                    &SolveStats {
                        objective: r.objective,
                        iterations: r.iterations,
                    },
                    false,
                    opts.solver.max_iters,
                );
                r.bandwidth
            }
            _ => state.uniform_split(),
        };
        let t_per_token = state.t_per_token(&bandwidth);
        let placement = initial_placement(n_experts, &t_per_token, cache_capacity);
        // The pre-solve doubles as the warm start for the first
        // allocate_into call, so the coordinator path gets its cost back.
        let warm = match kind {
            ControlKind::StaticOptimal => Some(bandwidth.clone()),
            _ => None,
        };
        Self {
            kind,
            state,
            bandwidth,
            t_per_token,
            placement,
            warm,
            opts,
            ws: SolverWorkspace::new(),
            stats,
            solver,
        }
    }
}

impl ControlPlane for StaticPlane {
    fn kind(&self) -> ControlKind {
        self.kind
    }
    fn state(&self) -> &LinkState {
        &self.state
    }
    fn bandwidth(&self) -> &[f64] {
        &self.bandwidth
    }
    fn t_per_token(&self) -> &[f64] {
        &self.t_per_token
    }
    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn allocate_into(&mut self, loads: &[PerBlockLoad], out: &mut Vec<f64>) {
        match self.kind {
            ControlKind::StaticUniform => self.state.uniform_split_into(out),
            _ => {
                let warm_started = self.warm.is_some();
                let solve = self.state.solve_into(
                    loads,
                    &self.opts.solver,
                    self.warm.as_deref(),
                    &mut self.ws,
                    out,
                );
                self.solver.record(&solve, warm_started, self.opts.solver.max_iters);
                self.stats.resolves += 1;
                // detlint: allow(hotpath-alloc) capacity-0 construction on first solve only; the warm buffer is reused after
                let warm = self.warm.get_or_insert_with(Vec::new);
                warm.clear();
                warm.extend_from_slice(out);
            }
        }
    }

    fn epoch_s(&self) -> Option<f64> {
        None
    }
    fn on_epoch(&mut self, _demand_tokens: &[f64], _expert_tokens: &[f64]) -> bool {
        false
    }
    fn on_topology_change(&mut self, _online: &[bool]) {
        // Static planes keep their frozen split; the dispatcher's online
        // mask already keeps work off dead devices.
    }
    fn stats(&self) -> ControlStats {
        self.stats
    }
    fn solver_stats(&self) -> SolverIntrospection {
        self.solver
    }
}

// -------------------------------------------------------- AdaptivePlane

/// Closed-loop plane: starts from the uniform split and converges to the
/// observed load online.
pub struct AdaptivePlane {
    state: LinkState,
    bandwidth: Vec<f64>,
    t_per_token: Vec<f64>,
    placement: Placement,
    n_experts: usize,
    cache_capacity: usize,
    opts: ControlOptions,
    online: Vec<bool>,
    /// Demand share the last solve used (hysteresis reference).
    last_share: Option<Vec<f64>>,
    ws: SolverWorkspace,
    /// Staged single-block demand for [`Self::resolve_staged`] — filled
    /// in place, never rebuilt.
    staged: [PerBlockLoad; 1],
    /// Re-solve output buffer (swapped with `bandwidth`).
    next_bw: Vec<f64>,
    /// Online-masked demand of the current epoch.
    masked: Vec<f64>,
    /// Demand share of the current epoch.
    share: Vec<f64>,
    /// Floored expert load for the placement re-balance.
    eload: Vec<f64>,
    /// Finite-capped service times for the placement re-balance.
    t_safe: Vec<f64>,
    stats: ControlStats,
    solver: SolverIntrospection,
}

impl AdaptivePlane {
    pub fn new(
        state: LinkState,
        n_experts: usize,
        cache_capacity: usize,
        opts: ControlOptions,
    ) -> Self {
        let bandwidth = state.uniform_split();
        let t_per_token = state.t_per_token(&bandwidth);
        let placement = initial_placement(n_experts, &t_per_token, cache_capacity);
        let online = vec![true; state.n_devices()];
        Self {
            state,
            bandwidth,
            t_per_token,
            placement,
            n_experts,
            cache_capacity,
            opts,
            online,
            last_share: None,
            ws: SolverWorkspace::new(),
            staged: [PerBlockLoad { tokens: Vec::new() }],
            next_bw: Vec::new(),
            masked: Vec::new(),
            share: Vec::new(),
            eload: Vec::new(),
            t_safe: Vec::new(),
            stats: ControlStats::default(),
            solver: SolverIntrospection::default(),
        }
    }

    /// Replica autoscaling: re-balance the placement from observed
    /// per-expert demand instead of the uniform-load assumption. Returns
    /// true when the replica map actually changed.
    fn rebalance_placement(&mut self, expert_tokens: &[f64]) -> bool {
        if self.cache_capacity <= 1 {
            return false;
        }
        let etot: f64 = expert_tokens.iter().sum();
        if etot <= 0.0 || !etot.is_finite() {
            return false;
        }
        // Small floor keeps unobserved experts placeable; finite cap
        // keeps the greedy projections NaN-free when a device is offline
        // (infinite service time).
        let efloor = etot * 1e-3;
        self.eload.clear();
        self.eload.extend(expert_tokens.iter().map(|&q| q.max(efloor)));
        self.t_safe.clear();
        self.t_safe.extend(
            self.t_per_token
                .iter()
                .map(|&t| if t.is_finite() { t } else { 1e9 }),
        );
        let p = Placement::optimize(self.n_experts, &self.t_safe, &self.eload, self.cache_capacity);
        if p != self.placement {
            self.stats.placement_updates += 1;
            self.placement = p;
            true
        } else {
            false
        }
    }

    /// Re-solve P3 for the demand staged in `self.staged`, warm-started
    /// from the current split, and refresh the service-time vector. Zero
    /// heap allocation after warm-up.
    fn resolve_staged(&mut self) {
        let solve = self.state.solve_into(
            &self.staged,
            &self.opts.solver,
            Some(&self.bandwidth),
            &mut self.ws,
            &mut self.next_bw,
        );
        // Epoch/failover re-solves always warm-start from the live split.
        self.solver.record(&solve, true, self.opts.solver.max_iters);
        self.stats.churn_frac +=
            0.5 * l1(&self.next_bw, &self.bandwidth) / self.state.total_bandwidth_hz();
        std::mem::swap(&mut self.bandwidth, &mut self.next_bw);
        self.state.t_per_token_into(&self.bandwidth, &mut self.t_per_token);
        for (k, &on) in self.online.iter().enumerate() {
            if !on {
                self.t_per_token[k] = f64::INFINITY;
            }
        }
        self.stats.resolves += 1;
    }
}

impl ControlPlane for AdaptivePlane {
    fn kind(&self) -> ControlKind {
        ControlKind::Adaptive
    }
    fn state(&self) -> &LinkState {
        &self.state
    }
    fn bandwidth(&self) -> &[f64] {
        &self.bandwidth
    }
    fn t_per_token(&self) -> &[f64] {
        &self.t_per_token
    }
    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn allocate_into(&mut self, loads: &[PerBlockLoad], out: &mut Vec<f64>) {
        let solve = self.state.solve_into(
            loads,
            &self.opts.solver,
            Some(&self.bandwidth),
            &mut self.ws,
            out,
        );
        self.solver.record(&solve, true, self.opts.solver.max_iters);
        self.stats.resolves += 1;
    }

    fn epoch_s(&self) -> Option<f64> {
        Some(self.opts.epoch_s)
    }

    fn on_epoch(&mut self, demand_tokens: &[f64], expert_tokens: &[f64]) -> bool {
        let u = self.state.n_devices();
        debug_assert_eq!(demand_tokens.len(), u);
        debug_assert_eq!(expert_tokens.len(), self.n_experts);
        self.masked.clear();
        self.masked.extend(
            demand_tokens
                .iter()
                .zip(&self.online)
                .map(|(&q, &on)| if on { q.max(0.0) } else { 0.0 }),
        );
        let total: f64 = self.masked.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return false; // idle epoch: keep the current split
        }
        // Bandwidth re-solve, damped by hysteresis on the per-device
        // demand share.
        self.share.clear();
        self.share.extend(self.masked.iter().map(|q| q / total));
        let suppressed = match &self.last_share {
            Some(prev) => l1(&self.share, prev) < self.opts.hysteresis,
            None => false,
        };
        let resolved = if suppressed {
            false
        } else {
            // Floor online devices at 1% of the mean demand so a
            // currently idle device keeps a sliver of spectrum (finite
            // service time) and can win traffic back next epoch.
            let n_on = self.online.iter().filter(|&&on| on).count().max(1);
            let floor = 0.01 * total / n_on as f64;
            self.staged[0].tokens.clear();
            self.staged[0].tokens.extend(
                self.masked
                    .iter()
                    .zip(&self.online)
                    .map(|(&q, &on)| if on { q.max(floor) } else { 0.0 }),
            );
            self.resolve_staged();
            // get_or_insert_with replaces the is_none/expect pair: same
            // first-epoch allocation, no panic path at all.
            let last = self.last_share.get_or_insert_with(|| Vec::with_capacity(u));
            last.clear();
            last.extend_from_slice(&self.share);
            true
        };
        // Replica autoscaling runs on its own trigger: expert popularity
        // can invert while the per-device demand share stays flat (the
        // load-aware dispatcher equalizes queues), so placement must not
        // ride the bandwidth hysteresis.
        let rebalanced = self.rebalance_placement(expert_tokens);
        resolved || rebalanced
    }

    fn on_topology_change(&mut self, online: &[bool]) {
        debug_assert_eq!(online.len(), self.state.n_devices());
        self.online.clear();
        self.online.extend_from_slice(online);
        if !online.iter().any(|&on| on) {
            return; // everything offline: nothing to allocate for
        }
        // Failover re-solve: spread the spectrum over the survivors now
        // rather than waiting for the next epoch's demand signal.
        self.staged[0].tokens.clear();
        self.staged[0]
            .tokens
            .extend(online.iter().map(|&on| if on { 1.0 } else { 0.0 }));
        self.resolve_staged();
        self.last_share = None;
    }

    fn stats(&self) -> ControlStats {
        self.stats
    }
    fn solver_stats(&self) -> SolverIntrospection {
        self.solver
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::devices::Fleet;
    use crate::wireless::ChannelSimulator;

    fn link_state() -> LinkState {
        let cfg = SystemConfig::paper_simulation();
        let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
        let real = chan.expected_realization();
        let fleet = Fleet::new(&cfg.devices, 0);
        let t_comp = fleet.t_comp_nominal(cfg.model.l_comp_flops(cfg.activation_eta));
        LinkState::new(
            &cfg.channel,
            &real,
            &t_comp,
            cfg.model.l_comm_bits(cfg.channel.quant_bits),
        )
    }

    #[test]
    fn static_uniform_matches_even_split() {
        let state = link_state();
        let expect_bw = state.uniform_split();
        let expect_t = state.uniform_t_per_token();
        let mut plane = StaticPlane::new(
            ControlKind::StaticUniform,
            state,
            8,
            2,
            ControlOptions::default(),
        );
        assert_eq!(plane.bandwidth(), expect_bw.as_slice());
        assert_eq!(plane.t_per_token(), expect_t.as_slice());
        assert_eq!(plane.stats().resolves, 0);
        let loads = [PerBlockLoad {
            tokens: vec![10.0; 8],
        }];
        assert_eq!(plane.allocate_for(&loads), expect_bw);
        assert!(!plane.on_epoch(&[5.0; 8], &[1.0; 8]));
        assert_eq!(plane.epoch_s(), None);
    }

    #[test]
    fn static_optimal_presolves_and_beats_uniform_worst_device() {
        let state = link_state();
        let uni_t = state.uniform_t_per_token();
        let plane = StaticPlane::new(
            ControlKind::StaticOptimal,
            state,
            8,
            2,
            ControlOptions::default(),
        );
        assert_eq!(plane.stats().resolves, 1);
        let t = plane.t_per_token();
        let worst_uni = uni_t.iter().cloned().fold(f64::MIN, f64::max);
        let worst_opt = t.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            worst_opt < worst_uni,
            "pre-solve should shrink the slowest device: {worst_opt} vs {worst_uni}"
        );
    }

    #[test]
    fn allocate_into_reuses_buffer_across_blocks() {
        let mut plane = StaticPlane::new(
            ControlKind::StaticOptimal,
            link_state(),
            8,
            2,
            ControlOptions::default(),
        );
        let mut out = Vec::new();
        let mut prev = Vec::new();
        for round in 0..3 {
            let loads = [PerBlockLoad {
                tokens: (0..8).map(|k| 10.0 + (k + round) as f64).collect(),
            }];
            plane.allocate_into(&loads, &mut out);
            assert_eq!(out.len(), 8);
            let sum: f64 = out.iter().sum();
            let total = plane.state().total_bandwidth_hz();
            assert!((sum - total).abs() / total < 1e-6, "round {round}: {sum}");
            // The buffer path must agree with the allocating wrapper on a
            // fresh identically-constructed plane.
            let mut plane2 = StaticPlane::new(
                ControlKind::StaticOptimal,
                link_state(),
                8,
                2,
                ControlOptions::default(),
            );
            let mut expect = Vec::new();
            for loads_prev in prev.iter() {
                plane2.allocate_into(loads_prev, &mut expect);
            }
            assert_eq!(plane2.allocate_for(&loads), out);
            prev.push(loads);
        }
    }

    #[test]
    fn adaptive_resolves_on_demand_shift_and_respects_hysteresis() {
        let mut plane = AdaptivePlane::new(link_state(), 8, 2, ControlOptions::default());
        assert_eq!(plane.epoch_s(), Some(0.25));
        let experts = vec![1.0; 8];
        // First epoch with demand: must re-solve.
        let mut demand = vec![10.0; 8];
        demand[7] = 200.0;
        assert!(plane.on_epoch(&demand, &experts));
        assert_eq!(plane.stats().resolves, 1);
        assert!(plane.stats().churn_frac > 0.0);
        // Identical demand share: hysteresis suppresses the re-solve.
        assert!(!plane.on_epoch(&demand, &experts));
        assert_eq!(plane.stats().resolves, 1);
        // Large shift: re-solves again.
        let mut demand2 = vec![10.0; 8];
        demand2[0] = 300.0;
        assert!(plane.on_epoch(&demand2, &experts));
        assert_eq!(plane.stats().resolves, 2);
        // Idle epoch: no-op.
        assert!(!plane.on_epoch(&[0.0; 8], &experts));
    }

    #[test]
    fn adaptive_shifts_bandwidth_toward_demand() {
        let mut plane = AdaptivePlane::new(link_state(), 8, 1, ControlOptions::default());
        let before = plane.bandwidth().to_vec();
        let mut demand = vec![1.0; 8];
        demand[7] = 500.0; // far, slow device swamped
        plane.on_epoch(&demand, &[1.0; 8]);
        assert!(
            plane.bandwidth()[7] > before[7] * 2.0,
            "swamped device should gain spectrum: {:?}",
            plane.bandwidth()
        );
        // Service time on the hot device improves, and every online
        // device keeps a finite service time (the 1% demand floor).
        for &t in plane.t_per_token() {
            assert!(t.is_finite() && t > 0.0);
        }
    }

    #[test]
    fn adaptive_topology_change_triggers_resolve_and_infinite_service() {
        let mut plane = AdaptivePlane::new(link_state(), 8, 2, ControlOptions::default());
        let mut online = vec![true; 8];
        online[3] = false;
        plane.on_topology_change(&online);
        assert_eq!(plane.stats().resolves, 1);
        assert!(plane.t_per_token()[3].is_infinite());
        assert!(plane.t_per_token()[0].is_finite());
        // Dead device is starved of spectrum.
        assert!(plane.bandwidth()[3] < plane.bandwidth()[0] * 0.2);
    }

    #[test]
    fn adaptive_placement_follows_observed_expert_load() {
        let mut plane = AdaptivePlane::new(link_state(), 8, 2, ControlOptions::default());
        // Construction balances for *uniform* expert load, so expert 0 —
        // homed on the fastest, nearest device — starts unreplicated.
        assert_eq!(plane.placement().replicas(0).len(), 1);
        // Observed traffic then concentrates on expert 0: the autoscaler
        // must give it at least one extra replica.
        let mut experts = vec![1.0; 8];
        experts[0] = 400.0;
        let demand = vec![50.0; 8];
        assert!(plane.on_epoch(&demand, &experts));
        assert!(
            plane.placement().replicas(0).len() >= 2,
            "hot expert not replicated: {:?}",
            plane.placement().replicas(0)
        );
        assert!(plane.stats().placement_updates >= 1);
    }

    #[test]
    fn make_plane_dispatches_on_kind() {
        for kind in ControlKind::all() {
            let p = make_plane(kind, link_state(), 8, 2, ControlOptions::default());
            assert_eq!(p.kind(), kind);
            assert_eq!(p.t_per_token().len(), 8);
            p.placement().validate().unwrap();
        }
    }

    #[test]
    fn solver_introspection_tracks_every_solve() {
        // Static uniform never solves.
        let uni = StaticPlane::new(
            ControlKind::StaticUniform,
            link_state(),
            8,
            2,
            ControlOptions::default(),
        );
        assert_eq!(uni.solver_stats(), SolverIntrospection::default());
        assert_eq!(uni.solver_stats().iters_mean(), 0.0);

        // Static optimal: one cold pre-solve, then warm per-block solves.
        let mut opt = StaticPlane::new(
            ControlKind::StaticOptimal,
            link_state(),
            8,
            2,
            ControlOptions::default(),
        );
        let s = opt.solver_stats();
        assert_eq!(s.solves, 1);
        assert_eq!(s.cold, 1);
        assert_eq!(s.warm, 0);
        assert_eq!(s.converged, 1, "default-tolerance pre-solve must converge");
        let loads = [PerBlockLoad {
            tokens: (0..8).map(|k| 10.0 + k as f64).collect(),
        }];
        opt.allocate_for(&loads);
        let s = opt.solver_stats();
        assert_eq!(s.solves, 2);
        assert_eq!(s.warm, 1, "per-block solve warm-starts from the pre-solve");
        assert!(s.last_warm);
        assert!(s.iterations_max >= s.last_iterations as u64);

        // Adaptive: epoch re-solves are warm-started.
        let mut ad = AdaptivePlane::new(link_state(), 8, 2, ControlOptions::default());
        let mut demand = vec![10.0; 8];
        demand[7] = 200.0;
        assert!(ad.on_epoch(&demand, &[1.0; 8]));
        let s = ad.solver_stats();
        assert_eq!(s.solves, 1);
        assert_eq!(s.warm, 1);
        assert_eq!(s.solves, ad.stats().resolves as u64);
    }

    #[test]
    fn solver_introspection_absorb_merges() {
        let mut a = SolverIntrospection::default();
        a.record(
            &SolveStats {
                objective: 1.0,
                iterations: 10,
            },
            false,
            400,
        );
        let mut b = SolverIntrospection::default();
        b.record(
            &SolveStats {
                objective: 2.0,
                iterations: 30,
            },
            true,
            30, // hit the cap: not converged
        );
        a.absorb(&b);
        assert_eq!(a.solves, 2);
        assert_eq!(a.warm, 1);
        assert_eq!(a.cold, 1);
        assert_eq!(a.iterations_total, 40);
        assert_eq!(a.iterations_max, 30);
        assert_eq!(a.converged, 1);
        assert_eq!(a.last_iterations, 30);
        assert!(!a.last_converged);
        assert!((a.iters_mean() - 20.0).abs() < 1e-12);
        // Absorbing an empty aggregate keeps the last-solve fields.
        a.absorb(&SolverIntrospection::default());
        assert_eq!(a.last_iterations, 30);
        assert_eq!(a.solves, 2);
    }
}
