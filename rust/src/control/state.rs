//! [`LinkState`] — the one place where a cell's per-device
//! [`DeviceLink`]s are assembled from channel + fleet parameters.
//!
//! Before the control layer existed, both simulators duplicated the same
//! ritual: build an [`AllocationInput`], call `.links()`, and map a
//! bandwidth split through `t_per_token`. `LinkState` owns that ritual:
//! construct once per cell (or per batch under fading), then ask it for
//! service times under any split, or for a P3 solve (optionally
//! warm-started from the previous allocation).

use crate::config::ChannelConfig;
use crate::latency::TokenLatencies;
use crate::optim::solver::DeviceLink;
use crate::optim::{
    minimize_sum_max_warm, minimize_sum_max_ws, PerBlockLoad, SolveStats, SolverOptions,
    SolverResult, SolverWorkspace,
};
use crate::wireless::bandwidth::AllocationInput;
use crate::wireless::ChannelRealization;

/// Frozen per-cell link context: the Eq. (8) inputs for every device.
#[derive(Debug, Clone)]
pub struct LinkState {
    links: Vec<DeviceLink>,
    total_bandwidth_hz: f64,
}

impl LinkState {
    /// Assemble links for one cell. `t_comp_per_token[k]` is `L_comp/C_k`
    /// (infinite for offline devices); `l_comm_bits` is Eq. (4).
    pub fn new(
        channel: &ChannelConfig,
        realization: &ChannelRealization,
        t_comp_per_token: &[f64],
        l_comm_bits: f64,
    ) -> Self {
        assert_eq!(
            realization.n_devices(),
            t_comp_per_token.len(),
            "realization/fleet arity mismatch"
        );
        let loads: [PerBlockLoad; 0] = [];
        let input = AllocationInput {
            channel_cfg: channel,
            realization,
            loads: &loads,
            t_comp_per_token,
            l_comm_bits,
        };
        Self {
            links: input.links(),
            total_bandwidth_hz: channel.total_bandwidth_hz,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.links.len()
    }

    pub fn links(&self) -> &[DeviceLink] {
        &self.links
    }

    pub fn total_bandwidth_hz(&self) -> f64 {
        self.total_bandwidth_hz
    }

    /// The even split `B_k = B/U`.
    pub fn uniform_split(&self) -> Vec<f64> {
        let u = self.links.len();
        vec![self.total_bandwidth_hz / u as f64; u]
    }

    /// [`Self::uniform_split`] into a reused buffer (cleared first).
    pub fn uniform_split_into(&self, out: &mut Vec<f64>) {
        let u = self.links.len();
        out.clear();
        out.resize(u, self.total_bandwidth_hz / u as f64);
    }

    /// Per-device service seconds per token (Eq. (8)) under a split.
    pub fn t_per_token(&self, bandwidth: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.links.len());
        self.t_per_token_into(bandwidth, &mut out);
        out
    }

    /// [`Self::t_per_token`] into a reused buffer (cleared first) — the
    /// control plane's post-re-solve refresh without an allocation.
    pub fn t_per_token_into(&self, bandwidth: &[f64], out: &mut Vec<f64>) {
        assert_eq!(bandwidth.len(), self.links.len(), "split arity mismatch");
        out.clear();
        out.extend(
            self.links
                .iter()
                .zip(bandwidth)
                .map(|(l, &b)| l.t_per_token(b)),
        );
    }

    /// Service times under the uniform split — what selection policies
    /// consume (§IV-A) and what the static-uniform plane serves with.
    pub fn uniform_t_per_token(&self) -> Vec<f64> {
        self.t_per_token(&self.uniform_split())
    }

    /// [`TokenLatencies`] view of a split (the latency model's input).
    pub fn token_latencies(&self, bandwidth: &[f64]) -> TokenLatencies {
        TokenLatencies::from_links(&self.links, bandwidth)
    }

    /// Solve P3 for the given loads, optionally warm-starting from a
    /// previous allocation (e.g. the last control epoch's split).
    ///
    /// Allocating convenience wrapper; hot paths (epoch ticks, per-block
    /// solves) should hold a [`SolverWorkspace`] and use
    /// [`Self::solve_into`].
    pub fn solve(
        &self,
        loads: &[PerBlockLoad],
        opts: &SolverOptions,
        warm: Option<&[f64]>,
    ) -> SolverResult {
        minimize_sum_max_warm(&self.links, loads, self.total_bandwidth_hz, opts, warm)
    }

    /// Allocation-free P3 solve: scratch comes from `ws`, the split lands
    /// in `out` (cleared first). Same mathematics as [`Self::solve`].
    pub fn solve_into(
        &self,
        loads: &[PerBlockLoad],
        opts: &SolverOptions,
        warm: Option<&[f64]>,
        ws: &mut SolverWorkspace,
        out: &mut Vec<f64>,
    ) -> SolveStats {
        minimize_sum_max_ws(
            &self.links,
            loads,
            self.total_bandwidth_hz,
            opts,
            warm,
            ws,
            out,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::devices::Fleet;
    use crate::wireless::ChannelSimulator;

    fn state() -> LinkState {
        let cfg = SystemConfig::paper_simulation();
        let chan = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
        let real = chan.expected_realization();
        let fleet = Fleet::new(&cfg.devices, 0);
        let t_comp = fleet.t_comp_nominal(cfg.model.l_comp_flops(cfg.activation_eta));
        LinkState::new(
            &cfg.channel,
            &real,
            &t_comp,
            cfg.model.l_comm_bits(cfg.channel.quant_bits),
        )
    }

    #[test]
    fn uniform_split_partitions_budget() {
        let s = state();
        assert_eq!(s.n_devices(), 8);
        let b = s.uniform_split();
        assert_eq!(b.len(), 8);
        let sum: f64 = b.iter().sum();
        assert!((sum - s.total_bandwidth_hz()).abs() < 1e-3);
    }

    #[test]
    fn t_per_token_matches_links_directly() {
        let s = state();
        let bw = s.uniform_split();
        let t = s.t_per_token(&bw);
        for (k, link) in s.links().iter().enumerate() {
            assert_eq!(t[k], link.t_per_token(bw[k]));
            assert!(t[k].is_finite() && t[k] > 0.0);
        }
        assert_eq!(s.token_latencies(&bw).per_token, t);
    }

    #[test]
    fn far_device_is_slower_under_uniform_split() {
        // Preset orders devices by increasing distance; device 7 is also
        // the weakest compute, so it must be the slowest end to end.
        let t = state().uniform_t_per_token();
        assert!(t[7] > t[0], "t={t:?}");
    }

    #[test]
    fn solve_equalizes_loaded_devices() {
        let s = state();
        let loads = [PerBlockLoad {
            tokens: vec![50.0; 8],
        }];
        let r = s.solve(&loads, &SolverOptions::default(), None);
        let sum: f64 = r.bandwidth.iter().sum();
        assert!((sum - s.total_bandwidth_hz()).abs() / s.total_bandwidth_hz() < 1e-6);
        let t = s.t_per_token(&r.bandwidth);
        let per_dev: Vec<f64> = t.iter().map(|tk| 50.0 * tk).collect();
        let max = per_dev.iter().cloned().fold(f64::MIN, f64::max);
        let min = per_dev.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) / max < 0.05, "not equalised: {per_dev:?}");
    }
}
