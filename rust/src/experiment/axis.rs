//! Sweep axes: every knob a grid can vary, behind one typed dispatch.
//!
//! An [`Axis`] names a scenario knob (arrival rate, control plane,
//! handover policy, backhaul, queue limit, cache capacity, cell/device
//! count, seed, epoch cadence, hysteresis, backlog-delta trigger,
//! energy weight, battery capacity, device-class preset); an
//! [`AxisValue`] is one setting of it. [`Axis::apply`] is the *single*
//! place any axis mutates a [`Scenario`] — adding a knob to the
//! experiment API is one new variant plus one `apply` arm, not a third
//! hand-rolled sweep function. [`AxisSpec::parse`] turns the CLI's
//! `--axis name=spec` strings (comma lists and `start:step:end` ranges)
//! into validated axes.

use super::grid::Scenario;
use crate::config::{ControlKind, DispatchKind, DropPolicy, EnergyConfig, HandoverPolicy};
use anyhow::Result;

/// A sweepable scenario knob. Numeric axes carry [`AxisValue::Num`]
/// settings and appear as a CSV coordinate column ([`Axis::key`]);
/// word axes ([`ControlKind`], [`HandoverPolicy`], …) carry
/// [`AxisValue::Word`] settings and appear in the row label only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Axis {
    /// Poisson arrival rate (requests/s). The only axis that varies the
    /// workload instead of the [`crate::config::ClusterConfig`]: points
    /// that differ only in *other* axes replay identical arrival
    /// streams, so rows compare policies on the same traffic.
    ArrivalRate,
    /// [`ControlKind`] (static_uniform / static_optimal / adaptive).
    ControlPlane,
    /// [`HandoverPolicy`] (none / rehome_on_arrival / borrow_expert).
    Handover,
    /// One-way inter-cell backhaul seconds per token.
    Backhaul,
    /// Per-device queue bound in seconds of backlog (0 = unbounded).
    QueueLimit,
    /// [`DropPolicy`] applied at the queue bound.
    Drop,
    /// Experts a device can cache (1 = no replication).
    CacheCapacity,
    /// [`DispatchKind`] (load_aware / static).
    Dispatch,
    /// Cell count (extra cells synthesized from cell 0's template).
    Cells,
    /// Devices per cell, truncating each cell's fleet to its first `n`.
    Devices,
    /// RNG seed (gates, channels *and* the arrival stream).
    Seed,
    /// Adaptive re-solve cadence in virtual seconds.
    ControlEpoch,
    /// Demand-share hysteresis damping adaptive re-solves.
    ControlHysteresis,
    /// Backlog-delta trigger in queued seconds (0 = epoch cadence only).
    BacklogDelta,
    /// Mean time to failure per device in seconds (0 = no stochastic
    /// crashes); see [`crate::config::FaultConfig::mttf_s`].
    Mttf,
    /// Mean time to repair a crashed device in seconds.
    Mttr,
    /// Mean time between straggler episodes per device in seconds
    /// (0 = no stochastic stragglers).
    Straggler,
    /// Per-request completion deadline in seconds (0 = SLO accounting
    /// off).
    Deadline,
    /// Hedged dispatch on deadline pressure (`on` / `off`).
    Hedge,
    /// Weight of the energy term in the dispatch objective (0 = pure
    /// latency); see [`crate::config::ClusterConfig::energy_weight`].
    EnergyWeight,
    /// Per-device battery capacity in joules (0 = mains-powered); see
    /// [`crate::config::EnergyConfig::battery_j`].
    Battery,
    /// Device-class preset (`uniform` / `mixed`) assigning heterogeneous
    /// energy multipliers round-robin across each cell's fleet.
    DeviceClass,
}

/// One setting of an axis.
#[derive(Debug, Clone, PartialEq)]
pub enum AxisValue {
    Num(f64),
    Word(String),
}

impl AxisValue {
    pub fn num(v: f64) -> Self {
        AxisValue::Num(v)
    }

    pub fn word(s: &str) -> Self {
        AxisValue::Word(s.to_string())
    }

    /// Numeric value lists (`Axis::ArrivalRate`, bounds, counts, …).
    pub fn nums(vs: &[f64]) -> Vec<Self> {
        vs.iter().map(|&v| AxisValue::Num(v)).collect()
    }

    /// Word value lists (`Axis::ControlPlane`, `Axis::Handover`, …).
    pub fn words(ws: &[&str]) -> Vec<Self> {
        ws.iter().map(|w| AxisValue::word(w)).collect()
    }

    pub fn as_num(&self) -> Result<f64> {
        match self {
            AxisValue::Num(v) => Ok(*v),
            AxisValue::Word(w) => anyhow::bail!("expected a number, got '{w}'"),
        }
    }

    pub fn as_word(&self) -> Result<&str> {
        match self {
            AxisValue::Word(w) => Ok(w),
            AxisValue::Num(v) => anyhow::bail!("expected a word, got {v}"),
        }
    }
}

impl std::fmt::Display for AxisValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AxisValue::Num(v) => write!(f, "{v}"),
            AxisValue::Word(w) => write!(f, "{w}"),
        }
    }
}

/// `v` as a positive integer count (cache slots, cells, devices).
fn as_count(v: &AxisValue, what: &str, min: usize) -> Result<usize> {
    let n = v.as_num()?;
    anyhow::ensure!(
        n.is_finite() && n.fract() == 0.0 && n >= min as f64 && n <= u32::MAX as f64,
        "{what} must be an integer >= {min}, got {n}"
    );
    Ok(n as usize)
}

/// `v` as a seed (non-negative integer exactly representable in f64).
fn as_seed(v: &AxisValue) -> Result<u64> {
    let n = v.as_num()?;
    anyhow::ensure!(
        n.is_finite() && n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
        "seed must be a non-negative integer <= 2^53, got {n}"
    );
    Ok(n as u64)
}

impl Axis {
    /// Every axis, in the order the CLI help lists them.
    pub fn all() -> [Axis; 22] {
        [
            Axis::ArrivalRate,
            Axis::ControlPlane,
            Axis::Handover,
            Axis::Backhaul,
            Axis::QueueLimit,
            Axis::Drop,
            Axis::CacheCapacity,
            Axis::Dispatch,
            Axis::Cells,
            Axis::Devices,
            Axis::Seed,
            Axis::ControlEpoch,
            Axis::ControlHysteresis,
            Axis::BacklogDelta,
            Axis::Mttf,
            Axis::Mttr,
            Axis::Straggler,
            Axis::Deadline,
            Axis::Hedge,
            Axis::EnergyWeight,
            Axis::Battery,
            Axis::DeviceClass,
        ]
    }

    /// Canonical CLI name (`--axis <name>=<spec>`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Axis::ArrivalRate => "rate",
            Axis::ControlPlane => "control",
            Axis::Handover => "handover",
            Axis::Backhaul => "backhaul",
            Axis::QueueLimit => "queue_limit",
            Axis::Drop => "drop",
            Axis::CacheCapacity => "cache",
            Axis::Dispatch => "dispatch",
            Axis::Cells => "cells",
            Axis::Devices => "devices",
            Axis::Seed => "seed",
            Axis::ControlEpoch => "epoch",
            Axis::ControlHysteresis => "hysteresis",
            Axis::BacklogDelta => "backlog_delta",
            Axis::Mttf => "mttf",
            Axis::Mttr => "mttr",
            Axis::Straggler => "straggler",
            Axis::Deadline => "deadline",
            Axis::Hedge => "hedge",
            Axis::EnergyWeight => "energy_weight",
            Axis::Battery => "battery",
            Axis::DeviceClass => "device_class",
        }
    }

    /// Schema key: the CSV coordinate column header for numeric axes and
    /// the JSON coordinate key for every axis.
    pub fn key(&self) -> &'static str {
        match self {
            Axis::ArrivalRate => "rate_rps",
            Axis::ControlPlane => "control",
            Axis::Handover => "handover",
            Axis::Backhaul => "backhaul_s_per_token",
            Axis::QueueLimit => "queue_limit_s",
            Axis::Drop => "drop_policy",
            Axis::CacheCapacity => "cache_capacity",
            Axis::Dispatch => "dispatch",
            Axis::Cells => "cells",
            Axis::Devices => "devices_per_cell",
            Axis::Seed => "seed",
            Axis::ControlEpoch => "control_epoch_s",
            Axis::ControlHysteresis => "control_hysteresis",
            Axis::BacklogDelta => "control_backlog_delta_s",
            Axis::Mttf => "mttf_s",
            Axis::Mttr => "mttr_s",
            Axis::Straggler => "straggler_mtbf_s",
            Axis::Deadline => "deadline_s",
            Axis::Hedge => "hedge",
            Axis::EnergyWeight => "energy_weight",
            Axis::Battery => "battery_j",
            Axis::DeviceClass => "device_class",
        }
    }

    /// Whether settings are numbers (and get a CSV coordinate column).
    pub fn is_numeric(&self) -> bool {
        !matches!(
            self,
            Axis::ControlPlane
                | Axis::Handover
                | Axis::Drop
                | Axis::Dispatch
                | Axis::Hedge
                | Axis::DeviceClass
        )
    }

    /// Whether applying a setting mutates the
    /// [`crate::config::ClusterConfig`]. [`Grid`](super::Grid) clones one
    /// scenario per distinct combination of these axes — never per point
    /// — so a pure arrival-rate sweep shares the caller's config.
    pub fn touches_config(&self) -> bool {
        !matches!(self, Axis::ArrivalRate)
    }

    /// Parse an axis name: canonical CLI name, schema key, or alias
    /// (`-` and `_` are interchangeable).
    pub fn parse(name: &str) -> Result<Axis> {
        let n = name.trim().to_lowercase().replace('-', "_");
        Ok(match n.as_str() {
            "rate" | "rate_rps" | "arrival_rate" => Axis::ArrivalRate,
            "control" | "control_plane" | "plane" => Axis::ControlPlane,
            "handover" => Axis::Handover,
            "backhaul" | "backhaul_s_per_token" => Axis::Backhaul,
            "queue_limit" | "queue_limit_s" => Axis::QueueLimit,
            "drop" | "drop_policy" => Axis::Drop,
            "cache" | "cache_capacity" => Axis::CacheCapacity,
            "dispatch" => Axis::Dispatch,
            "cells" | "n_cells" => Axis::Cells,
            "devices" | "devices_per_cell" => Axis::Devices,
            "seed" => Axis::Seed,
            "epoch" | "control_epoch" | "control_epoch_s" => Axis::ControlEpoch,
            "hysteresis" | "control_hysteresis" => Axis::ControlHysteresis,
            "backlog_delta" | "control_backlog_delta_s" => Axis::BacklogDelta,
            "mttf" | "mttf_s" => Axis::Mttf,
            "mttr" | "mttr_s" => Axis::Mttr,
            "straggler" | "straggler_mtbf_s" => Axis::Straggler,
            "deadline" | "deadline_s" => Axis::Deadline,
            "hedge" => Axis::Hedge,
            "energy_weight" | "energy" => Axis::EnergyWeight,
            "battery" | "battery_j" => Axis::Battery,
            "device_class" | "class" => Axis::DeviceClass,
            other => anyhow::bail!(
                "unknown axis '{other}' (valid: {})",
                Axis::all().map(|a| a.as_str()).join(", ")
            ),
        })
    }

    /// Parse one CLI value for this axis. Word values are normalised to
    /// their canonical spelling (`rehome` -> `rehome_on_arrival`), so
    /// labels and JSON coordinates are alias-independent.
    pub fn parse_value(&self, s: &str) -> Result<AxisValue> {
        let s = s.trim();
        if self.is_numeric() {
            let v: f64 = s
                .parse()
                .map_err(|e| anyhow::anyhow!("axis {}: bad number '{s}': {e}", self.as_str()))?;
            return Ok(AxisValue::Num(v));
        }
        Ok(match self {
            Axis::ControlPlane => AxisValue::word(ControlKind::parse(s)?.as_str()),
            Axis::Handover => AxisValue::word(HandoverPolicy::parse(s)?.as_str()),
            Axis::Drop => AxisValue::word(DropPolicy::parse(s)?.as_str()),
            Axis::Dispatch => AxisValue::word(DispatchKind::parse(s)?.as_str()),
            Axis::Hedge => match s.to_lowercase().as_str() {
                "on" | "true" | "1" => AxisValue::word("on"),
                "off" | "false" | "0" => AxisValue::word("off"),
                other => anyhow::bail!("axis hedge: expected on/off, got '{other}'"),
            },
            Axis::DeviceClass => {
                let w = s.to_lowercase();
                EnergyConfig::class_preset(&w)?; // validate the preset name
                AxisValue::Word(w)
            }
            _ => unreachable!("numeric axes handled above"),
        })
    }

    /// The single dispatch every axis mutates a scenario through.
    /// Out-of-range numeric settings that map onto config fields are
    /// left to [`crate::config::ClusterConfig::validate`], so axis
    /// application and `--config` files share one validation story.
    pub fn apply(&self, sc: &mut Scenario, v: &AxisValue) -> Result<()> {
        match self {
            Axis::ArrivalRate => {
                let r = v.as_num()?;
                anyhow::ensure!(
                    r.is_finite() && r > 0.0,
                    "arrival rate must be finite and positive, got {r}"
                );
                sc.rate_rps = r;
            }
            Axis::ControlPlane => sc.cluster.control = ControlKind::parse(v.as_word()?)?,
            Axis::Handover => sc.cluster.handover = HandoverPolicy::parse(v.as_word()?)?,
            Axis::Backhaul => {
                // The scalar axis must always take effect: a base config
                // carrying a per-pair matrix would otherwise shadow every
                // swept value (pairs read the matrix before the scalar).
                sc.cluster.backhaul_s_per_token = v.as_num()?;
                sc.cluster.backhaul_matrix = None;
            }
            Axis::QueueLimit => sc.cluster.queue_limit_s = v.as_num()?,
            Axis::Drop => sc.cluster.drop_policy = DropPolicy::parse(v.as_word()?)?,
            Axis::CacheCapacity => {
                sc.cluster.cache_capacity = as_count(v, "cache capacity", 1)?;
            }
            Axis::Dispatch => sc.cluster.dispatch = DispatchKind::parse(v.as_word()?)?,
            Axis::Cells => {
                let n = as_count(v, "cell count", 1)?;
                sc.cluster = sc.cluster.clone().with_n_cells(n);
            }
            Axis::Devices => {
                let n = as_count(v, "devices per cell", 1)?;
                for cell in &mut sc.cluster.cells {
                    anyhow::ensure!(
                        n <= cell.devices.len(),
                        "{}: cannot grow the fleet ({} devices) to {n} via the devices axis",
                        cell.name,
                        cell.devices.len()
                    );
                    cell.devices.truncate(n);
                }
            }
            Axis::Seed => {
                let s = as_seed(v)?;
                sc.cluster.seed = s;
                sc.workload_seed = s;
            }
            Axis::ControlEpoch => sc.cluster.control_epoch_s = v.as_num()?,
            Axis::ControlHysteresis => sc.cluster.control_hysteresis = v.as_num()?,
            Axis::BacklogDelta => sc.cluster.control_backlog_delta_s = v.as_num()?,
            Axis::Mttf => sc.cluster.faults.mttf_s = v.as_num()?,
            Axis::Mttr => sc.cluster.faults.mttr_s = v.as_num()?,
            Axis::Straggler => sc.cluster.faults.straggler_mtbf_s = v.as_num()?,
            Axis::Deadline => sc.cluster.deadline_s = v.as_num()?,
            Axis::Hedge => sc.cluster.hedge = v.as_word()? == "on",
            Axis::EnergyWeight => sc.cluster.energy_weight = v.as_num()?,
            Axis::Battery => sc.cluster.energy.battery_j = v.as_num()?,
            Axis::DeviceClass => {
                sc.cluster.energy.classes = EnergyConfig::class_preset(v.as_word()?)?;
            }
        }
        Ok(())
    }

    /// One coordinate of a row label. Control-plane settings label bare
    /// (`adaptive@rate=2`), matching the legacy comparison-sweep rows;
    /// every other axis labels `name=value`.
    pub fn coord_label(&self, v: &AxisValue) -> String {
        match self {
            Axis::ControlPlane => v.to_string(),
            _ => format!("{}={v}", self.as_str()),
        }
    }
}

/// One parsed `--axis name=spec` argument: the axis plus its settings.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisSpec {
    pub axis: Axis,
    pub values: Vec<AxisValue>,
}

impl AxisSpec {
    /// Parse `name=spec`, where `spec` is a comma list (`0.5,1,2` or
    /// `none,rehome,borrow`) or an inclusive numeric range
    /// `start:step:end` (`0:0.5:2` -> 0, 0.5, 1, 1.5, 2; descending
    /// ranges use a negative step).
    pub fn parse(s: &str) -> Result<AxisSpec> {
        let (name, spec) = s
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("axis spec must be name=values, got '{s}'"))?;
        let axis = Axis::parse(name)?;
        let spec = spec.trim();
        anyhow::ensure!(!spec.is_empty(), "axis {} has an empty spec", axis.as_str());
        let values = if axis.is_numeric() && spec.contains(':') {
            Self::parse_range(axis, spec)?
        } else {
            spec.split(',')
                .map(|w| axis.parse_value(w))
                .collect::<Result<Vec<_>>>()?
        };
        anyhow::ensure!(!values.is_empty(), "axis {} has no values", axis.as_str());
        Ok(AxisSpec { axis, values })
    }

    fn parse_range(axis: Axis, spec: &str) -> Result<Vec<AxisValue>> {
        let parts: Vec<&str> = spec.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "axis {}: range spec must be start:step:end, got '{spec}'",
            axis.as_str()
        );
        let mut nums = [0.0f64; 3];
        for (slot, part) in nums.iter_mut().zip(&parts) {
            *slot = axis.parse_value(part)?.as_num()?;
        }
        let [start, step, end] = nums;
        anyhow::ensure!(
            start.is_finite() && step.is_finite() && end.is_finite(),
            "axis {}: range '{spec}' must be finite",
            axis.as_str()
        );
        anyhow::ensure!(
            step != 0.0,
            "axis {}: range step must be non-zero",
            axis.as_str()
        );
        anyhow::ensure!(
            (end - start) * step >= 0.0,
            "axis {}: range '{spec}' steps away from its end",
            axis.as_str()
        );
        // `start + i*step` (not repeated addition) keeps long ranges
        // from accumulating float drift; the epsilon keeps an exact-end
        // range inclusive. Each value is then rounded to 12 significant
        // digits so labels/CSV/JSON coordinates print as typed
        // (0.1:0.1:0.4 yields 0.3, not 0.30000000000000004) — the same
        // values a comma list would parse.
        let eps = step.abs() * 1e-9;
        let mut values = Vec::new();
        for i in 0..=100_000u32 {
            let raw = start + step * f64::from(i);
            // detlint: allow(panic) parsing back our own {:.12e} formatting is infallible
            let v: f64 = format!("{raw:.12e}").parse().expect("formatted float");
            let past_end = if step > 0.0 { v > end + eps } else { v < end - eps };
            if past_end {
                return Ok(values);
            }
            values.push(AxisValue::Num(v));
        }
        anyhow::bail!(
            "axis {}: range '{spec}' expands to more than 100000 values",
            axis.as_str()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::util::Json;
    use crate::workload::Benchmark;

    fn scenario() -> Scenario {
        Scenario::new(ClusterConfig::edge_default(), 16, Benchmark::Piqa)
    }

    #[test]
    fn parse_accepts_canonical_names_keys_and_aliases() {
        for a in Axis::all() {
            assert_eq!(Axis::parse(a.as_str()).unwrap(), a, "{}", a.as_str());
            assert_eq!(Axis::parse(a.key()).unwrap(), a, "{}", a.key());
        }
        assert_eq!(Axis::parse("queue-limit").unwrap(), Axis::QueueLimit);
        assert_eq!(Axis::parse("backlog-delta").unwrap(), Axis::BacklogDelta);
        assert_eq!(Axis::parse("RATE").unwrap(), Axis::ArrivalRate);
        assert!(Axis::parse("bogus").is_err());
    }

    #[test]
    fn device_class_axis_validates_presets() {
        let v = Axis::DeviceClass.parse_value("Mixed").unwrap();
        assert_eq!(v, AxisValue::word("mixed"));
        assert!(Axis::DeviceClass.parse_value("bogus").is_err());
        assert!(!Axis::DeviceClass.is_numeric());
    }

    #[test]
    fn parse_value_normalises_word_aliases() {
        let v = Axis::Handover.parse_value("rehome").unwrap();
        assert_eq!(v, AxisValue::word("rehome_on_arrival"));
        let v = Axis::ControlPlane.parse_value("uniform").unwrap();
        assert_eq!(v, AxisValue::word("static_uniform"));
        let v = Axis::Drop.parse_value("shed").unwrap();
        assert_eq!(v, AxisValue::word("shed_tokens"));
        assert!(Axis::Handover.parse_value("bogus").is_err());
        assert!(Axis::ArrivalRate.parse_value("fast").is_err());
    }

    #[test]
    fn spec_parses_lists_and_ranges() {
        let s = AxisSpec::parse("rate=0.5,1,2").unwrap();
        assert_eq!(s.axis, Axis::ArrivalRate);
        assert_eq!(s.values, AxisValue::nums(&[0.5, 1.0, 2.0]));

        let s = AxisSpec::parse("queue_limit=0:0.5:2").unwrap();
        assert_eq!(s.axis, Axis::QueueLimit);
        assert_eq!(s.values, AxisValue::nums(&[0.0, 0.5, 1.0, 1.5, 2.0]));

        // Descending range, negative step. The 12-significant-digit
        // clean-up makes non-dyadic steps land exactly on the values a
        // comma list would parse.
        let s = AxisSpec::parse("backhaul=3e-4:-1e-4:1e-4").unwrap();
        assert_eq!(s.values.len(), 3);
        assert_eq!(s.values[0], AxisValue::Num(3e-4));
        assert_eq!(s.values[1], AxisValue::Num(2e-4));
        assert_eq!(s.values[2], AxisValue::Num(1e-4));

        // The classic accumulation case: 0.1 steps print as typed.
        let s = AxisSpec::parse("rate=0.1:0.1:0.4").unwrap();
        assert_eq!(s.values, AxisValue::nums(&[0.1, 0.2, 0.3, 0.4]));

        // Degenerate range: one point.
        let s = AxisSpec::parse("rate=2:1:2").unwrap();
        assert_eq!(s.values, AxisValue::nums(&[2.0]));

        let s = AxisSpec::parse("handover=none,rehome,borrow").unwrap();
        assert_eq!(
            s.values,
            AxisValue::words(&["none", "rehome_on_arrival", "borrow_expert"])
        );
    }

    #[test]
    fn spec_rejects_bad_input() {
        assert!(AxisSpec::parse("rate").is_err(), "missing '='");
        assert!(AxisSpec::parse("bogus=1,2").is_err(), "unknown axis");
        assert!(AxisSpec::parse("rate=").is_err(), "empty spec");
        assert!(AxisSpec::parse("rate=1,x").is_err(), "bad number in list");
        assert!(AxisSpec::parse("rate=0:0:2").is_err(), "zero step");
        assert!(AxisSpec::parse("rate=0:1").is_err(), "two-part range");
        assert!(AxisSpec::parse("rate=0:1:2:3").is_err(), "four-part range");
        assert!(AxisSpec::parse("rate=2:1:0").is_err(), "step away from end");
        assert!(AxisSpec::parse("handover=none,bogus").is_err(), "bad word");
    }

    /// Every axis variant applies onto a scenario that still passes
    /// `ClusterConfig::validate` and survives the JSON round-trip — the
    /// guarantee that grid points and `--config` files agree on what a
    /// valid configuration is.
    #[test]
    fn apply_round_trips_every_variant_against_config_validation() {
        for axis in Axis::all() {
            let value = match axis {
                Axis::ArrivalRate => AxisValue::num(3.5),
                Axis::ControlPlane => AxisValue::word("adaptive"),
                Axis::Handover => AxisValue::word("borrow_expert"),
                Axis::Backhaul => AxisValue::num(5e-4),
                Axis::QueueLimit => AxisValue::num(1.5),
                Axis::Drop => AxisValue::word("shed_tokens"),
                Axis::CacheCapacity => AxisValue::num(3.0),
                Axis::Dispatch => AxisValue::word("static"),
                Axis::Cells => AxisValue::num(3.0),
                Axis::Devices => AxisValue::num(6.0),
                Axis::Seed => AxisValue::num(42.0),
                Axis::ControlEpoch => AxisValue::num(0.5),
                Axis::ControlHysteresis => AxisValue::num(0.1),
                Axis::BacklogDelta => AxisValue::num(0.25),
                Axis::Mttf => AxisValue::num(50.0),
                Axis::Mttr => AxisValue::num(2.0),
                Axis::Straggler => AxisValue::num(20.0),
                Axis::Deadline => AxisValue::num(2.5),
                Axis::Hedge => AxisValue::word("on"),
                Axis::EnergyWeight => AxisValue::num(0.5),
                Axis::Battery => AxisValue::num(250.0),
                Axis::DeviceClass => AxisValue::word("mixed"),
            };
            let mut sc = scenario();
            // Devices truncates below 8 experts/cell feasibility at
            // cache 1; edge_default has cache 2, 6*2 >= 8 holds.
            axis.apply(&mut sc, &value).unwrap_or_else(|e| {
                panic!("axis {} failed to apply: {e}", axis.as_str());
            });
            sc.cluster
                .validate()
                .unwrap_or_else(|e| panic!("axis {} broke validation: {e}", axis.as_str()));
            let back = ClusterConfig::from_json(
                &Json::parse(&sc.cluster.to_json().to_string()).unwrap(),
            )
            .unwrap();
            assert_eq!(back, sc.cluster, "axis {} lost in JSON", axis.as_str());
            // The applied setting must actually have landed somewhere.
            let base = scenario();
            assert!(
                sc.cluster != base.cluster
                    || sc.rate_rps != base.rate_rps
                    || sc.workload_seed != base.workload_seed,
                "axis {} was a no-op",
                axis.as_str()
            );
        }
    }

    #[test]
    fn backhaul_axis_overrides_a_per_pair_matrix() {
        let mut sc = scenario();
        let n = sc.cluster.cells.len();
        sc.cluster.backhaul_matrix = Some(vec![vec![2e-3; n]; n]);
        Axis::Backhaul.apply(&mut sc, &AxisValue::num(5e-4)).unwrap();
        assert_eq!(sc.cluster.backhaul_s_per_token, 5e-4);
        assert!(
            sc.cluster.backhaul_matrix.is_none(),
            "a stale matrix would shadow every swept scalar"
        );
    }

    #[test]
    fn apply_rejects_type_mismatch_and_bad_counts() {
        let mut sc = scenario();
        assert!(Axis::ArrivalRate.apply(&mut sc, &AxisValue::word("x")).is_err());
        assert!(Axis::ControlPlane.apply(&mut sc, &AxisValue::num(1.0)).is_err());
        assert!(Axis::ArrivalRate.apply(&mut sc, &AxisValue::num(0.0)).is_err());
        assert!(Axis::ArrivalRate.apply(&mut sc, &AxisValue::num(-2.0)).is_err());
        assert!(Axis::CacheCapacity.apply(&mut sc, &AxisValue::num(0.0)).is_err());
        assert!(Axis::CacheCapacity.apply(&mut sc, &AxisValue::num(1.5)).is_err());
        assert!(Axis::Cells.apply(&mut sc, &AxisValue::num(0.0)).is_err());
        assert!(Axis::Devices.apply(&mut sc, &AxisValue::num(99.0)).is_err());
        assert!(Axis::Seed.apply(&mut sc, &AxisValue::num(-1.0)).is_err());
    }

    #[test]
    fn coord_labels_match_legacy_row_format() {
        assert_eq!(
            Axis::ArrivalRate.coord_label(&AxisValue::num(0.5)),
            "rate=0.5"
        );
        assert_eq!(Axis::ArrivalRate.coord_label(&AxisValue::num(2.0)), "rate=2");
        assert_eq!(
            Axis::ControlPlane.coord_label(&AxisValue::word("adaptive")),
            "adaptive"
        );
        assert_eq!(
            Axis::QueueLimit.coord_label(&AxisValue::num(1.5)),
            "queue_limit=1.5"
        );
    }
}
