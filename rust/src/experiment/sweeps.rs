//! Legacy sweep entry points, reduced to thin wrappers over [`Grid`].
//!
//! `arrival_rate_sweep` is a one-axis grid; `control_plane_sweep` is a
//! two-axis grid (plane-major, rate fastest — the legacy row order).
//! Their CSV output is **byte-compatible** with the hand-rolled
//! pre-grid implementations: the row labels, the column subsets and
//! every value formula are projections of the unified
//! [`Record`](super::Record) schema (see `rust/tests/experiment.rs` for
//! the byte-level regression test). New experiments should build a
//! [`Grid`] directly and get every axis and metric; these wrappers
//! exist so `repro cluster` and the existing tests/benches keep their
//! exact shape.

use super::axis::{Axis, AxisValue};
use super::grid::{Grid, Scenario};
use super::record::records_table;
use crate::cluster::ClusterOutcome;
use crate::config::{ClusterConfig, ControlKind};
use crate::metrics::Table;
use crate::workload::Benchmark;

/// The legacy arrival-rate summary columns: the unified schema minus
/// `placement_updates` (the static-plane sweeps predate it).
const ARRIVAL_METRICS: [&str; 14] = [
    "throughput_rps",
    "goodput_tps",
    "drop_rate",
    "shed_tps",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "mean_ms",
    "util_mean",
    "util_max",
    "resolves",
    "churn",
    "handover_rate",
    "borrowed_tokens",
];

/// The legacy control-plane comparison columns: no utilization or mean
/// latency, but the placement-update counter.
const CONTROL_METRICS: [&str; 12] = [
    "throughput_rps",
    "goodput_tps",
    "drop_rate",
    "shed_tps",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "resolves",
    "placement_updates",
    "churn",
    "handover_rate",
    "borrowed_tokens",
];

/// One point of an arrival-rate sweep.
pub struct SweepPoint {
    pub rate_rps: f64,
    pub outcome: ClusterOutcome,
}

/// Sweep output: per-rate outcomes plus rendered tables (the `repro
/// cluster` CSVs).
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
    pub summary: Table,
    pub utilization: Table,
}

/// Sweep Poisson arrival rate and tabulate throughput, goodput, drop
/// rate, steady-state latency percentiles, control-plane activity and
/// per-device utilization — a one-axis [`Grid`].
///
/// Points run on the [`crate::exec`] worker pool (`threads` workers,
/// 0 = one per core, 1 = serial): each point is a pure function of
/// `(config, rate, derived seed)` and results are merged in rate order,
/// so the tables are byte-identical at any thread count.
pub fn arrival_rate_sweep(
    cfg: &ClusterConfig,
    rates_rps: &[f64],
    requests: usize,
    bench: Benchmark,
    seed: u64,
    threads: usize,
) -> anyhow::Result<SweepResult> {
    let base = Scenario::new(cfg.clone(), requests, bench).with_workload_seed(seed);
    let result = Grid::new(base)
        .axis(Axis::ArrivalRate, AxisValue::nums(rates_rps))
        .run(threads)?;

    let summary = records_table(
        &format!("Cluster arrival-rate sweep — {}", bench.name()),
        &result.axes,
        &ARRIVAL_METRICS,
        result.records(),
    )?;
    let dev_names: Vec<String> = cfg
        .cells
        .iter()
        .flat_map(|c| c.devices.iter().map(|d| d.name.clone()))
        .collect();
    let dev_cols: Vec<&str> = dev_names.iter().map(String::as_str).collect();
    let mut util_t = Table::new("Cluster per-device utilization", &dev_cols);
    util_t.precision = 3;
    for run in &result.runs {
        util_t.row(&run.record.label, run.outcome.flat_utilization());
    }
    let points = result
        .runs
        .into_iter()
        .map(|r| SweepPoint {
            rate_rps: r.rate_rps,
            outcome: r.outcome,
        })
        .collect();
    Ok(SweepResult {
        points,
        summary,
        utilization: util_t,
    })
}

/// Compare the three control planes on one workload in a single table —
/// a two-axis [`Grid`] (plane × rate, plane-major rows). The same
/// arrival streams are replayed for every plane, so rows differ only by
/// control behaviour.
///
/// `threads` as in [`arrival_rate_sweep`]: all plane × rate points run
/// concurrently; rows are emitted in the canonical plane-major order.
pub fn control_plane_sweep(
    cfg: &ClusterConfig,
    rates_rps: &[f64],
    requests: usize,
    bench: Benchmark,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Table> {
    let base = Scenario::new(cfg.clone(), requests, bench).with_workload_seed(seed);
    let planes: Vec<AxisValue> = ControlKind::all()
        .iter()
        .map(|k| AxisValue::word(k.as_str()))
        .collect();
    let result = Grid::new(base)
        .axis(Axis::ControlPlane, planes)
        .axis(Axis::ArrivalRate, AxisValue::nums(rates_rps))
        .run(threads)?;
    records_table(
        &format!("Cluster control-plane comparison — {}", bench.name()),
        &result.axes,
        &CONTROL_METRICS,
        result.records(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn small_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::single_cell();
        cfg.model.n_blocks = 8;
        cfg
    }

    #[test]
    fn sweep_emits_consistent_tables() {
        let cfg = small_cfg();
        let r = arrival_rate_sweep(&cfg, &[0.5, 2.0], 24, Benchmark::Piqa, 0, 1).unwrap();
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.summary.rows.len(), 2);
        assert_eq!(r.utilization.rows.len(), 2);
        assert_eq!(r.utilization.columns.len(), 8);
        for p in &r.points {
            assert_eq!(p.outcome.completed, 24);
        }
        for col in [
            "goodput_tps",
            "drop_rate",
            "shed_tps",
            "resolves",
            "churn",
            "handover_rate",
            "borrowed_tokens",
        ] {
            assert!(
                r.summary.columns.iter().any(|c| c == col),
                "missing column {col}"
            );
        }
    }

    #[test]
    fn parallel_sweep_is_byte_identical_to_serial() {
        let mut cfg = small_cfg();
        cfg.model.n_blocks = 4;
        let rates = [0.5, 2.0, 4.0];
        let serial = arrival_rate_sweep(&cfg, &rates, 16, Benchmark::Piqa, 0, 1).unwrap();
        let parallel = arrival_rate_sweep(&cfg, &rates, 16, Benchmark::Piqa, 0, 4).unwrap();
        assert_eq!(serial.summary.to_csv(), parallel.summary.to_csv());
        assert_eq!(serial.utilization.to_csv(), parallel.utilization.to_csv());
    }

    #[test]
    fn control_plane_sweep_rows_cover_all_kinds() {
        let mut cfg = small_cfg();
        cfg.model.n_blocks = 4;
        let t = control_plane_sweep(&cfg, &[1.0, 4.0], 16, Benchmark::Piqa, 0, 1).unwrap();
        assert_eq!(t.rows.len(), 3 * 2);
        for kind in ControlKind::all() {
            assert!(
                t.rows.iter().any(|(label, _)| label.starts_with(kind.as_str())),
                "missing rows for {}",
                kind.as_str()
            );
        }
    }

    #[test]
    fn sweep_headers_are_schema_projections() {
        let cfg = small_cfg();
        let r = arrival_rate_sweep(&cfg, &[1.0], 8, Benchmark::Piqa, 0, 1).unwrap();
        let expect: Vec<String> = std::iter::once("rate_rps".to_string())
            .chain(ARRIVAL_METRICS.iter().map(|s| s.to_string()))
            .collect();
        assert_eq!(r.summary.columns, expect);
        let t = control_plane_sweep(&cfg, &[1.0], 8, Benchmark::Piqa, 0, 1).unwrap();
        let expect: Vec<String> = std::iter::once("rate_rps".to_string())
            .chain(CONTROL_METRICS.iter().map(|s| s.to_string()))
            .collect();
        assert_eq!(t.columns, expect);
    }
}
