//! The unified sweep record: one metric schema, one writer.
//!
//! Every sweep row used to be a hand-rolled `Vec<f64>` pushed against a
//! per-function string header — adding a metric meant editing every
//! sweep in lockstep or silently drifting. A [`Record`] instead derives
//! *all* of [`METRIC_KEYS`] from a [`ClusterOutcome`] once (single
//! percentile sort, shared utilization fold), tags the row with its
//! grid coordinates, and serializes to CSV ([`records_table`]) and JSON
//! ([`Record::to_json`]) from this module only. Legacy sweep tables are
//! column *projections* of this schema, so their CSV bytes are
//! unchanged while new sweeps get every column for free.

use super::axis::{Axis, AxisValue};
use crate::cluster::ClusterOutcome;
use crate::metrics::Table;
use crate::util::Json;
use anyhow::Result;

/// The full metric schema, in canonical column order. Every sweep CSV's
/// metric columns are a subsequence of this list.
pub const METRIC_KEYS: [&str; 26] = [
    "throughput_rps",
    "goodput_tps",
    "drop_rate",
    "shed_tps",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "mean_ms",
    "util_mean",
    "util_max",
    "resolves",
    "placement_updates",
    "churn",
    "handover_rate",
    "borrowed_tokens",
    "solver_iters_mean",
    "solver_iters_max",
    "slo_miss_rate",
    "retries",
    "hedge_rate",
    "wasted_tokens",
    "availability",
    "joules_per_token",
    "energy_j",
    "fleet_lifetime_s",
    "depleted_devices",
];

/// One sweep row: grid coordinates plus the full metric vector.
#[derive(Debug, Clone)]
pub struct Record {
    /// Row label, coordinates joined with `@` (`adaptive@rate=2`).
    pub label: String,
    coords: Vec<(Axis, AxisValue)>,
    metrics: [f64; METRIC_KEYS.len()],
}

impl Record {
    /// Derive every metric from one outcome. The latency series is
    /// sorted once for all three percentiles, exactly as the legacy
    /// sweep rows computed them — projections stay bit-identical.
    pub fn new(label: String, coords: Vec<(Axis, AxisValue)>, out: &ClusterOutcome) -> Self {
        let s = out.steady_latency();
        let pct = s.percentiles(&[50.0, 95.0, 99.0]);
        let util = out.flat_utilization();
        let util_mean = util.iter().sum::<f64>() / util.len().max(1) as f64;
        let util_max = util.iter().cloned().fold(0.0f64, f64::max);
        let ctl = out.control_total();
        let metrics = [
            out.throughput_rps(),
            out.goodput_tps(),
            out.drop_rate(),
            out.shed_tps(),
            pct[0],
            pct[1],
            pct[2],
            s.mean(),
            util_mean,
            util_max,
            ctl.resolves as f64,
            ctl.placement_updates as f64,
            ctl.churn_frac,
            out.handover_rate(),
            out.borrowed_tokens,
            out.solver_iters_mean(),
            out.solver_iters_max(),
            out.slo_miss_rate(),
            out.retries as f64,
            out.hedge_rate(),
            out.wasted_tokens,
            out.availability(),
            out.joules_per_token(),
            out.energy_j,
            out.fleet_lifetime_s(),
            out.depleted_devices() as f64,
        ];
        Self {
            label,
            coords,
            metrics,
        }
    }

    pub fn coords(&self) -> &[(Axis, AxisValue)] {
        &self.coords
    }

    /// Numeric coordinate of `axis`, if this record has one.
    pub fn coord_num(&self, axis: Axis) -> Option<f64> {
        self.coords.iter().find(|(a, _)| *a == axis).and_then(|(_, v)| match v {
            AxisValue::Num(n) => Some(*n),
            AxisValue::Word(_) => None,
        })
    }

    /// Metric by schema key.
    pub fn metric(&self, key: &str) -> Result<f64> {
        let i = METRIC_KEYS
            .iter()
            .position(|k| *k == key)
            .ok_or_else(|| anyhow::anyhow!("unknown metric '{key}'"))?;
        Ok(self.metrics[i])
    }

    /// `{label, coords: {key: value}, metrics: {key: value}}`.
    pub fn to_json(&self) -> Json {
        let coords = Json::obj(
            self.coords
                .iter()
                .map(|(a, v)| {
                    let j = match v {
                        AxisValue::Num(n) => Json::Num(*n),
                        AxisValue::Word(w) => Json::str(w),
                    };
                    (a.key(), j)
                })
                .collect(),
        );
        let metrics = Json::obj(
            METRIC_KEYS
                .iter()
                .zip(&self.metrics)
                .map(|(k, v)| (*k, Json::Num(*v)))
                .collect(),
        );
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("coords", coords),
            ("metrics", metrics),
        ])
    }
}

/// The one CSV/table writer every sweep output goes through: one row per
/// record, labelled by its coordinates; columns are the numeric-axis
/// coordinates (in `axes` order) followed by `metric_keys` (a
/// subsequence of [`METRIC_KEYS`], or the whole schema).
pub fn records_table<'a, I>(
    title: &str,
    axes: &[Axis],
    metric_keys: &[&str],
    records: I,
) -> Result<Table>
where
    I: IntoIterator<Item = &'a Record>,
{
    let num_axes: Vec<Axis> = axes.iter().copied().filter(Axis::is_numeric).collect();
    let mut cols: Vec<&str> = num_axes.iter().map(Axis::key).collect();
    cols.extend_from_slice(metric_keys);
    let mut t = Table::new(title, &cols);
    t.precision = 3;
    for r in records {
        let mut vals = Vec::with_capacity(cols.len());
        for a in &num_axes {
            vals.push(r.coord_num(*a).ok_or_else(|| {
                anyhow::anyhow!(
                    "record '{}' has no numeric coordinate for {}",
                    r.label,
                    a.as_str()
                )
            })?);
        }
        for k in metric_keys {
            vals.push(r.metric(k)?);
        }
        t.row(&r.label, vals);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSim;
    use crate::config::ClusterConfig;
    use crate::workload::{ArrivalProcess, Benchmark};

    fn outcome() -> ClusterOutcome {
        let mut cfg = ClusterConfig::single_cell();
        cfg.model.n_blocks = 4;
        let mut sim = ClusterSim::new(&cfg).unwrap();
        let arrivals =
            ArrivalProcess::Poisson { rate_rps: 2.0 }.generate(16, Benchmark::Piqa, 0);
        sim.run(&arrivals)
    }

    #[test]
    fn record_metrics_match_outcome_accessors() {
        let out = outcome();
        let r = Record::new(
            "rate=2".into(),
            vec![(Axis::ArrivalRate, AxisValue::num(2.0))],
            &out,
        );
        assert_eq!(r.metric("throughput_rps").unwrap(), out.throughput_rps());
        assert_eq!(r.metric("goodput_tps").unwrap(), out.goodput_tps());
        assert_eq!(r.metric("p99_ms").unwrap(), out.p99_ms());
        assert_eq!(r.metric("borrowed_tokens").unwrap(), out.borrowed_tokens);
        assert_eq!(r.metric("solver_iters_mean").unwrap(), out.solver_iters_mean());
        assert_eq!(r.metric("solver_iters_max").unwrap(), out.solver_iters_max());
        assert_eq!(r.metric("slo_miss_rate").unwrap(), out.slo_miss_rate());
        assert_eq!(r.metric("retries").unwrap(), out.retries as f64);
        assert_eq!(r.metric("hedge_rate").unwrap(), out.hedge_rate());
        assert_eq!(r.metric("wasted_tokens").unwrap(), out.wasted_tokens);
        assert_eq!(r.metric("availability").unwrap(), out.availability());
        assert_eq!(r.metric("joules_per_token").unwrap(), out.joules_per_token());
        assert_eq!(r.metric("energy_j").unwrap(), out.energy_j);
        assert_eq!(r.metric("fleet_lifetime_s").unwrap(), out.fleet_lifetime_s());
        assert_eq!(
            r.metric("depleted_devices").unwrap(),
            out.depleted_devices() as f64
        );
        assert_eq!(r.coord_num(Axis::ArrivalRate), Some(2.0));
        assert_eq!(r.coord_num(Axis::QueueLimit), None);
        assert!(r.metric("bogus").is_err());
    }

    #[test]
    fn records_table_orders_coords_before_metrics() {
        let out = outcome();
        let r = Record::new(
            "adaptive@rate=2@queue_limit=0.5".into(),
            vec![
                (Axis::ControlPlane, AxisValue::word("adaptive")),
                (Axis::ArrivalRate, AxisValue::num(2.0)),
                (Axis::QueueLimit, AxisValue::num(0.5)),
            ],
            &out,
        );
        let t = records_table(
            "t",
            &[Axis::ControlPlane, Axis::ArrivalRate, Axis::QueueLimit],
            &METRIC_KEYS,
            [&r],
        )
        .unwrap();
        // Word axes contribute no column; numeric axes lead in order.
        assert_eq!(t.columns[0], "rate_rps");
        assert_eq!(t.columns[1], "queue_limit_s");
        assert_eq!(t.columns[2], "throughput_rps");
        assert_eq!(t.columns.len(), 2 + METRIC_KEYS.len());
        let (label, vals) = &t.rows[0];
        assert_eq!(label, "adaptive@rate=2@queue_limit=0.5");
        assert_eq!(vals[0], 2.0);
        assert_eq!(vals[1], 0.5);
        assert_eq!(vals[2], out.throughput_rps());
    }

    #[test]
    fn record_json_round_trips() {
        let out = outcome();
        let r = Record::new(
            "rate=2".into(),
            vec![
                (Axis::ArrivalRate, AxisValue::num(2.0)),
                (Axis::Handover, AxisValue::word("none")),
            ],
            &out,
        );
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "rate=2");
        let coords = j.get("coords").unwrap();
        assert_eq!(coords.get("rate_rps").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(coords.get("handover").unwrap().as_str().unwrap(), "none");
        let metrics = j.get("metrics").unwrap();
        for k in METRIC_KEYS {
            assert_eq!(
                metrics.get(k).unwrap().as_f64().unwrap(),
                r.metric(k).unwrap(),
                "metric {k}"
            );
        }
    }

    #[test]
    fn records_table_rejects_missing_coordinate() {
        let out = outcome();
        let r = Record::new("base".into(), vec![], &out);
        assert!(records_table("t", &[Axis::ArrivalRate], &["p50_ms"], [&r]).is_err());
    }
}
