//! # `experiment` — typed scenario/axis sweep grids
//!
//! The paper's evaluation is a grid — arrival rates × bandwidth ×
//! placement × availability — but until this module every sweep was a
//! hand-rolled function with its own loop and its own CSV header:
//! two existed (`arrival_rate_sweep`, `control_plane_sweep`) and every
//! new knob (handover, backhaul, queue limits, replication, …) would
//! have demanded a third ~100-line copy. This module makes the grid a
//! first-class value instead:
//!
//! * [`Axis`] — every sweepable knob as one enum variant; a setting is
//!   applied through the single [`Axis::apply`] dispatch onto a
//!   [`Scenario`] (cluster config + workload). Adding a knob is one
//!   variant + one match arm, and it is immediately sweepable from the
//!   CLI, the JSON output and every test.
//! * [`Grid`] — a base scenario plus N axes, expanded in declaration
//!   order (exactly the rows hand-nested `for` loops would emit) and
//!   run through [`crate::exec::try_map_indexed`]: any grid is parallel
//!   and byte-identical to serial. Config axes are pre-applied once per
//!   distinct config combination — never once per point.
//! * [`Record`] — one metric schema ([`METRIC_KEYS`]) derived from a
//!   [`crate::cluster::ClusterOutcome`] in one place, serialized to CSV
//!   tables ([`records_table`]) and JSON from this module only. The
//!   legacy sweeps are column projections of it, byte-for-byte.
//! * [`arrival_rate_sweep`] / [`control_plane_sweep`] — the legacy
//!   entry points, now thin wrappers over a one- and two-axis grid
//!   (still re-exported from [`crate::cluster`]).
//!
//! CLI: `repro sweep --axis rate=0.5:0.5:4 --axis handover=none,borrow
//! --axis queue_limit=0.5,1` runs a three-axis grid; `repro cluster`
//! keeps its historical shape on top of the same machinery.

pub mod axis;
pub mod grid;
pub mod record;
pub mod sweeps;

pub use axis::{Axis, AxisSpec, AxisValue};
pub use grid::{Grid, GridPoint, GridResult, GridRun, Scenario};
pub use record::{records_table, Record, METRIC_KEYS};
pub use sweeps::{arrival_rate_sweep, control_plane_sweep, SweepPoint, SweepResult};
