//! Typed scenario grids: declare axes, get the cross-product, run it on
//! the deterministic parallel engine.
//!
//! A [`Scenario`] is one runnable configuration (cluster config +
//! workload); a [`Grid`] is a base scenario plus N axes, expanded in
//! declaration order (first axis outermost, last fastest — exactly the
//! rows N nested `for` loops would emit). [`Grid::run`] evaluates every
//! point through [`crate::exec::try_map_indexed`], so any grid is
//! parallel and byte-identical to serial, and pre-applies config axes
//! once per distinct config combination — a pure arrival-rate sweep
//! never clones the config per point, a 3-plane comparison clones it
//! three times, whatever the axes demand.
//!
//! Determinism contract: a point is a pure function of `(base scenario,
//! coordinates)`. The arrival stream's seed is derived from the
//! scenario's workload seed plus the point's *arrival-rate index only*,
//! so points that differ in policy axes replay identical traffic — the
//! property the legacy control-plane comparison relied on, now true of
//! every grid.

use super::axis::{Axis, AxisSpec, AxisValue};
use super::record::{records_table, Record, METRIC_KEYS};
use crate::cluster::{ClusterOutcome, ClusterSim};
use crate::config::ClusterConfig;
use crate::metrics::Table;
use crate::util::Json;
use crate::workload::{ArrivalProcess, Benchmark};
use anyhow::Result;

/// One runnable experiment point: the cluster configuration plus the
/// open-loop workload driving it.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub cluster: ClusterConfig,
    /// Poisson arrival rate (requests/s) when no
    /// [`Axis::ArrivalRate`] overrides it.
    pub rate_rps: f64,
    /// Requests per run.
    pub requests: usize,
    /// Token-length distribution of the requests.
    pub bench: Benchmark,
    /// Base seed of the arrival stream. Defaults to the cluster seed;
    /// the legacy sweep signatures allow them to differ.
    pub workload_seed: u64,
}

impl Scenario {
    pub fn new(cluster: ClusterConfig, requests: usize, bench: Benchmark) -> Self {
        let workload_seed = cluster.seed;
        Self {
            cluster,
            rate_rps: 2.0,
            requests,
            bench,
            workload_seed,
        }
    }

    pub fn with_workload_seed(mut self, seed: u64) -> Self {
        self.workload_seed = seed;
        self
    }
}

/// A base scenario plus N typed axes — the experiment cross-product.
#[derive(Debug, Clone)]
pub struct Grid {
    base: Scenario,
    axes: Vec<(Axis, Vec<AxisValue>)>,
}

/// One expanded (not yet run) grid point. `scenario` is fully
/// self-contained: its `workload_seed` already includes the point's
/// arrival-stream offset, so simulating it directly reproduces the
/// corresponding [`Grid::run`] row exactly.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub index: usize,
    pub coords: Vec<(Axis, AxisValue)>,
    pub scenario: Scenario,
}

/// One completed grid point.
#[derive(Debug)]
pub struct GridRun {
    /// The arrival rate this point actually ran at.
    pub rate_rps: f64,
    pub outcome: ClusterOutcome,
    pub record: Record,
}

/// All completed points of one grid, in canonical expansion order.
#[derive(Debug)]
pub struct GridResult {
    pub axes: Vec<Axis>,
    pub runs: Vec<GridRun>,
}

/// Decompose `i` into per-axis value indices (last axis fastest).
fn value_indices(mut i: usize, dims: &[usize], out: &mut [usize]) {
    for k in (0..dims.len()).rev() {
        out[k] = i % dims[k];
        i /= dims[k];
    }
}

impl Grid {
    pub fn new(base: Scenario) -> Self {
        Self {
            base,
            axes: Vec::new(),
        }
    }

    /// Add an axis (builder style). Declaration order is expansion
    /// order: the first axis varies slowest. Duplicates and empty value
    /// lists are rejected when the grid expands or runs.
    pub fn axis(mut self, axis: Axis, values: Vec<AxisValue>) -> Self {
        self.axes.push((axis, values));
        self
    }

    /// Add a parsed `--axis name=spec` argument.
    pub fn axis_spec(self, spec: AxisSpec) -> Self {
        self.axis(spec.axis, spec.values)
    }

    pub fn axes(&self) -> &[(Axis, Vec<AxisValue>)] {
        &self.axes
    }

    /// Number of points the grid expands to (1 for an axis-free grid).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|(_, vs)| vs.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn check(&self) -> Result<Vec<usize>> {
        anyhow::ensure!(self.base.requests > 0, "need at least one request");
        for (i, (a, vs)) in self.axes.iter().enumerate() {
            anyhow::ensure!(!vs.is_empty(), "axis {} has no values", a.as_str());
            anyhow::ensure!(
                !self.axes[..i].iter().any(|(b, _)| b == a),
                "duplicate axis {}",
                a.as_str()
            );
        }
        let mut n = 1usize;
        for (a, vs) in &self.axes {
            n = n
                .checked_mul(vs.len())
                .ok_or_else(|| anyhow::anyhow!("grid size overflows"))?;
            anyhow::ensure!(
                n <= 1_000_000,
                "grid expands past 1e6 points at axis {}",
                a.as_str()
            );
        }
        Ok(self.axes.iter().map(|(_, vs)| vs.len()).collect())
    }

    /// Expand the full cross-product: every point's coordinates and
    /// fully-applied scenario, in canonical order. [`Grid::run`] derives
    /// the same scenarios without cloning one per point; this
    /// materialized form serves tests and tooling.
    pub fn points(&self) -> Result<Vec<GridPoint>> {
        let dims = self.check()?;
        let n: usize = dims.iter().product();
        let rate_axis = self.axes.iter().position(|(a, _)| *a == Axis::ArrivalRate);
        let mut idx = vec![0usize; dims.len()];
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            value_indices(i, &dims, &mut idx);
            let mut scenario = self.base.clone();
            let mut coords = Vec::with_capacity(self.axes.len());
            for (k, (a, vs)) in self.axes.iter().enumerate() {
                a.apply(&mut scenario, &vs[idx[k]])?;
                coords.push((*a, vs[idx[k]].clone()));
            }
            // The same arrival-seed derivation `run()` uses, folded in
            // so the materialized scenario reproduces the run row.
            if let Some(ai) = rate_axis {
                scenario.workload_seed =
                    scenario.workload_seed.wrapping_add(idx[ai] as u64 * 7919);
            }
            // The same validation story as `run()`: an out-of-range
            // axis value is an error on every expansion path.
            scenario.cluster.validate()?;
            out.push(GridPoint {
                index: i,
                coords,
                scenario,
            });
        }
        Ok(out)
    }

    /// Run every point on the [`crate::exec`] pool (`threads` workers,
    /// 0 = one per core, 1 = serial) and return outcomes in canonical
    /// order — byte-identical tables at any thread count.
    pub fn run(&self, threads: usize) -> Result<GridResult> {
        self.base.cluster.validate()?;
        let dims = self.check()?;
        let n: usize = dims.iter().product();

        // Pre-apply config axes once per distinct config combination.
        let cfg_axes: Vec<usize> = (0..self.axes.len())
            .filter(|&k| self.axes[k].0.touches_config())
            .collect();
        let cfg_dims: Vec<usize> = cfg_axes.iter().map(|&k| dims[k]).collect();
        let n_variants: usize = cfg_dims.iter().product();
        let mut variants = Vec::with_capacity(n_variants);
        let mut vis = vec![0usize; cfg_axes.len()];
        for combo in 0..n_variants {
            // Decompose fully first, then apply in *declaration* order —
            // order-sensitive axis pairs (e.g. cells before devices)
            // must behave exactly as `points()` and the docs promise.
            value_indices(combo, &cfg_dims, &mut vis);
            let mut sc = self.base.clone();
            for (pos, &ai) in cfg_axes.iter().enumerate() {
                let (axis, values) = &self.axes[ai];
                axis.apply(&mut sc, &values[vis[pos]])?;
            }
            sc.cluster.validate()?;
            variants.push(sc);
        }

        let rate_axis = self.axes.iter().position(|(a, _)| *a == Axis::ArrivalRate);
        // Every rate a point can run at is validated up front — axis
        // values and the base scenario's fallback alike — so a bad rate
        // is an error here, never a panic inside a worker.
        match rate_axis {
            Some(ai) => {
                for v in &self.axes[ai].1 {
                    let r = v.as_num()?;
                    anyhow::ensure!(
                        r.is_finite() && r > 0.0,
                        "arrival rate must be finite and positive, got {r}"
                    );
                }
            }
            None => {
                anyhow::ensure!(
                    self.base.rate_rps.is_finite() && self.base.rate_rps > 0.0,
                    "scenario arrival rate must be finite and positive, got {}",
                    self.base.rate_rps
                );
            }
        }

        let runs = crate::exec::try_map_indexed(n, threads, |i| -> Result<GridRun> {
            let mut idx = vec![0usize; dims.len()];
            value_indices(i, &dims, &mut idx);
            let mut combo = 0usize;
            for (pos, &ai) in cfg_axes.iter().enumerate() {
                combo = combo * cfg_dims[pos] + idx[ai];
            }
            let sc = &variants[combo];
            let (rate, rate_idx) = match rate_axis {
                Some(ai) => (self.axes[ai].1[idx[ai]].as_num()?, idx[ai]),
                None => (sc.rate_rps, 0),
            };
            let mut sim = ClusterSim::new(&sc.cluster)?;
            let arrivals = ArrivalProcess::Poisson { rate_rps: rate }.generate(
                sc.requests,
                sc.bench,
                sc.workload_seed.wrapping_add(rate_idx as u64 * 7919),
            );
            let outcome = sim.run(&arrivals);
            let coords: Vec<(Axis, AxisValue)> = self
                .axes
                .iter()
                .enumerate()
                .map(|(k, (a, vs))| (*a, vs[idx[k]].clone()))
                .collect();
            let label = if coords.is_empty() {
                "base".to_string()
            } else {
                coords
                    .iter()
                    .map(|(a, v)| a.coord_label(v))
                    .collect::<Vec<_>>()
                    .join("@")
            };
            let record = Record::new(label, coords, &outcome);
            Ok(GridRun {
                rate_rps: rate,
                outcome,
                record,
            })
        })?;
        Ok(GridResult {
            axes: self.axes.iter().map(|(a, _)| *a).collect(),
            runs,
        })
    }
}

impl GridResult {
    /// Iterate the unified records in canonical order.
    pub fn records(&self) -> impl Iterator<Item = &Record> {
        self.runs.iter().map(|r| &r.record)
    }

    /// The full-schema table: numeric-axis coordinate columns followed
    /// by every metric in [`METRIC_KEYS`].
    pub fn table(&self, title: &str) -> Result<Table> {
        records_table(title, &self.axes, &METRIC_KEYS, self.records())
    }

    /// The full grid as one JSON document (the CSV's machine-readable
    /// twin; word-axis coordinates survive here).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("wdmoe-grid-v1")),
            (
                "axes",
                Json::Arr(self.axes.iter().map(|a| Json::str(a.key())).collect()),
            ),
            (
                "points",
                Json::Arr(self.runs.iter().map(|r| r.record.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ControlKind, HandoverPolicy};

    fn base() -> Scenario {
        let mut cfg = ClusterConfig::single_cell();
        cfg.model.n_blocks = 4;
        Scenario::new(cfg, 12, Benchmark::Piqa)
    }

    #[test]
    fn expansion_matches_hand_nested_loops() {
        let grid = Grid::new(base())
            .axis(Axis::ArrivalRate, AxisValue::nums(&[1.0, 2.0]))
            .axis(Axis::Handover, AxisValue::words(&["none", "rehome_on_arrival"]))
            .axis(Axis::QueueLimit, AxisValue::nums(&[0.0, 0.5, 1.0]));
        assert_eq!(grid.len(), 12);
        let points = grid.points().unwrap();
        assert_eq!(points.len(), 12);
        // The exact rows three nested for loops would produce, in order.
        let mut expect = Vec::new();
        for &rate in &[1.0, 2.0] {
            for h in ["none", "rehome_on_arrival"] {
                for &q in &[0.0, 0.5, 1.0] {
                    expect.push(vec![
                        (Axis::ArrivalRate, AxisValue::num(rate)),
                        (Axis::Handover, AxisValue::word(h)),
                        (Axis::QueueLimit, AxisValue::num(q)),
                    ]);
                }
            }
        }
        for (p, e) in points.iter().zip(&expect) {
            assert_eq!(&p.coords, e, "point {}", p.index);
        }
        // And the scenarios carry the applied coordinates.
        assert_eq!(points[0].scenario.rate_rps, 1.0);
        assert_eq!(points[11].scenario.rate_rps, 2.0);
        assert_eq!(points[11].scenario.cluster.queue_limit_s, 1.0);
        assert_eq!(
            points[11].scenario.cluster.handover,
            HandoverPolicy::RehomeOnArrival
        );
    }

    #[test]
    fn rejects_duplicate_and_empty_axes() {
        let g = Grid::new(base())
            .axis(Axis::ArrivalRate, AxisValue::nums(&[1.0]))
            .axis(Axis::ArrivalRate, AxisValue::nums(&[2.0]));
        assert!(g.run(1).is_err());
        let g = Grid::new(base()).axis(Axis::QueueLimit, vec![]);
        assert!(g.points().is_err());
    }

    #[test]
    fn axis_free_grid_runs_one_base_point() {
        let result = Grid::new(base()).run(1).unwrap();
        assert_eq!(result.runs.len(), 1);
        assert_eq!(result.runs[0].record.label, "base");
        assert_eq!(result.runs[0].outcome.completed, 12);
        assert_eq!(result.runs[0].rate_rps, base().rate_rps);
    }

    #[test]
    fn policy_axes_replay_identical_arrival_streams() {
        // Points that differ only in a config axis must see the same
        // traffic: same arrivals, same token volume.
        let result = Grid::new(base())
            .axis(
                Axis::ControlPlane,
                AxisValue::words(&["static_uniform", "adaptive"]),
            )
            .axis(Axis::ArrivalRate, AxisValue::nums(&[1.0, 4.0]))
            .run(1)
            .unwrap();
        assert_eq!(result.runs.len(), 4);
        for ri in 0..2 {
            let a = &result.runs[ri].outcome; // static_uniform @ rate ri
            let b = &result.runs[2 + ri].outcome; // adaptive @ rate ri
            assert_eq!(a.arrived, b.arrived);
            assert_eq!(a.arrived_tokens, b.arrived_tokens);
        }
    }

    #[test]
    fn run_applies_config_axes_per_variant() {
        let result = Grid::new(base())
            .axis(Axis::ControlPlane, AxisValue::words(&["adaptive"]))
            .axis(Axis::ArrivalRate, AxisValue::nums(&[2.0]))
            .run(1)
            .unwrap();
        // The adaptive plane actually ran: control ticks happened.
        assert_eq!(result.runs.len(), 1);
        assert!(result.runs[0].outcome.control_total().resolves >= 1);
    }

    #[test]
    fn invalid_base_rate_errors_instead_of_panicking() {
        // No ArrivalRate axis: the base scenario's rate is the fallback
        // and must be validated up front, not panic in a worker.
        let mut sc = base();
        sc.rate_rps = 0.0;
        let err = Grid::new(sc)
            .axis(Axis::QueueLimit, AxisValue::nums(&[0.0, 0.5]))
            .run(1)
            .unwrap_err();
        assert!(err.to_string().contains("arrival rate"), "{err}");
    }

    #[test]
    fn invalid_axis_value_surfaces_config_validation_error() {
        // Negative backhaul passes apply (range left to validate) and
        // must be rejected on every expansion path before anything runs.
        let g = Grid::new(base()).axis(Axis::Backhaul, AxisValue::nums(&[-1.0]));
        assert!(g.run(1).is_err());
        assert!(g.points().is_err());
    }

    #[test]
    fn materialized_points_reproduce_run_rows() {
        // A GridPoint's scenario is self-contained: simulating it
        // directly (config + workload fields, arrival seed as stored)
        // must give exactly the outcome `run()` reported for that row —
        // including rate indices > 0, whose arrival-seed offset is
        // folded into the scenario.
        let grid = Grid::new(base())
            .axis(Axis::ArrivalRate, AxisValue::nums(&[1.0, 4.0]))
            .axis(Axis::CacheCapacity, AxisValue::nums(&[1.0, 2.0]));
        let result = grid.run(1).unwrap();
        let points = grid.points().unwrap();
        assert_eq!(points.len(), result.runs.len());
        for (p, run) in points.iter().zip(&result.runs) {
            let sc = &p.scenario;
            let mut sim = ClusterSim::new(&sc.cluster).unwrap();
            let arrivals = ArrivalProcess::Poisson {
                rate_rps: sc.rate_rps,
            }
            .generate(sc.requests, sc.bench, sc.workload_seed);
            let out = sim.run(&arrivals);
            assert_eq!(out.makespan_s, run.outcome.makespan_s, "point {}", p.index);
            assert_eq!(out.utilization, run.outcome.utilization, "point {}", p.index);
        }
    }

    #[test]
    fn grid_table_and_json_share_the_run_order() {
        let result = Grid::new(base())
            .axis(Axis::ArrivalRate, AxisValue::nums(&[1.0, 2.0]))
            .axis(Axis::QueueLimit, AxisValue::nums(&[0.0, 0.5]))
            .run(1)
            .unwrap();
        let t = result.table("grid").unwrap();
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.columns[0], "rate_rps");
        assert_eq!(t.columns[1], "queue_limit_s");
        assert_eq!(t.rows[0].0, "rate=1@queue_limit=0");
        assert_eq!(t.rows[3].0, "rate=2@queue_limit=0.5");
        let j = Json::parse(&result.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "wdmoe-grid-v1");
        let pts = j.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 4);
        assert_eq!(
            pts[3].get("label").unwrap().as_str().unwrap(),
            "rate=2@queue_limit=0.5"
        );
    }

    #[test]
    fn seed_axis_changes_traffic_and_gates() {
        let result = Grid::new(base())
            .axis(Axis::Seed, AxisValue::nums(&[0.0, 1.0]))
            .run(1)
            .unwrap();
        let (a, b) = (&result.runs[0].outcome, &result.runs[1].outcome);
        assert_eq!(a.completed, 12);
        assert_eq!(b.completed, 12);
        assert!(
            a.arrived_tokens != b.arrived_tokens || a.makespan_s != b.makespan_s,
            "different seeds should draw different workloads"
        );
    }

    #[test]
    fn run_applies_order_sensitive_config_axes_in_declaration_order() {
        // Cell 1 has only 4 devices, so `devices=6` is only feasible
        // *after* `cells=1` drops it: if run() applied config axes in
        // any order other than declaration order (as points() does),
        // this grid would error instead of running.
        let mut cfg = ClusterConfig::edge_default();
        cfg.model.n_blocks = 4;
        cfg.cells[1].devices.truncate(4);
        let grid = Grid::new(Scenario::new(cfg, 8, Benchmark::Piqa))
            .axis(Axis::Cells, AxisValue::nums(&[1.0]))
            .axis(Axis::Devices, AxisValue::nums(&[6.0]));
        let points = grid.points().unwrap();
        assert_eq!(points[0].scenario.cluster.n_cells(), 1);
        assert_eq!(points[0].scenario.cluster.cells[0].devices.len(), 6);
        let result = grid.run(1).unwrap();
        assert_eq!(result.runs.len(), 1);
        assert_eq!(result.runs[0].outcome.utilization.len(), 1);
        assert_eq!(result.runs[0].outcome.utilization[0].len(), 6);
    }

    #[test]
    fn control_kind_words_cover_all_kinds() {
        // Guard: the wrapper sweeps build their plane axis from
        // ControlKind::all(); the words must stay parseable.
        for k in ControlKind::all() {
            Axis::ControlPlane.parse_value(k.as_str()).unwrap();
        }
    }
}
