//! Artifact manifest + weight-blob loading.
//!
//! `python/compile/aot.py` writes `manifest.json` (model config, artifact
//! arg signatures, weight table) and `weights.bin` (all weights, f32
//! little-endian, concatenated in manifest order). This module is the
//! rust-side reader; shapes here are the single source of truth for the
//! execute-path literals.

use crate::util::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Model config block of the manifest (mirrors python ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub d_hidden: usize,
    pub n_experts: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub seq_len: usize,
    pub top_k: usize,
    pub seed: u64,
    pub total_params: u64,
}

/// One artifact's argument signature.
#[derive(Debug, Clone)]
pub struct ArtifactArg {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub args: Vec<ArtifactArg>,
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct WeightsBlock {
    pub file: String,
    pub dtype: String,
    pub tensors: Vec<WeightEntry>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub weights: WeightsBlock,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} — run `make artifacts` first: {e}",
                path.display()
            )
        })?;
        let j = Json::parse(&text)?;
        let c = j.get("config")?;
        let config = ManifestConfig {
            vocab: c.get("vocab")?.as_usize()?,
            d_model: c.get("d_model")?.as_usize()?,
            d_hidden: c.get("d_hidden")?.as_usize()?,
            n_experts: c.get("n_experts")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            n_blocks: c.get("n_blocks")?.as_usize()?,
            seq_len: c.get("seq_len")?.as_usize()?,
            top_k: c.get("top_k")?.as_usize()?,
            seed: c.get("seed")?.as_u64()?,
            total_params: c.get("total_params")?.as_u64()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, entry) in j.get("artifacts")?.as_obj()? {
            let args = entry
                .get("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArtifactArg {
                        shape: a.get("shape")?.as_usize_vec()?,
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    file: entry.get("file")?.as_str()?.to_string(),
                    args,
                },
            );
        }
        let w = j.get("weights")?;
        let tensors = w
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(WeightEntry {
                    name: t.get("name")?.as_str()?.to_string(),
                    shape: t.get("shape")?.as_usize_vec()?,
                    offset: t.get("offset")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let m = Manifest {
            config,
            artifacts,
            weights: WeightsBlock {
                file: w.get("file")?.as_str()?.to_string(),
                dtype: w.get("dtype")?.as_str()?.to_string(),
                tensors,
            },
        };
        anyhow::ensure!(m.weights.dtype == "f32", "unsupported weight dtype");
        Ok(m)
    }
}

/// All model weights, loaded from `weights.bin` and indexed by name.
pub struct WeightStore {
    tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl WeightStore {
    pub fn load(dir: &Path, manifest: &Manifest) -> anyhow::Result<Self> {
        let path = dir.join(&manifest.weights.file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let blob: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for t in &manifest.weights.tensors {
            let size: usize = t.shape.iter().product();
            anyhow::ensure!(
                t.offset + size <= blob.len(),
                "{}: offset {} + size {} exceeds blob {}",
                t.name,
                t.offset,
                size,
                blob.len()
            );
            tensors.insert(
                t.name.clone(),
                (t.shape.clone(), blob[t.offset..t.offset + size].to_vec()),
            );
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<(&[usize], &[f32])> {
        self.tensors
            .get(name)
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .ok_or_else(|| anyhow::anyhow!("weight {name} not in manifest"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Repo-level artifacts (built by `make artifacts`); tests that need
    /// them are skipped gracefully when absent.
    pub fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.d_model, 256);
        assert!(m.artifacts.contains_key("expert"));
        assert!(m.artifacts.contains_key("gate"));
        // expert args: x, w1, w3, w2
        let e = &m.artifacts["expert"];
        assert_eq!(e.args.len(), 4);
        assert_eq!(e.args[0].shape, vec![m.config.seq_len, m.config.d_model]);
    }

    #[test]
    fn weights_load_and_param_count_matches() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let w = WeightStore::load(&dir, &m).unwrap();
        assert_eq!(w.len(), m.weights.tensors.len());
        let total: usize = m
            .weights
            .tensors
            .iter()
            .map(|t| t.shape.iter().product::<usize>())
            .sum();
        assert_eq!(total as u64, m.config.total_params);
        let (shape, data) = w.get("emb").unwrap();
        assert_eq!(shape, &[m.config.vocab, m.config.d_model]);
        assert_eq!(data.len(), m.config.vocab * m.config.d_model);
        assert!(w.get("nonexistent").is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
