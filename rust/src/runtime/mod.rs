//! PJRT runtime: load AOT artifacts, compile once, execute on the request
//! path. Python is never invoked here — the HLO text produced by
//! `python/compile/aot.py` is the only interface between the layers.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** (not serialized
//! proto — xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids),
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`, unwrap the 1-tuple root.

pub mod manifest;

pub use manifest::{Manifest, WeightStore};

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// A compiled-artifact registry bound to one PJRT client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub weights: WeightStore,
    dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load the manifest and weights and compile every artifact on the
    /// CPU PJRT client. Compilation happens once, here; the request path
    /// only executes.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = WeightStore::load(artifacts_dir, &manifest)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let path = artifacts_dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Self {
            client,
            executables,
            manifest,
            weights,
            dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Execute an artifact with the given literals; returns the unwrapped
    /// single output (all entry points lower with `return_tuple=True`).
    /// Accepts owned or borrowed literals (`&[Literal]` / `&[&Literal]`)
    /// so cached weight literals can be reused without copying.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> anyhow::Result<xla::Literal> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?;
        let expected = &self.manifest.artifacts[name].args;
        anyhow::ensure!(
            args.len() == expected.len(),
            "{name}: got {} args, artifact takes {}",
            args.len(),
            expected.len()
        );
        let out = exe
            .execute(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e:?}"))?;
        out.to_tuple1()
            .map_err(|e| anyhow::anyhow!("{name}: unwrapping tuple: {e:?}"))
    }

    /// Build an f32 literal of the given shape from a flat slice.
    pub fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "shape {shape:?} != data len {}", data.len());
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
    }

    /// Build an i32 literal of the given shape.
    pub fn literal_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "shape {shape:?} != data len {}", data.len());
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
    }

    /// Literal for a named weight tensor.
    pub fn weight_literal(&self, name: &str) -> anyhow::Result<xla::Literal> {
        let (shape, data) = self.weights.get(name)?;
        Self::literal_f32(data, shape)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn literal_builders_validate_shape() {
        assert!(Runtime::literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = Runtime::literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let i = Runtime::literal_i32(&[1, 2], &[2]).unwrap();
        assert_eq!(i.element_count(), 2);
    }

    #[test]
    fn runtime_loads_and_executes_expert() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert!(!rt.platform().is_empty());
        let c = &rt.manifest.config;
        let x = vec![0.1f32; c.seq_len * c.d_model];
        let xl = Runtime::literal_f32(&x, &[c.seq_len, c.d_model]).unwrap();
        let w1 = rt.weight_literal("blk0.expert0.w1").unwrap();
        let w3 = rt.weight_literal("blk0.expert0.w3").unwrap();
        let w2 = rt.weight_literal("blk0.expert0.w2").unwrap();
        let out = rt.execute("expert", &[xl, w1, w3, w2]).unwrap();
        let v = out.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), c.seq_len * c.d_model);
        assert!(v.iter().all(|f| f.is_finite()));
        // non-degenerate output
        assert!(v.iter().any(|&f| f.abs() > 1e-8));
    }

    #[test]
    fn execute_rejects_wrong_arity() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let Err(err) = rt.execute::<xla::Literal>("expert", &[]) else {
            panic!("arity mismatch must fail");
        };
        assert!(err.to_string().contains("args"));
        assert!(rt.execute::<xla::Literal>("nope", &[]).is_err());
    }

    #[test]
    fn gate_rows_sum_to_one() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        let c = &rt.manifest.config;
        let x = vec![0.05f32; c.seq_len * c.d_model];
        let xl = Runtime::literal_f32(&x, &[c.seq_len, c.d_model]).unwrap();
        let gamma = rt.weight_literal("blk0.moe.gamma").unwrap();
        let wg = rt.weight_literal("blk0.moe.wg").unwrap();
        let out = rt.execute("gate", &[xl, gamma, wg]).unwrap();
        let v = out.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), c.seq_len * c.n_experts);
        for j in 0..c.seq_len {
            let s: f32 = v[j * c.n_experts..(j + 1) * c.n_experts].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {j} sums to {s}");
        }
    }
}
