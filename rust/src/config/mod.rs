//! Configuration system: every knob of the WDMoE stack, with JSON
//! persistence (hand-rolled via [`crate::util::Json`]; the offline build
//! environment has no serde/toml).
//!
//! A [`SystemConfig`] fully determines a run: the model dimensions (which
//! set the paper's `m`, `m_h`, `n`, `I`), the wireless scenario (bandwidth,
//! powers, carrier, noise, device distances), the device fleet (compute
//! capacities `C_k`), the routing policy, and the workload. Presets match
//! the paper's two experimental setups: [`SystemConfig::paper_simulation`]
//! (Section V — 8 devices, Mixtral-scale model, 100 MHz) and
//! [`SystemConfig::paper_testbed`] (Section VI — 4 Jetson-class devices
//! over WiFi).

pub mod cluster;
pub mod energy;
pub mod faults;
mod presets; // preset constructors are inherent impls on SystemConfig

pub use cluster::{
    CellConfig, ClusterConfig, ControlKind, DispatchKind, DropPolicy, HandoverPolicy,
};
pub use energy::{EnergyClass, EnergyConfig};
pub use faults::{FaultConfig, FaultKind, ScheduledFault};

use crate::util::Json;
use anyhow::Result;
use std::path::Path;

/// Model dimensions — mirrors `python/compile/model.py::ModelConfig`.
///
/// For *execution* (PJRT) these must match `artifacts/manifest.json`; for
/// the *analytic* latency simulation they may instead be set to the
/// paper's Mixtral-8x7B scale (see [`ModelDims::mixtral_8x7b`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    /// Vocabulary size (execution only).
    pub vocab: usize,
    /// Token embedding dimension — the paper's `m` (Eq. (4)).
    pub d_model: usize,
    /// Expert FFN hidden dimension — the paper's `m_h` (Eq. (5)).
    pub d_hidden: usize,
    /// Experts per MoE layer — the paper's `n`.
    pub n_experts: usize,
    /// Attention heads (execution only).
    pub n_heads: usize,
    /// Number of MoE blocks — the paper's `I`.
    pub n_blocks: usize,
    /// AOT-compiled token batch shape `J` (execution pads to this).
    pub seq_len: usize,
    /// Default routing fan-out (Mixtral uses top-2).
    pub top_k: usize,
}

impl ModelDims {
    /// The shipped AOT artifact configuration (~27.8M params).
    pub fn artifact_default() -> Self {
        Self {
            vocab: 2048,
            d_model: 256,
            d_hidden: 512,
            n_experts: 8,
            n_heads: 8,
            n_blocks: 8,
            seq_len: 256,
            top_k: 2,
        }
    }

    /// Mixtral-8x7B dimensions — what the paper's latency model plugs into
    /// Eqs. (4)–(5). Used by the analytic simulation behind every paper
    /// table/figure; never executed on CPU.
    pub fn mixtral_8x7b() -> Self {
        Self {
            vocab: 32000,
            d_model: 4096,
            d_hidden: 14336,
            n_experts: 8,
            n_heads: 32,
            n_blocks: 32,
            seq_len: 4096,
            top_k: 2,
        }
    }

    /// Communication payload per token in bits — paper Eq. (4):
    /// `L_comm = eps * m` with `eps` the quantisation precision in bits.
    pub fn l_comm_bits(&self, quant_bits: u32) -> f64 {
        (quant_bits as f64) * (self.d_model as f64)
    }

    /// Expert FLOPs per token — paper Eq. (5):
    /// `L_comp = 4 m m_h + 2 m_h m + eta m_h + m_h`.
    pub fn l_comp_flops(&self, eta: f64) -> f64 {
        let m = self.d_model as f64;
        let mh = self.d_hidden as f64;
        4.0 * m * mh + 2.0 * mh * m + eta * mh + mh
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::Num(self.vocab as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("d_hidden", Json::Num(self.d_hidden as f64)),
            ("n_experts", Json::Num(self.n_experts as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("n_blocks", Json::Num(self.n_blocks as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("top_k", Json::Num(self.top_k as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            d_hidden: j.get("d_hidden")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_blocks: j.get("n_blocks")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
        })
    }
}

/// Wireless scenario parameters (paper §V-A).
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelConfig {
    /// Total bandwidth `B` in Hz (paper: 100 MHz).
    pub total_bandwidth_hz: f64,
    /// Carrier frequency in GHz (paper: 3.5 GHz).
    pub carrier_ghz: f64,
    /// BS transmit power in W (paper: 10 W).
    pub bs_power_w: f64,
    /// Device transmit power in W (paper: 0.2 W).
    pub device_power_w: f64,
    /// Noise power spectral density in dBm/Hz (3GPP thermal: -174).
    pub noise_dbm_per_hz: f64,
    /// Quantisation precision `eps` in bits/element (paper: fp16 = 16).
    pub quant_bits: u32,
    /// Block-fading coherence: how many MoE blocks share one fading draw.
    /// 0 = static channel (fading drawn once per run).
    pub fading_blocks: usize,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            total_bandwidth_hz: 100e6,
            carrier_ghz: 3.5,
            bs_power_w: 10.0,
            device_power_w: 0.2,
            noise_dbm_per_hz: -174.0,
            quant_bits: 16,
            fading_blocks: 0,
        }
    }
}

impl ChannelConfig {
    /// Noise PSD `N_0` in W/Hz.
    pub fn noise_w_per_hz(&self) -> f64 {
        10f64.powf((self.noise_dbm_per_hz - 30.0) / 10.0)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_bandwidth_hz", Json::Num(self.total_bandwidth_hz)),
            ("carrier_ghz", Json::Num(self.carrier_ghz)),
            ("bs_power_w", Json::Num(self.bs_power_w)),
            ("device_power_w", Json::Num(self.device_power_w)),
            ("noise_dbm_per_hz", Json::Num(self.noise_dbm_per_hz)),
            ("quant_bits", Json::Num(self.quant_bits as f64)),
            ("fading_blocks", Json::Num(self.fading_blocks as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            total_bandwidth_hz: j.get("total_bandwidth_hz")?.as_f64()?,
            carrier_ghz: j.get("carrier_ghz")?.as_f64()?,
            bs_power_w: j.get("bs_power_w")?.as_f64()?,
            device_power_w: j.get("device_power_w")?.as_f64()?,
            noise_dbm_per_hz: j.get("noise_dbm_per_hz")?.as_f64()?,
            quant_bits: j.get("quant_bits")?.as_usize()? as u32,
            fading_blocks: j.get("fading_blocks")?.as_usize()?,
        })
    }
}

/// One mobile device hosting an expert (paper: device k hosts expert k of
/// every MoE layer in the simulation setup).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Human-readable name ("jetson-agx-orin-0", …).
    pub name: String,
    /// Distance from the BS in metres (drives path loss).
    pub distance_m: f64,
    /// Compute capacity `C_k` in FLOP/s (paper Eq. (7)).
    pub compute_flops: f64,
    /// Multiplicative compute jitter stddev (0 = deterministic).
    pub compute_jitter: f64,
}

impl DeviceConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("distance_m", Json::Num(self.distance_m)),
            ("compute_flops", Json::Num(self.compute_flops)),
            ("compute_jitter", Json::Num(self.compute_jitter)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            distance_m: j.get("distance_m")?.as_f64()?,
            compute_flops: j.get("compute_flops")?.as_f64()?,
            compute_jitter: j.get("compute_jitter")?.as_f64()?,
        })
    }
}

/// Expert-selection policy selector (see `moe::selection`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Plain top-k on gate weights — the "Mixtral-based method" baseline.
    VanillaTopK,
    /// Paper Algorithm 1: cosine-similarity threshold, WLR-guarded.
    Wdmoe,
    /// Paper Algorithm 2: latency-history-driven testbed policy.
    Testbed,
    /// Uniform-random k experts (sanity baseline for ablations).
    Random,
}

impl PolicyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::VanillaTopK => "vanilla_top_k",
            PolicyKind::Wdmoe => "wdmoe",
            PolicyKind::Testbed => "testbed",
            PolicyKind::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "vanilla_top_k" => PolicyKind::VanillaTopK,
            "wdmoe" => PolicyKind::Wdmoe,
            "testbed" => PolicyKind::Testbed,
            "random" => PolicyKind::Random,
            other => anyhow::bail!("unknown policy kind '{other}'"),
        })
    }
}

/// Bandwidth-allocation strategy selector (see `wireless::bandwidth`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocatorKind {
    /// Even split `B_k = B/U` — the baseline.
    Uniform,
    /// Convex-optimal solution of problem P3 (min-max water filling).
    Optimal,
}

impl AllocatorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AllocatorKind::Uniform => "uniform",
            AllocatorKind::Optimal => "optimal",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "uniform" => AllocatorKind::Uniform,
            "optimal" => AllocatorKind::Optimal,
            other => anyhow::bail!("unknown allocator kind '{other}'"),
        })
    }
}

/// Policy block of the config.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    pub selection: PolicyKind,
    pub allocator: AllocatorKind,
    /// Algorithm 1 initial cosine-similarity threshold (paper: 0.5).
    pub theta_init: f64,
    /// Algorithm 1 threshold increment per round (paper: 0.1).
    pub theta_step: f64,
    /// Algorithm 1 WLR guard factor (paper: 1.01).
    pub wlr_guard: f64,
    /// Algorithm 2 bottleneck trigger vs third quartile (paper: 1.5).
    pub bottleneck_factor: f64,
    /// Algorithm 2 low-weight drop fraction (paper: 1/5 of device mass).
    pub drop_weight_frac: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            selection: PolicyKind::Wdmoe,
            allocator: AllocatorKind::Optimal,
            theta_init: 0.5,
            theta_step: 0.1,
            wlr_guard: 1.01,
            bottleneck_factor: 1.5,
            drop_weight_frac: 0.2,
        }
    }
}

impl PolicyConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("selection", Json::str(self.selection.as_str())),
            ("allocator", Json::str(self.allocator.as_str())),
            ("theta_init", Json::Num(self.theta_init)),
            ("theta_step", Json::Num(self.theta_step)),
            ("wlr_guard", Json::Num(self.wlr_guard)),
            ("bottleneck_factor", Json::Num(self.bottleneck_factor)),
            ("drop_weight_frac", Json::Num(self.drop_weight_frac)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            selection: PolicyKind::parse(j.get("selection")?.as_str()?)?,
            allocator: AllocatorKind::parse(j.get("allocator")?.as_str()?)?,
            theta_init: j.get("theta_init")?.as_f64()?,
            theta_step: j.get("theta_step")?.as_f64()?,
            wlr_guard: j.get("wlr_guard")?.as_f64()?,
            bottleneck_factor: j.get("bottleneck_factor")?.as_f64()?,
            drop_weight_frac: j.get("drop_weight_frac")?.as_f64()?,
        })
    }
}

/// The full system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub model: ModelDims,
    pub channel: ChannelConfig,
    pub devices: Vec<DeviceConfig>,
    pub policy: PolicyConfig,
    /// RNG seed for every stochastic element (fading, workload, jitter).
    pub seed: u64,
    /// FLOPs of the expert activation per hidden element (paper `eta`).
    pub activation_eta: f64,
}

impl SystemConfig {
    /// Number of devices `U`.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.to_json()),
            ("channel", self.channel.to_json()),
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| d.to_json()).collect()),
            ),
            ("policy", self.policy.to_json()),
            ("seed", Json::Num(self.seed as f64)),
            ("activation_eta", Json::Num(self.activation_eta)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            model: ModelDims::from_json(j.get("model")?)?,
            channel: ChannelConfig::from_json(j.get("channel")?)?,
            devices: j
                .get("devices")?
                .as_arr()?
                .iter()
                .map(DeviceConfig::from_json)
                .collect::<Result<Vec<_>>>()?,
            policy: PolicyConfig::from_json(j.get("policy")?)?,
            seed: j.get("seed")?.as_u64()?,
            activation_eta: j.get("activation_eta")?.as_f64()?,
        })
    }

    /// Load from a JSON file.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Write to a JSON file.
    pub fn save_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Validate invariants that would otherwise surface as NaNs deep in
    /// the latency model.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.devices.is_empty(), "at least one device required");
        anyhow::ensure!(
            self.model.n_experts == self.devices.len(),
            "n_experts ({}) must equal device count ({}) — the paper places expert k on device k",
            self.model.n_experts,
            self.devices.len()
        );
        anyhow::ensure!(self.channel.total_bandwidth_hz > 0.0, "bandwidth must be positive");
        anyhow::ensure!(self.model.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(
            self.model.top_k <= self.model.n_experts,
            "top_k exceeds expert count"
        );
        for d in &self.devices {
            anyhow::ensure!(d.distance_m > 0.0, "{}: distance must be positive", d.name);
            anyhow::ensure!(d.compute_flops > 0.0, "{}: compute must be positive", d.name);
            anyhow::ensure!(
                (0.0..1.0).contains(&d.compute_jitter),
                "{}: jitter must be in [0,1)",
                d.name
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l_comm_matches_eq4() {
        let m = ModelDims::mixtral_8x7b();
        assert_eq!(m.l_comm_bits(16), 16.0 * 4096.0);
    }

    #[test]
    fn l_comp_matches_eq5() {
        let m = ModelDims::mixtral_8x7b();
        let (md, mh) = (4096.0, 14336.0);
        let want = 4.0 * md * mh + 2.0 * mh * md + 7.0 * mh + mh;
        assert_eq!(m.l_comp_flops(7.0), want);
    }

    #[test]
    fn noise_psd_thermal() {
        let c = ChannelConfig::default();
        let n0 = c.noise_w_per_hz();
        assert!((n0 - 3.981e-21).abs() / 3.981e-21 < 1e-3, "n0={n0}");
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            SystemConfig::paper_simulation(),
            SystemConfig::paper_testbed(),
            SystemConfig::artifact_serving(),
        ] {
            let j = cfg.to_json();
            let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn json_file_roundtrip() {
        let dir = crate::util::temp_dir("cfg");
        let path = dir.join("config.json");
        let cfg = SystemConfig::paper_testbed();
        cfg.save_json(&path).unwrap();
        let back = SystemConfig::from_json_file(&path).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn presets_validate() {
        SystemConfig::paper_simulation().validate().unwrap();
        SystemConfig::paper_testbed().validate().unwrap();
        SystemConfig::artifact_serving().validate().unwrap();
    }

    #[test]
    fn kind_parsing_roundtrip() {
        for k in [
            PolicyKind::VanillaTopK,
            PolicyKind::Wdmoe,
            PolicyKind::Testbed,
            PolicyKind::Random,
        ] {
            assert_eq!(PolicyKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(PolicyKind::parse("bogus").is_err());
        for a in [AllocatorKind::Uniform, AllocatorKind::Optimal] {
            assert_eq!(AllocatorKind::parse(a.as_str()).unwrap(), a);
        }
        assert!(AllocatorKind::parse("bogus").is_err());
    }

    #[test]
    fn validation_rejects_mismatched_experts() {
        let mut cfg = SystemConfig::paper_simulation();
        cfg.devices.pop();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_topk() {
        let mut cfg = SystemConfig::paper_simulation();
        cfg.model.top_k = 99;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_distance() {
        let mut cfg = SystemConfig::paper_simulation();
        cfg.devices[0].distance_m = 0.0;
        assert!(cfg.validate().is_err());
    }
}
