//! Config presets matching the paper's two experimental setups plus the
//! locally-executable artifact configuration.

use super::*;

impl SystemConfig {
    /// Section V simulation setup: 8 mobile devices, Mixtral-8x7B-scale
    /// model, 100 MHz total bandwidth at 3.5 GHz, BS 10 W / device 0.2 W.
    ///
    /// The paper does not publish per-device distances or capacities; the
    /// values here are chosen to span a realistic cell (50–350 m) and the
    /// consumer-accelerator range the paper's testbed motivates (Jetson
    /// Xavier NX ≈ 1 TFLOPS fp16-effective up to RTX-4070-Ti-class ≈ 20
    /// TFLOPS effective). EXPERIMENTS.md records how the resulting
    /// baseline latencies line up with Table II.
    pub fn paper_simulation() -> Self {
        let dists = [60.0, 95.0, 130.0, 170.0, 210.0, 255.0, 300.0, 350.0];
        let flops = [20e12, 10e12, 15e12, 5e12, 10e12, 2e12, 5e12, 1e12];
        let devices = dists
            .iter()
            .zip(flops.iter())
            .enumerate()
            .map(|(i, (&d, &c))| DeviceConfig {
                name: format!("device-{i}"),
                distance_m: d,
                compute_flops: c,
                compute_jitter: 0.0,
            })
            .collect();
        Self {
            model: ModelDims::mixtral_8x7b(),
            channel: ChannelConfig::default(),
            devices,
            policy: PolicyConfig::default(),
            seed: 0,
            activation_eta: 7.0,
        }
    }

    /// Section VI hardware testbed: 2× Jetson AGX Orin, 1× Jetson Xavier
    /// NX, 1× RTX 4070 Ti PC, all within a 1.45 m × 0.8 m indoor area
    /// around a WiFi AP (802.11ax). Four experts per device per layer in
    /// the paper; here device k hosts expert k (n_experts = 4) which
    /// preserves the load-balancing dynamics Algorithm 2 acts on.
    pub fn paper_testbed() -> Self {
        let devices = vec![
            DeviceConfig {
                name: "jetson-agx-orin-0".into(),
                distance_m: 0.9,
                compute_flops: 8e12,
                compute_jitter: 0.15,
            },
            DeviceConfig {
                name: "jetson-agx-orin-1".into(),
                distance_m: 1.2,
                compute_flops: 8e12,
                compute_jitter: 0.15,
            },
            DeviceConfig {
                name: "jetson-xavier-nx".into(),
                distance_m: 0.7,
                compute_flops: 1.5e12,
                compute_jitter: 0.20,
            },
            DeviceConfig {
                name: "rtx-4070-ti-pc".into(),
                distance_m: 1.4,
                compute_flops: 25e12,
                compute_jitter: 0.10,
            },
        ];
        let mut model = ModelDims::mixtral_8x7b();
        model.n_experts = 4;
        Self {
            model,
            channel: ChannelConfig {
                // 802.11ax: 80 MHz channel, AP ~0.1 W, device ~0.05 W,
                // 5 GHz band; short range keeps SNR high like real WiFi.
                total_bandwidth_hz: 80e6,
                carrier_ghz: 5.0,
                bs_power_w: 0.1,
                device_power_w: 0.05,
                noise_dbm_per_hz: -174.0,
                quant_bits: 16,
                fading_blocks: 1,
            },
            devices,
            policy: PolicyConfig {
                selection: PolicyKind::Testbed,
                allocator: AllocatorKind::Uniform, // testbed does no BW allocation (§VI-C)
                ..PolicyConfig::default()
            },
            seed: 0,
            activation_eta: 7.0,
        }
    }

    /// Locally executable configuration matching the shipped AOT artifacts
    /// (`artifacts/manifest.json`): ~27.8M-param model, 8 devices scaled so
    /// per-token latencies stay in interactive range.
    pub fn artifact_serving() -> Self {
        let mut cfg = Self::paper_simulation();
        cfg.model = ModelDims::artifact_default();
        cfg
    }
}
