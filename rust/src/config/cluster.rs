//! Cluster-serving configuration: multiple cells, expert replication and
//! sustained open-loop traffic (the substrate of [`crate::cluster`]).
//!
//! A [`ClusterConfig`] describes a small edge deployment: `n` cells, each
//! a BS with its own device fleet, channel scenario and bandwidth budget;
//! a shared MoE model; a per-device expert cache capacity (how many
//! experts' weights a device can hold — the paper's §I "limited computing
//! and caching resources" constraint, Eq. (7)); and the dispatch policy
//! that picks among expert replicas at serving time.

use super::energy::EnergyConfig;
use super::faults::FaultConfig;
use super::{AllocatorKind, ChannelConfig, DeviceConfig, ModelDims, PolicyConfig};
use crate::util::Json;
use anyhow::Result;

/// Which control plane owns a cell's bandwidth allocation and expert
/// placement (see [`crate::control`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlKind {
    /// Uniform bandwidth split, placement balanced on device speed under
    /// a uniform expert-load assumption, both frozen at construction —
    /// the PR-1 baseline behaviour.
    StaticUniform,
    /// One-shot P3 pre-solve (equal expected load per device) frozen at
    /// construction; placement balanced under the pre-solved split.
    StaticOptimal,
    /// Closed loop: re-solve P3 from observed per-device demand on an
    /// epoch cadence inside the DES (warm-started), and re-optimize
    /// placement from observed per-expert token counts.
    Adaptive,
}

impl ControlKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ControlKind::StaticUniform => "static_uniform",
            ControlKind::StaticOptimal => "static_optimal",
            ControlKind::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "static_uniform" | "uniform" => ControlKind::StaticUniform,
            "static_optimal" | "optimal" => ControlKind::StaticOptimal,
            "adaptive" => ControlKind::Adaptive,
            other => anyhow::bail!("unknown control kind '{other}'"),
        })
    }

    /// All kinds, in baseline → adaptive order (comparison sweeps).
    pub fn all() -> [ControlKind; 3] {
        [
            ControlKind::StaticUniform,
            ControlKind::StaticOptimal,
            ControlKind::Adaptive,
        ]
    }
}

impl From<AllocatorKind> for ControlKind {
    fn from(a: AllocatorKind) -> Self {
        match a {
            AllocatorKind::Uniform => ControlKind::StaticUniform,
            AllocatorKind::Optimal => ControlKind::StaticOptimal,
        }
    }
}

/// What happens when a dispatch would exceed a device's queue bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropPolicy {
    /// Reject the whole request: no further blocks are scheduled and it
    /// counts against the drop rate (admission control).
    DropRequest,
    /// Shed only the offending expert's token group; the request
    /// continues degraded (quality-for-latency trade).
    ShedTokens,
}

impl DropPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            DropPolicy::DropRequest => "drop_request",
            DropPolicy::ShedTokens => "shed_tokens",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "drop_request" | "request" | "drop" => DropPolicy::DropRequest,
            "shed_tokens" | "shed" | "tokens" => DropPolicy::ShedTokens,
            other => anyhow::bail!("unknown drop policy '{other}'"),
        })
    }
}

/// Inter-cell handover: whether (and how) a request's work may cross
/// cell boundaries (see [`crate::cluster::handover`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandoverPolicy {
    /// Requests are pinned to their round-robin cell for their whole
    /// lifetime — the pre-handover baseline behaviour, unchanged
    /// (handover CSV columns report zero).
    None,
    /// Load-aware cell choice at arrival: the request is homed on the
    /// cell with the lowest live backlog per online device instead of
    /// blind round-robin (ties keep the round-robin home).
    RehomeOnArrival,
    /// Cross-cell expert borrowing at dispatch: when every local replica
    /// of a selected expert is over the queue bound or unserviceable,
    /// the token group is routed to the least-loaded neighbor cell's
    /// replica, paying `backhaul_s_per_token` per token per hop.
    BorrowExpert,
}

impl HandoverPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            HandoverPolicy::None => "none",
            HandoverPolicy::RehomeOnArrival => "rehome_on_arrival",
            HandoverPolicy::BorrowExpert => "borrow_expert",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" | "off" => HandoverPolicy::None,
            "rehome_on_arrival" | "rehome" => HandoverPolicy::RehomeOnArrival,
            "borrow_expert" | "borrow" => HandoverPolicy::BorrowExpert,
            other => anyhow::bail!("unknown handover policy '{other}'"),
        })
    }

    /// All policies, in baseline → borrowing order (comparison sweeps).
    pub fn all() -> [HandoverPolicy; 3] {
        [
            HandoverPolicy::None,
            HandoverPolicy::RehomeOnArrival,
            HandoverPolicy::BorrowExpert,
        ]
    }
}

/// How the BS picks among the replicas of a selected expert.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKind {
    /// Minimise predicted completion time (queue backlog + Eq. (9)–(11)
    /// service) over the expert's online replicas.
    LoadAware,
    /// Always the expert's home replica — the no-replication baseline's
    /// behaviour even when replicas exist.
    Static,
}

impl DispatchKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DispatchKind::LoadAware => "load_aware",
            DispatchKind::Static => "static",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "load_aware" | "loadaware" => DispatchKind::LoadAware,
            "static" | "home" => DispatchKind::Static,
            other => anyhow::bail!("unknown dispatch kind '{other}'"),
        })
    }
}

/// One cell: a BS with its own channel scenario and device fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct CellConfig {
    pub name: String,
    pub channel: ChannelConfig,
    pub devices: Vec<DeviceConfig>,
}

impl CellConfig {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("channel", self.channel.to_json()),
            (
                "devices",
                Json::Arr(self.devices.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            channel: ChannelConfig::from_json(j.get("channel")?)?,
            devices: j
                .get("devices")?
                .as_arr()?
                .iter()
                .map(DeviceConfig::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// Full multi-cell serving configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub model: ModelDims,
    pub cells: Vec<CellConfig>,
    pub policy: PolicyConfig,
    /// Experts a device can cache (1 = no replication).
    pub cache_capacity: usize,
    /// Replica-choice policy at dispatch time.
    pub dispatch: DispatchKind,
    /// Control plane owning bandwidth allocation + placement per cell.
    pub control: ControlKind,
    /// Adaptive re-solve cadence in virtual seconds.
    pub control_epoch_s: f64,
    /// Minimum relative L1 shift of the per-device demand share since the
    /// last solve before the adaptive plane re-solves (churn damping).
    pub control_hysteresis: f64,
    /// Backlog-delta trigger for the adaptive plane: when a cell's total
    /// queued seconds drift more than this since its last solve, it
    /// re-solves immediately instead of waiting for the next epoch tick
    /// (0 = epoch cadence only). Ignored by the static planes.
    pub control_backlog_delta_s: f64,
    /// Per-device queue bound in seconds of backlog (0 = unbounded).
    pub queue_limit_s: f64,
    /// Policy applied when a dispatch would exceed the queue bound.
    pub drop_policy: DropPolicy,
    /// Inter-cell handover policy (cross-cell dispatch layer).
    pub handover: HandoverPolicy,
    /// One-way inter-cell transfer latency per token (seconds). Borrowed
    /// groups pay it twice: once to reach the neighbor, once for the
    /// result to return through the Eq. (11) barrier.
    pub backhaul_s_per_token: f64,
    /// Optional per-cell-pair backhaul latency (seconds per token),
    /// `matrix[from][to]` for the directed `from → to` hop. `None`
    /// means every pair pays the uniform [`Self::backhaul_s_per_token`]
    /// (read through [`Self::backhaul_pair`]). The matrix may be
    /// asymmetric; the diagonal is never read. Its off-diagonal minimum
    /// is the conservative lookahead bound of the sharded DES.
    pub backhaul_matrix: Option<Vec<Vec<f64>>>,
    /// Deterministic fault-injection plan (crashes, stragglers, link dips,
    /// backhaul outages). The default plan is empty and compiles away.
    pub faults: FaultConfig,
    /// Per-request latency SLO in seconds (0 = no deadline). When set,
    /// completions slower than the deadline and dropped requests count as
    /// SLO misses, and `hedge` may arm speculative duplicates.
    pub deadline_s: f64,
    /// Hedged dispatch: when a group's predicted finish would bust the
    /// deadline, also place a speculative duplicate on the second-best
    /// replica — first finish wins, the loser's tokens count as waste.
    /// Only meaningful with `deadline_s > 0`.
    pub hedge: bool,
    /// Re-dispatch budget per request when a crash loses its queued or
    /// in-service groups (0 = fall straight through to the drop policy).
    pub max_retries: u32,
    /// Per-device energy model (joules/token, battery, idle draw). The
    /// default model is empty and compiles away.
    pub energy: EnergyConfig,
    /// Weight of the energy term in the dispatch objective: 0 = pure
    /// latency (the pre-energy scoring, bit-equal); > 0 trades predicted
    /// finish time against joules/token and remaining battery.
    pub energy_weight: f64,
    /// Fraction of completed requests discarded as warm-up before
    /// steady-state latency percentiles are computed.
    pub warmup_frac: f64,
    /// Synthetic-router concentration (see `WorkloadGen`).
    pub gate_sharpness: f64,
    /// Per-block expert-popularity bias std (trained-router imbalance).
    pub gate_bias: f64,
    /// FLOPs of the expert activation per hidden element (paper `eta`).
    pub activation_eta: f64,
    /// RNG seed for every stochastic element (arrivals, gating).
    pub seed: u64,
}

impl ClusterConfig {
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Backhaul latency (seconds per token) for the directed hop
    /// `from → to`, falling back to the uniform scalar when no matrix
    /// is configured.
    pub fn backhaul_pair(&self, from: usize, to: usize) -> f64 {
        match &self.backhaul_matrix {
            Some(m) => m[from][to],
            None => self.backhaul_s_per_token,
        }
    }

    /// Minimum off-diagonal backhaul latency (seconds per token) — the
    /// conservative lookahead bound of the sharded DES. Equals the
    /// uniform scalar when no matrix is set, and `None` for a single
    /// cell (no inter-cell hops exist).
    pub fn min_backhaul_s_per_token(&self) -> Option<f64> {
        let n = self.cells.len();
        if n < 2 {
            return None;
        }
        let mut min = f64::INFINITY;
        for from in 0..n {
            for to in 0..n {
                if from != to {
                    min = min.min(self.backhaul_pair(from, to));
                }
            }
        }
        Some(min)
    }

    /// Two-cell edge deployment: each cell reuses the §V fleet shape
    /// (50–350 m, 1–20 TFLOPS) with slightly different geometry, Mixtral
    /// dims, 100 MHz per cell and a 2-expert cache per device.
    pub fn edge_default() -> Self {
        let base = super::SystemConfig::paper_simulation();
        let devices = base
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| DeviceConfig {
                name: format!("cell0-dev{i}"),
                distance_m: d.distance_m,
                compute_flops: d.compute_flops,
                compute_jitter: 0.0,
            })
            .collect();
        let cfg = Self {
            model: ModelDims::mixtral_8x7b(),
            cells: vec![CellConfig {
                name: "cell-0".to_string(),
                channel: base.channel.clone(),
                devices,
            }],
            policy: PolicyConfig::default(),
            cache_capacity: 2,
            dispatch: DispatchKind::LoadAware,
            control: ControlKind::StaticUniform,
            control_epoch_s: 0.25,
            control_hysteresis: 0.05,
            control_backlog_delta_s: 0.0,
            queue_limit_s: 0.0,
            drop_policy: DropPolicy::DropRequest,
            handover: HandoverPolicy::None,
            backhaul_s_per_token: 2e-4,
            backhaul_matrix: None,
            faults: FaultConfig::default(),
            deadline_s: 0.0,
            hedge: false,
            max_retries: 2,
            energy: EnergyConfig::default(),
            energy_weight: 0.0,
            warmup_frac: 0.2,
            gate_sharpness: 1.5,
            gate_bias: 0.4,
            activation_eta: 7.0,
            seed: 0,
        };
        cfg.with_n_cells(2)
    }

    /// Single-cell variant of [`Self::edge_default`] (tests, benches).
    pub fn single_cell() -> Self {
        Self::edge_default().with_n_cells(1)
    }

    /// Grow (or shrink) to `n` cells. Extra cells are synthesized from
    /// cell 0's template with the preset naming convention and a 15 m
    /// geometry shift per cell, so every cell sees a different channel.
    pub fn with_n_cells(mut self, n: usize) -> Self {
        assert!(n >= 1, "need at least one cell");
        assert!(!self.cells.is_empty(), "no template cell to clone");
        // A per-pair matrix is keyed by cell index, so changing the cell
        // count invalidates it; fall back to the uniform scalar.
        if self.cells.len() != n {
            self.backhaul_matrix = None;
        }
        let template = self.cells[0].clone();
        while self.cells.len() < n {
            let i = self.cells.len();
            let mut c = template.clone();
            c.name = format!("cell-{i}");
            for (di, d) in c.devices.iter_mut().enumerate() {
                d.name = format!("cell{i}-dev{di}");
                d.distance_m += 15.0 * i as f64;
            }
            self.cells.push(c);
        }
        self.cells.truncate(n);
        self
    }

    /// Load from a JSON file (the format `repro config cluster` prints).
    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", self.model.to_json()),
            (
                "cells",
                Json::Arr(self.cells.iter().map(|c| c.to_json()).collect()),
            ),
            ("policy", self.policy.to_json()),
            ("cache_capacity", Json::Num(self.cache_capacity as f64)),
            ("dispatch", Json::str(self.dispatch.as_str())),
            ("control", Json::str(self.control.as_str())),
            ("control_epoch_s", Json::Num(self.control_epoch_s)),
            ("control_hysteresis", Json::Num(self.control_hysteresis)),
            (
                "control_backlog_delta_s",
                Json::Num(self.control_backlog_delta_s),
            ),
            ("queue_limit_s", Json::Num(self.queue_limit_s)),
            ("drop_policy", Json::str(self.drop_policy.as_str())),
            ("handover", Json::str(self.handover.as_str())),
            ("backhaul_s_per_token", Json::Num(self.backhaul_s_per_token)),
        ];
        // Emitted only when set: configs with the uniform scalar keep
        // the exact byte output of the previous format.
        if let Some(m) = &self.backhaul_matrix {
            fields.push((
                "backhaul_matrix",
                Json::Arr(
                    m.iter()
                        .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v)).collect()))
                        .collect(),
                ),
            ));
        }
        // Same discipline for the robustness knobs: emitted only when they
        // differ from the defaults, so pre-fault configs keep their bytes.
        if self.faults != FaultConfig::default() {
            fields.push(("faults", self.faults.to_json()));
        }
        if self.deadline_s != 0.0 {
            fields.push(("deadline_s", Json::Num(self.deadline_s)));
        }
        if self.hedge {
            fields.push(("hedge", Json::Bool(true)));
        }
        if self.max_retries != 2 {
            fields.push(("max_retries", Json::Num(self.max_retries as f64)));
        }
        if self.energy != EnergyConfig::default() {
            fields.push(("energy", self.energy.to_json()));
        }
        if self.energy_weight != 0.0 {
            fields.push(("energy_weight", Json::Num(self.energy_weight)));
        }
        fields.extend([
            ("warmup_frac", Json::Num(self.warmup_frac)),
            ("gate_sharpness", Json::Num(self.gate_sharpness)),
            ("gate_bias", Json::Num(self.gate_bias)),
            ("activation_eta", Json::Num(self.activation_eta)),
            ("seed", Json::Num(self.seed as f64)),
        ]);
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        // The control/admission knobs postdate the first released config
        // format: files written before they existed (or hand-trimmed
        // ones) load with the documented defaults instead of erroring.
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            j.opt(key).map_or(Ok(default), |v| v.as_f64())
        };
        Ok(Self {
            model: ModelDims::from_json(j.get("model")?)?,
            cells: j
                .get("cells")?
                .as_arr()?
                .iter()
                .map(CellConfig::from_json)
                .collect::<Result<Vec<_>>>()?,
            policy: PolicyConfig::from_json(j.get("policy")?)?,
            cache_capacity: j.get("cache_capacity")?.as_usize()?,
            dispatch: DispatchKind::parse(j.get("dispatch")?.as_str()?)?,
            control: match j.opt("control") {
                Some(v) => ControlKind::parse(v.as_str()?)?,
                None => ControlKind::StaticUniform,
            },
            control_epoch_s: opt_f64("control_epoch_s", 0.25)?,
            control_hysteresis: opt_f64("control_hysteresis", 0.05)?,
            control_backlog_delta_s: opt_f64("control_backlog_delta_s", 0.0)?,
            queue_limit_s: opt_f64("queue_limit_s", 0.0)?,
            drop_policy: match j.opt("drop_policy") {
                Some(v) => DropPolicy::parse(v.as_str()?)?,
                None => DropPolicy::DropRequest,
            },
            handover: match j.opt("handover") {
                Some(v) => HandoverPolicy::parse(v.as_str()?)?,
                None => HandoverPolicy::None,
            },
            backhaul_s_per_token: opt_f64("backhaul_s_per_token", 2e-4)?,
            backhaul_matrix: match j.opt("backhaul_matrix") {
                Some(v) => Some(
                    v.as_arr()?
                        .iter()
                        .map(|row| {
                            row.as_arr()?
                                .iter()
                                .map(|x| x.as_f64())
                                .collect::<Result<Vec<f64>>>()
                        })
                        .collect::<Result<Vec<Vec<f64>>>>()?,
                ),
                None => None,
            },
            faults: match j.opt("faults") {
                Some(v) => FaultConfig::from_json(v)?,
                None => FaultConfig::default(),
            },
            deadline_s: opt_f64("deadline_s", 0.0)?,
            hedge: match j.opt("hedge") {
                Some(v) => v.as_bool()?,
                None => false,
            },
            max_retries: match j.opt("max_retries") {
                Some(v) => v.as_u64()? as u32,
                None => 2,
            },
            energy: match j.opt("energy") {
                Some(v) => EnergyConfig::from_json(v)?,
                None => EnergyConfig::default(),
            },
            energy_weight: opt_f64("energy_weight", 0.0)?,
            warmup_frac: j.get("warmup_frac")?.as_f64()?,
            gate_sharpness: j.get("gate_sharpness")?.as_f64()?,
            gate_bias: j.get("gate_bias")?.as_f64()?,
            activation_eta: j.get("activation_eta")?.as_f64()?,
            seed: j.get("seed")?.as_u64()?,
        })
    }

    /// Invariants the cluster simulator assumes.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.cells.is_empty(), "at least one cell required");
        anyhow::ensure!(self.cache_capacity >= 1, "cache capacity must be >= 1");
        anyhow::ensure!(self.model.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(
            self.model.top_k <= self.model.n_experts,
            "top_k exceeds expert count"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.warmup_frac),
            "warmup_frac must be in [0,1)"
        );
        anyhow::ensure!(
            self.control_epoch_s.is_finite() && self.control_epoch_s > 0.0,
            "control_epoch_s must be positive and finite"
        );
        anyhow::ensure!(
            self.control_hysteresis.is_finite() && self.control_hysteresis >= 0.0,
            "control_hysteresis must be non-negative and finite"
        );
        anyhow::ensure!(
            self.control_backlog_delta_s.is_finite() && self.control_backlog_delta_s >= 0.0,
            "control_backlog_delta_s must be non-negative and finite (0 = epoch cadence only)"
        );
        anyhow::ensure!(
            self.queue_limit_s.is_finite() && self.queue_limit_s >= 0.0,
            "queue_limit_s must be non-negative and finite (0 = unbounded)"
        );
        anyhow::ensure!(
            self.backhaul_s_per_token.is_finite() && self.backhaul_s_per_token >= 0.0,
            "backhaul_s_per_token must be non-negative and finite"
        );
        anyhow::ensure!(
            self.deadline_s.is_finite() && self.deadline_s >= 0.0,
            "deadline_s must be non-negative and finite (0 = no deadline)"
        );
        let device_counts: Vec<usize> = self.cells.iter().map(|c| c.devices.len()).collect();
        self.faults.validate(&device_counts)?;
        self.energy.validate()?;
        anyhow::ensure!(
            self.energy_weight.is_finite() && self.energy_weight >= 0.0,
            "energy_weight must be non-negative and finite (0 = pure latency)"
        );
        if let Some(m) = &self.backhaul_matrix {
            anyhow::ensure!(
                m.len() == self.cells.len(),
                "backhaul_matrix has {} rows for {} cells",
                m.len(),
                self.cells.len()
            );
            for (i, row) in m.iter().enumerate() {
                anyhow::ensure!(
                    row.len() == self.cells.len(),
                    "backhaul_matrix row {i} has {} entries for {} cells",
                    row.len(),
                    self.cells.len()
                );
                for (j, &v) in row.iter().enumerate() {
                    anyhow::ensure!(
                        v.is_finite() && v >= 0.0,
                        "backhaul_matrix[{i}][{j}] must be non-negative and finite"
                    );
                }
            }
        }
        for cell in &self.cells {
            anyhow::ensure!(
                !cell.devices.is_empty(),
                "{}: at least one device required",
                cell.name
            );
            // Every expert needs a host: n_experts <= devices x cache is
            // exactly ceil(n_experts / n_devices) <= cache for the
            // round-robin home placement.
            anyhow::ensure!(
                self.model.n_experts <= cell.devices.len() * self.cache_capacity,
                "{}: {} devices with cache {} cannot host {} experts",
                cell.name,
                cell.devices.len(),
                self.cache_capacity,
                self.model.n_experts
            );
            anyhow::ensure!(
                cell.channel.total_bandwidth_hz > 0.0,
                "{}: bandwidth must be positive",
                cell.name
            );
            for d in &cell.devices {
                anyhow::ensure!(d.distance_m > 0.0, "{}: distance must be positive", d.name);
                anyhow::ensure!(d.compute_flops > 0.0, "{}: compute must be positive", d.name);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ClusterConfig::edge_default().validate().unwrap();
        ClusterConfig::single_cell().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ClusterConfig::edge_default();
        let back = ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn with_n_cells_synthesizes_from_template() {
        let cfg = ClusterConfig::edge_default().with_n_cells(4);
        cfg.validate().unwrap();
        assert_eq!(cfg.n_cells(), 4);
        assert_eq!(cfg.cells[3].name, "cell-3");
        assert_eq!(cfg.cells[3].devices[0].name, "cell3-dev0");
        // each synthesized cell is shifted 15 m per index
        assert_eq!(
            cfg.cells[3].devices[0].distance_m,
            cfg.cells[0].devices[0].distance_m + 45.0
        );
        // shrinking works too
        assert_eq!(cfg.with_n_cells(1).n_cells(), 1);
    }

    #[test]
    fn json_file_roundtrip() {
        let dir = crate::util::temp_dir("cluster-cfg");
        let path = dir.join("cluster.json");
        let cfg = ClusterConfig::edge_default();
        std::fs::write(&path, cfg.to_json().to_string()).unwrap();
        let back = ClusterConfig::from_json_file(&path).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dispatch_kind_parsing_roundtrip() {
        for k in [DispatchKind::LoadAware, DispatchKind::Static] {
            assert_eq!(DispatchKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(DispatchKind::parse("bogus").is_err());
    }

    #[test]
    fn control_kind_parsing_roundtrip() {
        for k in ControlKind::all() {
            assert_eq!(ControlKind::parse(k.as_str()).unwrap(), k);
        }
        // allocator-style aliases
        assert_eq!(
            ControlKind::parse("uniform").unwrap(),
            ControlKind::StaticUniform
        );
        assert_eq!(
            ControlKind::parse("optimal").unwrap(),
            ControlKind::StaticOptimal
        );
        assert!(ControlKind::parse("bogus").is_err());
        assert_eq!(
            ControlKind::from(AllocatorKind::Uniform),
            ControlKind::StaticUniform
        );
        assert_eq!(
            ControlKind::from(AllocatorKind::Optimal),
            ControlKind::StaticOptimal
        );
    }

    #[test]
    fn drop_policy_parsing_roundtrip() {
        for p in [DropPolicy::DropRequest, DropPolicy::ShedTokens] {
            assert_eq!(DropPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(DropPolicy::parse("shed").unwrap(), DropPolicy::ShedTokens);
        assert!(DropPolicy::parse("bogus").is_err());
    }

    #[test]
    fn json_without_control_fields_loads_defaults() {
        // Configs written before the control/admission knobs existed
        // must still load, with the documented defaults.
        let mut cfg = ClusterConfig::edge_default();
        cfg.control = ControlKind::Adaptive;
        cfg.queue_limit_s = 3.0;
        let Json::Obj(mut m) = cfg.to_json() else {
            panic!("config serializes to an object")
        };
        for key in [
            "control",
            "control_epoch_s",
            "control_hysteresis",
            "control_backlog_delta_s",
            "queue_limit_s",
            "drop_policy",
            "handover",
            "backhaul_s_per_token",
        ] {
            m.remove(key);
        }
        let back = ClusterConfig::from_json(&Json::Obj(m)).unwrap();
        assert_eq!(back.control, ControlKind::StaticUniform);
        assert_eq!(back.control_epoch_s, 0.25);
        assert_eq!(back.control_hysteresis, 0.05);
        assert_eq!(back.control_backlog_delta_s, 0.0);
        assert_eq!(back.queue_limit_s, 0.0);
        assert_eq!(back.drop_policy, DropPolicy::DropRequest);
        assert_eq!(back.handover, HandoverPolicy::None);
        assert_eq!(back.backhaul_s_per_token, 2e-4);
        back.validate().unwrap();
    }

    #[test]
    fn handover_policy_parsing_roundtrip() {
        for p in HandoverPolicy::all() {
            assert_eq!(HandoverPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(
            HandoverPolicy::parse("rehome").unwrap(),
            HandoverPolicy::RehomeOnArrival
        );
        assert_eq!(
            HandoverPolicy::parse("borrow").unwrap(),
            HandoverPolicy::BorrowExpert
        );
        assert!(HandoverPolicy::parse("bogus").is_err());
    }

    #[test]
    fn json_roundtrip_preserves_handover_fields() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.handover = HandoverPolicy::BorrowExpert;
        cfg.backhaul_s_per_token = 5e-4;
        cfg.queue_limit_s = 1.0;
        let back = ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn validation_rejects_bad_backhaul() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.backhaul_s_per_token = -1e-4;
        assert!(cfg.validate().is_err());
        cfg.backhaul_s_per_token = f64::INFINITY;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_roundtrip_preserves_backhaul_matrix() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.backhaul_matrix = Some(vec![vec![0.0, 3e-4], vec![5e-4, 0.0]]);
        cfg.validate().unwrap();
        let back = ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn backhaul_matrix_absent_stays_uniform() {
        let cfg = ClusterConfig::edge_default();
        assert_eq!(cfg.backhaul_matrix, None);
        // to_json omits the key entirely when unset, so the serialized
        // form matches the pre-matrix format byte for byte.
        assert!(!cfg.to_json().to_string().contains("backhaul_matrix"));
        assert_eq!(cfg.backhaul_pair(0, 1), cfg.backhaul_s_per_token);
        assert_eq!(
            cfg.min_backhaul_s_per_token(),
            Some(cfg.backhaul_s_per_token)
        );
    }

    #[test]
    fn backhaul_pair_reads_directed_entries() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.backhaul_matrix = Some(vec![vec![0.0, 3e-4], vec![5e-4, 0.0]]);
        assert_eq!(cfg.backhaul_pair(0, 1), 3e-4);
        assert_eq!(cfg.backhaul_pair(1, 0), 5e-4);
        // lookahead bound = off-diagonal minimum; diagonal ignored
        assert_eq!(cfg.min_backhaul_s_per_token(), Some(3e-4));
        assert_eq!(
            ClusterConfig::single_cell().min_backhaul_s_per_token(),
            None
        );
    }

    #[test]
    fn validation_rejects_bad_backhaul_matrix() {
        let mut cfg = ClusterConfig::edge_default();
        // wrong row count
        cfg.backhaul_matrix = Some(vec![vec![0.0, 1e-4]]);
        assert!(cfg.validate().is_err());
        // ragged row
        cfg.backhaul_matrix = Some(vec![vec![0.0, 1e-4], vec![1e-4]]);
        assert!(cfg.validate().is_err());
        // negative entry
        cfg.backhaul_matrix = Some(vec![vec![0.0, -1e-4], vec![1e-4, 0.0]]);
        assert!(cfg.validate().is_err());
        // non-finite entry
        cfg.backhaul_matrix = Some(vec![vec![0.0, f64::NAN], vec![1e-4, 0.0]]);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fault_fields_absent_keep_default_bytes() {
        let cfg = ClusterConfig::edge_default();
        let text = cfg.to_json().to_string();
        // Default robustness knobs are omitted entirely, so pre-fault
        // configs serialize byte-identically to the previous format.
        assert!(!text.contains("\"faults\""));
        assert!(!text.contains("deadline_s"));
        assert!(!text.contains("hedge"));
        assert!(!text.contains("max_retries"));
        let back = ClusterConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn fault_fields_round_trip_through_json() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.mttf_s = 30.0;
        cfg.faults.mttr_s = 2.0;
        cfg.faults.scheduled.push(super::super::faults::ScheduledFault {
            at_s: 1.5,
            cell: 1,
            device: None,
            kind: super::super::faults::FaultKind::Straggle,
            duration_s: 3.0,
            mult: 5.0,
        });
        cfg.deadline_s = 2.5;
        cfg.hedge = true;
        cfg.max_retries = 4;
        cfg.validate().unwrap();
        let back =
            ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn validation_rejects_bad_fault_and_deadline_fields() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.deadline_s = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.deadline_s = -1.0;
        assert!(cfg.validate().is_err());

        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.mttf_s = 10.0;
        cfg.faults.mttr_s = 0.0;
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("mttr_s"), "{err}");

        // Scheduled faults are bounds-checked against the actual topology.
        let mut cfg = ClusterConfig::edge_default();
        cfg.faults.scheduled.push(super::super::faults::ScheduledFault {
            at_s: 0.5,
            cell: 7,
            device: None,
            kind: super::super::faults::FaultKind::Crash,
            duration_s: 0.0,
            mult: 1.0,
        });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn energy_fields_absent_keep_default_bytes() {
        let cfg = ClusterConfig::edge_default();
        let text = cfg.to_json().to_string();
        // The default (empty) energy model is omitted entirely, so
        // pre-energy configs serialize byte-identically to before.
        assert!(!text.contains("\"energy\""));
        assert!(!text.contains("energy_weight"));
        let back = ClusterConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn energy_fields_round_trip_through_json() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.energy.compute_j_per_token = 0.02;
        cfg.energy.tx_j_per_token = 0.004;
        cfg.energy.battery_j = 150.0;
        cfg.energy.recharge_s = 5.0;
        cfg.energy.classes = EnergyConfig::class_preset("mixed").unwrap();
        cfg.energy_weight = 0.5;
        cfg.validate().unwrap();
        let back =
            ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn validation_rejects_bad_energy_fields() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.energy.compute_j_per_token = -0.5;
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("compute_j_per_token"), "{err}");

        let mut cfg = ClusterConfig::edge_default();
        cfg.energy_weight = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.energy_weight = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn with_n_cells_drops_stale_backhaul_matrix() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.backhaul_matrix = Some(vec![vec![0.0, 1e-4], vec![1e-4, 0.0]]);
        // same cell count: the matrix is still index-valid and kept
        assert!(cfg.clone().with_n_cells(2).backhaul_matrix.is_some());
        // count change invalidates the indexing
        assert_eq!(cfg.with_n_cells(3).backhaul_matrix, None);
    }

    #[test]
    fn json_roundtrip_preserves_control_fields() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.control = ControlKind::Adaptive;
        cfg.control_epoch_s = 0.5;
        cfg.control_hysteresis = 0.1;
        cfg.control_backlog_delta_s = 0.2;
        cfg.queue_limit_s = 2.0;
        cfg.drop_policy = DropPolicy::ShedTokens;
        let back = ClusterConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn validation_rejects_bad_control_knobs() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.control_epoch_s = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ClusterConfig::edge_default();
        cfg.control_hysteresis = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = ClusterConfig::edge_default();
        cfg.control_backlog_delta_s = -0.5;
        assert!(cfg.validate().is_err());
        let mut cfg = ClusterConfig::edge_default();
        cfg.queue_limit_s = f64::NAN;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_infeasible_cache() {
        let mut cfg = ClusterConfig::single_cell();
        cfg.cache_capacity = 1;
        cfg.cells[0].devices.truncate(4); // 8 experts on 4 devices needs cache >= 2
        assert!(cfg.validate().is_err());
        cfg.cache_capacity = 2;
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_rejects_empty_cells() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.cells.clear();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_warmup() {
        let mut cfg = ClusterConfig::edge_default();
        cfg.warmup_frac = 1.0;
        assert!(cfg.validate().is_err());
    }
}
