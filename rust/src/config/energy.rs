//! Energy-model configuration: per-device joules/token and battery state
//! for the cluster DES.
//!
//! An [`EnergyConfig`] describes *what serving costs* in joules — a compute
//! cost per token, radio TX/RX costs per token (scaled by the device's
//! current bandwidth share: a thin slice means longer airtime and more
//! radio energy), an optional battery capacity (0 = mains powered), idle
//! draw, and an optional recharge episode length (0 = depletion is
//! permanent death). Heterogeneous fleets come from [`EnergyClass`]
//! multipliers assigned round-robin over a cell's devices
//! (`device k → classes[k % len]`).
//!
//! The config layer only holds parameters and validates them;
//! `cluster::energy` compiles a config into per-cell [`CellEnergy`]
//! accounting state. An all-default config is *empty*
//! ([`EnergyConfig::is_empty`]) and the DES monomorphizes it away entirely,
//! so the zero-energy hot path is bit-equal to the pre-energy engine —
//! the same discipline as `NullProbe` and empty fault plans.
//!
//! [`CellEnergy`]: crate::cluster::energy::CellEnergy

use crate::util::Json;
use anyhow::Result;

/// One device class in a heterogeneous fleet: multipliers over the base
/// per-token costs and battery capacity. Device `k` of a cell gets class
/// `k % classes.len()`; an empty class list means a uniform fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyClass {
    /// Human-readable name ("jetson", "phone", …).
    pub name: String,
    /// Multiplier on `compute_j_per_token`.
    pub compute_mult: f64,
    /// Multiplier on `tx_j_per_token` + `rx_j_per_token`.
    pub radio_mult: f64,
    /// Multiplier on `battery_j`.
    pub battery_mult: f64,
}

impl EnergyClass {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("compute_mult", Json::Num(self.compute_mult)),
            ("radio_mult", Json::Num(self.radio_mult)),
            ("battery_mult", Json::Num(self.battery_mult)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let opt = |key: &str| -> Result<f64> {
            match j.opt(key) {
                Some(v) => v.as_f64(),
                None => Ok(1.0),
            }
        };
        Ok(EnergyClass {
            name: j.get("name")?.as_str()?.to_string(),
            compute_mult: opt("compute_mult")?,
            radio_mult: opt("radio_mult")?,
            battery_mult: opt("battery_mult")?,
        })
    }
}

/// Per-device energy model parameters.
///
/// All-zero defaults mean "no energy model": the DES monomorphizes the
/// accounting away and stays bit-equal to the pre-energy engine. Costs are
/// per *token*; radio cost scales with the reciprocal of the device's
/// bandwidth share relative to the cell's uniform split (a device holding
/// half the uniform share pays twice the radio energy per token).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyConfig {
    /// Compute energy per served token, joules. 0 disables compute cost.
    pub compute_j_per_token: f64,
    /// Uplink (device→BS) radio energy per token at the uniform bandwidth
    /// share, joules.
    pub tx_j_per_token: f64,
    /// Downlink (BS→device) radio energy per token at the uniform bandwidth
    /// share, joules.
    pub rx_j_per_token: f64,
    /// Battery capacity per device, joules. 0 = mains powered (accounting
    /// only, no depletion, no churn).
    pub battery_j: f64,
    /// Idle draw per device, watts (debited over sim time up to the last
    /// completed work instant).
    pub idle_w: f64,
    /// Recharge episode length after depletion, seconds. 0 = depletion is
    /// permanent (the device never comes back).
    pub recharge_s: f64,
    /// Device classes (round-robin over each cell's devices). Empty =
    /// uniform fleet with unit multipliers.
    pub classes: Vec<EnergyClass>,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            compute_j_per_token: 0.0,
            tx_j_per_token: 0.0,
            rx_j_per_token: 0.0,
            battery_j: 0.0,
            idle_w: 0.0,
            recharge_s: 0.0,
            classes: Vec::new(),
        }
    }
}

impl EnergyConfig {
    /// True when the model debits nothing: the DES uses this to
    /// monomorphize the energy machinery away entirely.
    pub fn is_empty(&self) -> bool {
        self.compute_j_per_token == 0.0
            && self.tx_j_per_token == 0.0
            && self.rx_j_per_token == 0.0
            && self.idle_w == 0.0
    }

    /// True when batteries can actually deplete (and hence emit crashes):
    /// this arms the DES fault machinery even with no fault plan.
    pub fn churn_possible(&self) -> bool {
        !self.is_empty() && self.battery_j > 0.0
    }

    /// Named class presets for the `device_class` experiment axis.
    ///
    /// `uniform` is a single explicit unit class (distinct from the empty
    /// default, so the axis is never a silent no-op); `mixed` is the
    /// paper-testbed-flavoured Jetson-vs-phone split: Jetson-class devices
    /// serve at the base joule cost on a double battery, phone-class
    /// devices burn 2.5x compute / 1.5x radio joules per token on a
    /// single battery.
    pub fn class_preset(name: &str) -> Result<Vec<EnergyClass>> {
        match name {
            "uniform" => Ok(vec![EnergyClass {
                name: "uniform".to_string(),
                compute_mult: 1.0,
                radio_mult: 1.0,
                battery_mult: 1.0,
            }]),
            "mixed" => Ok(vec![
                EnergyClass {
                    name: "jetson".to_string(),
                    compute_mult: 1.0,
                    radio_mult: 1.0,
                    battery_mult: 2.0,
                },
                EnergyClass {
                    name: "phone".to_string(),
                    compute_mult: 2.5,
                    radio_mult: 1.5,
                    battery_mult: 1.0,
                },
            ]),
            other => anyhow::bail!(
                "unknown device_class preset '{other}' (expected uniform|mixed)"
            ),
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("compute_j_per_token", self.compute_j_per_token),
            ("tx_j_per_token", self.tx_j_per_token),
            ("rx_j_per_token", self.rx_j_per_token),
            ("battery_j", self.battery_j),
            ("idle_w", self.idle_w),
            ("recharge_s", self.recharge_s),
        ] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "energy.{name} must be finite and >= 0, got {v}"
            );
        }
        if self.recharge_s > 0.0 {
            anyhow::ensure!(
                self.battery_j > 0.0,
                "energy.recharge_s is set but energy.battery_j is 0 (mains-powered \
                 devices never deplete, so there is nothing to recharge)"
            );
        }
        for (i, c) in self.classes.iter().enumerate() {
            anyhow::ensure!(
                !c.name.is_empty(),
                "energy.classes[{i}].name must be non-empty"
            );
            for (field, v) in [
                ("compute_mult", c.compute_mult),
                ("radio_mult", c.radio_mult),
                ("battery_mult", c.battery_mult),
            ] {
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "energy.classes[{i}].{field} must be finite and >= 0, got {v}"
                );
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("compute_j_per_token", Json::Num(self.compute_j_per_token)),
            ("tx_j_per_token", Json::Num(self.tx_j_per_token)),
            ("rx_j_per_token", Json::Num(self.rx_j_per_token)),
            ("battery_j", Json::Num(self.battery_j)),
            ("idle_w", Json::Num(self.idle_w)),
            ("recharge_s", Json::Num(self.recharge_s)),
            (
                "classes",
                Json::Arr(self.classes.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = EnergyConfig::default();
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            match j.opt(key) {
                Some(v) => v.as_f64(),
                None => Ok(default),
            }
        };
        let classes = match j.opt("classes") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(EnergyClass::from_json)
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(EnergyConfig {
            compute_j_per_token: opt_f64("compute_j_per_token", d.compute_j_per_token)?,
            tx_j_per_token: opt_f64("tx_j_per_token", d.tx_j_per_token)?,
            rx_j_per_token: opt_f64("rx_j_per_token", d.rx_j_per_token)?,
            battery_j: opt_f64("battery_j", d.battery_j)?,
            idle_w: opt_f64("idle_w", d.idle_w)?,
            recharge_s: opt_f64("recharge_s", d.recharge_s)?,
            classes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_empty_and_valid() {
        let e = EnergyConfig::default();
        assert!(e.is_empty());
        assert!(!e.churn_possible());
        e.validate().unwrap();
    }

    #[test]
    fn single_knob_configs_validate() {
        let mut e = EnergyConfig::default();
        e.compute_j_per_token = 0.01;
        e.validate().unwrap();
        assert!(!e.is_empty());
        assert!(!e.churn_possible()); // no battery → accounting only

        let mut e = EnergyConfig::default();
        e.tx_j_per_token = 0.002;
        e.battery_j = 50.0;
        e.validate().unwrap();
        assert!(e.churn_possible());
    }

    #[test]
    fn battery_alone_is_inert() {
        // A battery with nothing debiting it never depletes.
        let mut e = EnergyConfig::default();
        e.battery_j = 100.0;
        e.validate().unwrap();
        assert!(e.is_empty());
        assert!(!e.churn_possible());
    }

    #[test]
    fn nan_and_negative_rejected_with_field_names() {
        let mut e = EnergyConfig::default();
        e.compute_j_per_token = f64::NAN;
        let err = e.validate().unwrap_err();
        assert!(err.to_string().contains("compute_j_per_token"), "{err}");

        let mut e = EnergyConfig::default();
        e.battery_j = -1.0;
        let err = e.validate().unwrap_err();
        assert!(err.to_string().contains("battery_j"), "{err}");

        let mut e = EnergyConfig::default();
        e.idle_w = f64::INFINITY;
        let err = e.validate().unwrap_err();
        assert!(err.to_string().contains("idle_w"), "{err}");
    }

    #[test]
    fn recharge_without_battery_rejected() {
        let mut e = EnergyConfig::default();
        e.compute_j_per_token = 0.01;
        e.recharge_s = 5.0;
        let err = e.validate().unwrap_err();
        assert!(err.to_string().contains("recharge_s"), "{err}");
    }

    #[test]
    fn bad_class_rejected_with_index() {
        let mut e = EnergyConfig::default();
        e.classes = EnergyConfig::class_preset("mixed").unwrap();
        e.classes[1].radio_mult = -2.0;
        let err = e.validate().unwrap_err();
        assert!(err.to_string().contains("classes[1].radio_mult"), "{err}");
    }

    #[test]
    fn class_presets() {
        let u = EnergyConfig::class_preset("uniform").unwrap();
        assert_eq!(u.len(), 1);
        assert_eq!(u[0].compute_mult, 1.0);
        let m = EnergyConfig::class_preset("mixed").unwrap();
        assert_eq!(m.len(), 2);
        assert!(m[1].compute_mult > m[0].compute_mult);
        assert!(EnergyConfig::class_preset("quantum").is_err());
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let mut e = EnergyConfig::default();
        e.compute_j_per_token = 0.02;
        e.tx_j_per_token = 0.004;
        e.rx_j_per_token = 0.001;
        e.battery_j = 120.0;
        e.idle_w = 0.25;
        e.recharge_s = 4.0;
        e.classes = EnergyConfig::class_preset("mixed").unwrap();
        let text = e.to_json().to_string();
        let back = EnergyConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn from_json_defaults_missing_fields() {
        let back =
            EnergyConfig::from_json(&Json::parse(r#"{"compute_j_per_token": 0.5}"#).unwrap())
                .unwrap();
        assert_eq!(back.compute_j_per_token, 0.5);
        assert_eq!(back.battery_j, 0.0);
        assert!(back.classes.is_empty());
    }
}
