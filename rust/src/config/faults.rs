//! Fault-plan configuration: deterministic, seeded failure processes for
//! the cluster DES.
//!
//! A [`FaultConfig`] describes *what can go wrong* during a run — stochastic
//! device crash/recover cycles (MTTF/MTTR), per-device straggler episodes
//! that multiply service time, link-quality dips that inflate `t_per_token`,
//! backhaul outages, and explicitly scheduled one-off events (including
//! correlated whole-cell events). The config layer only holds parameters and
//! validates them; `cluster::faults` compiles a config into concrete
//! per-cell-lane `FaultEvent`s.
//!
//! An all-default config is *empty* ([`FaultConfig::is_empty`]) and the DES
//! monomorphizes it away entirely, so the zero-fault hot path is bit-equal
//! to the pre-fault engine. Dependent parameters (durations, multipliers,
//! MTTR) default to inert non-zero values so sweeping a single knob — e.g.
//! just `mttf_s` via the `mttf` axis — produces a valid config.

use crate::util::Json;
use anyhow::Result;

/// Kind of a scheduled fault entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Device goes offline at `at_s`; recovers after `duration_s`
    /// (a zero duration means the crash is permanent).
    Crash,
    /// Device service time is multiplied by `mult` for `duration_s`.
    Straggle,
    /// Device link degrades: `t_per_token` effectively multiplied by `mult`
    /// for `duration_s` (modelled as a service-time multiplier on that
    /// device, composing with straggler episodes).
    LinkDip,
    /// Backhaul for the cell is multiplied by `mult` for `duration_s`
    /// (`mult == 0.0` means a full outage: no cross-cell borrowing).
    Backhaul,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Straggle => "straggle",
            FaultKind::LinkDip => "link_dip",
            FaultKind::Backhaul => "backhaul",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "crash" => Ok(FaultKind::Crash),
            "straggle" => Ok(FaultKind::Straggle),
            "link_dip" => Ok(FaultKind::LinkDip),
            "backhaul" => Ok(FaultKind::Backhaul),
            other => anyhow::bail!(
                "unknown fault kind '{other}' (expected crash|straggle|link_dip|backhaul)"
            ),
        }
    }
}

/// One explicitly scheduled fault.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledFault {
    /// Sim time the fault fires, seconds.
    pub at_s: f64,
    /// Cell the fault hits.
    pub cell: usize,
    /// Device within the cell; `None` means the whole cell (correlated
    /// event — expanded over every device in device order). Ignored for
    /// `Backhaul`, which is per-cell by nature.
    pub device: Option<usize>,
    pub kind: FaultKind,
    /// How long the fault lasts, seconds. For `Crash`, zero means permanent.
    pub duration_s: f64,
    /// Multiplier for `Straggle`/`LinkDip` (>= 1.0) and `Backhaul` (>= 0.0,
    /// 0.0 = outage). Ignored for `Crash`.
    pub mult: f64,
}

impl ScheduledFault {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("at_s", Json::Num(self.at_s)),
            ("cell", Json::Num(self.cell as f64)),
        ];
        if let Some(d) = self.device {
            fields.push(("device", Json::Num(d as f64)));
        }
        fields.extend([
            ("kind", Json::str(self.kind.as_str())),
            ("duration_s", Json::Num(self.duration_s)),
            ("mult", Json::Num(self.mult)),
        ]);
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let kind = FaultKind::parse(j.get("kind")?.as_str()?)?;
        let device = match j.opt("device") {
            Some(v) => Some(v.as_usize()?),
            None => None,
        };
        Ok(ScheduledFault {
            at_s: j.get("at_s")?.as_f64()?,
            cell: j.get("cell")?.as_usize()?,
            device,
            kind,
            duration_s: match j.opt("duration_s") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            },
            mult: match j.opt("mult") {
                Some(v) => v.as_f64()?,
                None => 1.0,
            },
        })
    }
}

/// Deterministic fault plan parameters.
///
/// Every stochastic process is gated on its MTBF/MTTF being positive; the
/// dependent knobs (duration, multiplier, MTTR) carry inert defaults so a
/// config that sets only one rate field still validates.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Mean time to failure per device, seconds. 0 disables crashes.
    pub mttf_s: f64,
    /// Mean time to recovery per device, seconds. Must be > 0 when
    /// `mttf_s > 0` — a zero MTTR would re-arm a crashed device instantly.
    pub mttr_s: f64,
    /// Mean time between straggler episodes per device, seconds. 0 disables.
    pub straggler_mtbf_s: f64,
    /// Straggler episode length, seconds.
    pub straggler_duration_s: f64,
    /// Service-time multiplier during a straggler episode (>= 1.0).
    pub straggler_mult: f64,
    /// Mean time between link-quality dips per device, seconds. 0 disables.
    pub link_dip_mtbf_s: f64,
    /// Link-dip episode length, seconds.
    pub link_dip_duration_s: f64,
    /// Effective `t_per_token` multiplier during a dip (>= 1.0).
    pub link_dip_mult: f64,
    /// Mean time between backhaul outages per cell, seconds. 0 disables.
    pub backhaul_outage_mtbf_s: f64,
    /// Backhaul outage length, seconds.
    pub backhaul_outage_duration_s: f64,
    /// Explicitly scheduled faults (applied after the stochastic streams,
    /// in config order).
    pub scheduled: Vec<ScheduledFault>,
    /// Horizon for stochastic fault generation, seconds of sim time.
    pub horizon_s: f64,
    /// Seed for the fault-plan RNG streams (independent of the sim seed).
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            mttf_s: 0.0,
            mttr_s: 1.0,
            straggler_mtbf_s: 0.0,
            straggler_duration_s: 1.0,
            straggler_mult: 4.0,
            link_dip_mtbf_s: 0.0,
            link_dip_duration_s: 1.0,
            link_dip_mult: 2.0,
            backhaul_outage_mtbf_s: 0.0,
            backhaul_outage_duration_s: 1.0,
            scheduled: Vec::new(),
            horizon_s: 60.0,
            seed: 0x5EED,
        }
    }
}

impl FaultConfig {
    /// True when the plan injects nothing: the DES uses this to
    /// monomorphize the fault machinery away entirely.
    pub fn is_empty(&self) -> bool {
        self.mttf_s == 0.0
            && self.straggler_mtbf_s == 0.0
            && self.link_dip_mtbf_s == 0.0
            && self.backhaul_outage_mtbf_s == 0.0
            && self.scheduled.is_empty()
    }

    /// Validate against the cluster shape (`device_counts[cell]` = number of
    /// devices in that cell).
    pub fn validate(&self, device_counts: &[usize]) -> Result<()> {
        for (name, v) in [
            ("mttf_s", self.mttf_s),
            ("mttr_s", self.mttr_s),
            ("straggler_mtbf_s", self.straggler_mtbf_s),
            ("straggler_duration_s", self.straggler_duration_s),
            ("link_dip_mtbf_s", self.link_dip_mtbf_s),
            ("link_dip_duration_s", self.link_dip_duration_s),
            ("backhaul_outage_mtbf_s", self.backhaul_outage_mtbf_s),
            ("backhaul_outage_duration_s", self.backhaul_outage_duration_s),
            ("horizon_s", self.horizon_s),
        ] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "faults.{name} must be finite and >= 0, got {v}"
            );
        }
        anyhow::ensure!(
            self.straggler_mult.is_finite() && self.link_dip_mult.is_finite(),
            "faults straggler_mult/link_dip_mult must be finite"
        );
        if self.mttf_s > 0.0 {
            anyhow::ensure!(
                self.mttr_s > 0.0,
                "faults.mttr_s must be > 0 when mttf_s > 0 (a zero MTTR recovers \
                 devices instantly); got mttr_s = {}",
                self.mttr_s
            );
        }
        if self.straggler_mtbf_s > 0.0 {
            anyhow::ensure!(
                self.straggler_duration_s > 0.0,
                "faults.straggler_duration_s must be > 0 when straggler_mtbf_s > 0"
            );
            anyhow::ensure!(
                self.straggler_mult >= 1.0,
                "faults.straggler_mult must be >= 1.0 (it multiplies service time), got {}",
                self.straggler_mult
            );
        }
        if self.link_dip_mtbf_s > 0.0 {
            anyhow::ensure!(
                self.link_dip_duration_s > 0.0,
                "faults.link_dip_duration_s must be > 0 when link_dip_mtbf_s > 0"
            );
            anyhow::ensure!(
                self.link_dip_mult >= 1.0,
                "faults.link_dip_mult must be >= 1.0 (it inflates t_per_token), got {}",
                self.link_dip_mult
            );
        }
        if self.backhaul_outage_mtbf_s > 0.0 {
            anyhow::ensure!(
                self.backhaul_outage_duration_s > 0.0,
                "faults.backhaul_outage_duration_s must be > 0 when backhaul_outage_mtbf_s > 0"
            );
        }
        let any_stochastic = self.mttf_s > 0.0
            || self.straggler_mtbf_s > 0.0
            || self.link_dip_mtbf_s > 0.0
            || self.backhaul_outage_mtbf_s > 0.0;
        if any_stochastic {
            anyhow::ensure!(
                self.horizon_s > 0.0,
                "faults.horizon_s must be > 0 when any stochastic fault process is enabled"
            );
        }
        for (i, s) in self.scheduled.iter().enumerate() {
            anyhow::ensure!(
                s.at_s.is_finite() && s.at_s >= 0.0,
                "faults.scheduled[{i}].at_s must be finite and >= 0, got {}",
                s.at_s
            );
            anyhow::ensure!(
                s.cell < device_counts.len(),
                "faults.scheduled[{i}].cell = {} out of range ({} cells)",
                s.cell,
                device_counts.len()
            );
            if let Some(d) = s.device {
                anyhow::ensure!(
                    d < device_counts[s.cell],
                    "faults.scheduled[{i}].device = {} out of range (cell {} has {} devices)",
                    d,
                    s.cell,
                    device_counts[s.cell]
                );
            }
            anyhow::ensure!(
                s.duration_s.is_finite() && s.duration_s >= 0.0,
                "faults.scheduled[{i}].duration_s must be finite and >= 0, got {}",
                s.duration_s
            );
            match s.kind {
                FaultKind::Straggle | FaultKind::LinkDip => anyhow::ensure!(
                    s.mult.is_finite() && s.mult >= 1.0,
                    "faults.scheduled[{i}].mult must be >= 1.0 for {}, got {}",
                    s.kind.as_str(),
                    s.mult
                ),
                FaultKind::Backhaul => anyhow::ensure!(
                    s.mult.is_finite() && s.mult >= 0.0,
                    "faults.scheduled[{i}].mult must be >= 0.0 for backhaul, got {}",
                    s.mult
                ),
                FaultKind::Crash => {}
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mttf_s", Json::Num(self.mttf_s)),
            ("mttr_s", Json::Num(self.mttr_s)),
            ("straggler_mtbf_s", Json::Num(self.straggler_mtbf_s)),
            ("straggler_duration_s", Json::Num(self.straggler_duration_s)),
            ("straggler_mult", Json::Num(self.straggler_mult)),
            ("link_dip_mtbf_s", Json::Num(self.link_dip_mtbf_s)),
            ("link_dip_duration_s", Json::Num(self.link_dip_duration_s)),
            ("link_dip_mult", Json::Num(self.link_dip_mult)),
            ("backhaul_outage_mtbf_s", Json::Num(self.backhaul_outage_mtbf_s)),
            (
                "backhaul_outage_duration_s",
                Json::Num(self.backhaul_outage_duration_s),
            ),
            (
                "scheduled",
                Json::Arr(self.scheduled.iter().map(|s| s.to_json()).collect()),
            ),
            ("horizon_s", Json::Num(self.horizon_s)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = FaultConfig::default();
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            match j.opt(key) {
                Some(v) => v.as_f64(),
                None => Ok(default),
            }
        };
        let scheduled = match j.opt("scheduled") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(ScheduledFault::from_json)
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(FaultConfig {
            mttf_s: opt_f64("mttf_s", d.mttf_s)?,
            mttr_s: opt_f64("mttr_s", d.mttr_s)?,
            straggler_mtbf_s: opt_f64("straggler_mtbf_s", d.straggler_mtbf_s)?,
            straggler_duration_s: opt_f64("straggler_duration_s", d.straggler_duration_s)?,
            straggler_mult: opt_f64("straggler_mult", d.straggler_mult)?,
            link_dip_mtbf_s: opt_f64("link_dip_mtbf_s", d.link_dip_mtbf_s)?,
            link_dip_duration_s: opt_f64("link_dip_duration_s", d.link_dip_duration_s)?,
            link_dip_mult: opt_f64("link_dip_mult", d.link_dip_mult)?,
            backhaul_outage_mtbf_s: opt_f64("backhaul_outage_mtbf_s", d.backhaul_outage_mtbf_s)?,
            backhaul_outage_duration_s: opt_f64(
                "backhaul_outage_duration_s",
                d.backhaul_outage_duration_s,
            )?,
            scheduled,
            horizon_s: opt_f64("horizon_s", d.horizon_s)?,
            seed: match j.opt("seed") {
                Some(v) => v.as_u64()?,
                None => d.seed,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_empty_and_valid() {
        let f = FaultConfig::default();
        assert!(f.is_empty());
        f.validate(&[4, 4]).unwrap();
    }

    #[test]
    fn single_knob_configs_validate() {
        // Each rate knob alone must validate thanks to inert defaults.
        let mut f = FaultConfig::default();
        f.mttf_s = 50.0;
        f.validate(&[4]).unwrap();
        assert!(!f.is_empty());

        let mut f = FaultConfig::default();
        f.straggler_mtbf_s = 20.0;
        f.validate(&[4]).unwrap();
        assert!(!f.is_empty());

        let mut f = FaultConfig::default();
        f.link_dip_mtbf_s = 20.0;
        f.validate(&[4]).unwrap();

        let mut f = FaultConfig::default();
        f.backhaul_outage_mtbf_s = 30.0;
        f.validate(&[4]).unwrap();
    }

    #[test]
    fn zero_mttr_rejected_when_crashes_enabled() {
        let mut f = FaultConfig::default();
        f.mttf_s = 10.0;
        f.mttr_s = 0.0;
        let err = f.validate(&[4]).unwrap_err();
        assert!(err.to_string().contains("mttr_s"), "{err}");
    }

    #[test]
    fn nan_and_negative_rejected() {
        let mut f = FaultConfig::default();
        f.mttf_s = f64::NAN;
        assert!(f.validate(&[4]).is_err());

        let mut f = FaultConfig::default();
        f.straggler_mtbf_s = -1.0;
        assert!(f.validate(&[4]).is_err());

        let mut f = FaultConfig::default();
        f.straggler_mtbf_s = 10.0;
        f.straggler_mult = 0.5;
        let err = f.validate(&[4]).unwrap_err();
        assert!(err.to_string().contains("straggler_mult"), "{err}");
    }

    #[test]
    fn scheduled_bounds_checked() {
        let mut f = FaultConfig::default();
        f.scheduled.push(ScheduledFault {
            at_s: 1.0,
            cell: 2,
            device: None,
            kind: FaultKind::Crash,
            duration_s: 0.0,
            mult: 1.0,
        });
        let err = f.validate(&[4, 4]).unwrap_err();
        assert!(err.to_string().contains("cell"), "{err}");

        f.scheduled[0].cell = 0;
        f.scheduled[0].device = Some(9);
        let err = f.validate(&[4, 4]).unwrap_err();
        assert!(err.to_string().contains("device"), "{err}");

        f.scheduled[0].device = Some(3);
        f.validate(&[4, 4]).unwrap();
        assert!(!f.is_empty());
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let mut f = FaultConfig::default();
        f.mttf_s = 40.0;
        f.mttr_s = 3.0;
        f.straggler_mtbf_s = 12.0;
        f.straggler_mult = 6.0;
        f.seed = 99;
        f.scheduled.push(ScheduledFault {
            at_s: 2.5,
            cell: 1,
            device: Some(0),
            kind: FaultKind::Straggle,
            duration_s: 4.0,
            mult: 8.0,
        });
        f.scheduled.push(ScheduledFault {
            at_s: 5.0,
            cell: 0,
            device: None,
            kind: FaultKind::Crash,
            duration_s: 0.0,
            mult: 1.0,
        });
        let text = f.to_json().to_string();
        let back = FaultConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn fault_kind_parse_round_trips() {
        for k in [
            FaultKind::Crash,
            FaultKind::Straggle,
            FaultKind::LinkDip,
            FaultKind::Backhaul,
        ] {
            assert_eq!(FaultKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(FaultKind::parse("meltdown").is_err());
    }
}
