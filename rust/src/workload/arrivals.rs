//! Open-loop request arrival processes for the cluster simulator.
//!
//! The paper evaluates one batch at a time; serving "heavy traffic from
//! millions of users" means requests arrive *while others are in flight*.
//! This module generates those arrival streams: a seeded Poisson process
//! (exponential inter-arrival gaps at a target rate) and trace replay
//! (prompt sizes taken from a recorded [`Trace`], evenly paced), both
//! yielding the `(time, tokens)` pairs [`crate::cluster::ClusterSim`]
//! consumes.

use super::trace::Trace;
use super::Benchmark;
use crate::util::Rng;

/// One request entering the system.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Arrival instant in seconds from simulation start.
    pub time_s: f64,
    /// Prompt length in tokens.
    pub tokens: usize,
}

/// An open-loop arrival process.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_rps` requests/second; prompt lengths
    /// vary ±30% (uniform) around the benchmark mean, matching
    /// [`crate::workload::WorkloadGen::batch`]'s calibration.
    Poisson { rate_rps: f64 },
    /// Replay an explicit arrival sequence (times must be non-decreasing).
    Replay { arrivals: Vec<Arrival> },
}

impl ArrivalProcess {
    /// Trace-driven arrivals: prompt sizes from the recorded batches (in
    /// record order, flattened), paced deterministically at `rate_rps`.
    pub fn from_trace(trace: &Trace, rate_rps: f64) -> Self {
        assert!(rate_rps > 0.0, "rate must be positive");
        let gap = 1.0 / rate_rps;
        let arrivals = trace
            .batches
            .iter()
            .flat_map(|b| b.prompt_lens.iter().copied())
            .enumerate()
            .map(|(i, tokens)| Arrival {
                time_s: i as f64 * gap,
                tokens: tokens.max(1),
            })
            .collect();
        ArrivalProcess::Replay { arrivals }
    }

    /// Materialise the first `n_requests` arrivals. Deterministic given
    /// `seed`; the returned list is sorted by time.
    pub fn generate(&self, n_requests: usize, bench: Benchmark, seed: u64) -> Vec<Arrival> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                assert!(*rate_rps > 0.0, "rate must be positive");
                let mut rng = Rng::seed_from_u64(seed ^ 0xa881_7a1e);
                let mean = bench.mean_prompt_tokens() as f64;
                let mut t = 0.0f64;
                (0..n_requests)
                    .map(|_| {
                        // Exponential gap via inverse CDF; u in [0,1) so
                        // 1-u in (0,1] and ln is finite.
                        let u = rng.f64();
                        t += -(1.0 - u).ln() / rate_rps;
                        let f = rng.range_f64(0.7, 1.3);
                        Arrival {
                            time_s: t,
                            tokens: ((mean * f).round() as usize).max(1),
                        }
                    })
                    .collect()
            }
            ArrivalProcess::Replay { arrivals } => {
                // Fail fast here rather than as a cryptic virtual-time
                // panic deep inside a simulator run.
                for a in arrivals.iter().take(n_requests) {
                    assert!(
                        a.time_s.is_finite() && a.time_s >= 0.0,
                        "replay arrival times must be finite and non-negative, got {}",
                        a.time_s
                    );
                }
                let mut out: Vec<Arrival> =
                    arrivals.iter().take(n_requests).cloned().collect();
                // Times are validated finite above, so the total order
                // agrees with the partial one — and cannot panic.
                out.sort_by(|a, b| a.time_s.total_cmp(&b.time_s));
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadGen;

    #[test]
    fn poisson_rate_is_calibrated() {
        let p = ArrivalProcess::Poisson { rate_rps: 4.0 };
        let arr = p.generate(4000, Benchmark::Piqa, 0);
        assert_eq!(arr.len(), 4000);
        let horizon = arr.last().unwrap().time_s;
        let measured = arr.len() as f64 / horizon;
        assert!(
            (measured - 4.0).abs() / 4.0 < 0.1,
            "measured rate {measured} vs 4.0"
        );
        // times strictly increasing (exponential gaps are a.s. positive)
        for w in arr.windows(2) {
            assert!(w[1].time_s > w[0].time_s);
        }
    }

    #[test]
    fn poisson_tokens_match_benchmark_calibration() {
        let p = ArrivalProcess::Poisson { rate_rps: 1.0 };
        let arr = p.generate(2000, Benchmark::Boolq, 1);
        let mean = arr.iter().map(|a| a.tokens as f64).sum::<f64>() / arr.len() as f64;
        let nominal = Benchmark::Boolq.mean_prompt_tokens() as f64;
        assert!(
            (mean - nominal).abs() / nominal < 0.05,
            "mean tokens {mean} vs nominal {nominal}"
        );
        assert!(arr.iter().all(|a| a.tokens >= 1));
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let p = ArrivalProcess::Poisson { rate_rps: 2.0 };
        let a = p.generate(50, Benchmark::Mbpp, 7);
        let b = p.generate(50, Benchmark::Mbpp, 7);
        assert_eq!(a, b);
        let c = p.generate(50, Benchmark::Mbpp, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn trace_replay_preserves_prompt_sizes() {
        let mut gen = WorkloadGen::new(0, 2048);
        let mut trace = Trace::new();
        trace.record(gen.batch(Benchmark::Gsm8k));
        let p = ArrivalProcess::from_trace(&trace, 2.0);
        let arr = p.generate(100, Benchmark::Gsm8k, 0);
        let want: Vec<usize> = trace.batches[0].prompt_lens.clone();
        assert_eq!(arr.len(), want.len().min(100));
        for (a, &w) in arr.iter().zip(&want) {
            assert_eq!(a.tokens, w);
        }
        // evenly paced at 1/rate
        assert!((arr[1].time_s - arr[0].time_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn replay_truncates_to_n() {
        let arrivals = vec![
            Arrival { time_s: 0.0, tokens: 5 },
            Arrival { time_s: 1.0, tokens: 6 },
            Arrival { time_s: 2.0, tokens: 7 },
        ];
        let p = ArrivalProcess::Replay { arrivals };
        assert_eq!(p.generate(2, Benchmark::Piqa, 0).len(), 2);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn replay_rejects_negative_times_up_front() {
        let arrivals = vec![Arrival { time_s: -0.1, tokens: 5 }];
        let _ = ArrivalProcess::Replay { arrivals }.generate(1, Benchmark::Piqa, 0);
    }
}
