//! Workload trace record/replay: persist generated batches as JSON so a
//! run can be replayed bit-identically (e.g. to compare policies on the
//! exact same token stream, as the paper's ablations require).

use super::Batch;
use crate::util::Json;
use std::path::Path;

/// A recorded sequence of batches.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub batches: Vec<Batch>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, batch: Batch) {
        self.batches.push(batch);
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let j = Json::obj(vec![(
            "batches",
            Json::Arr(self.batches.iter().map(|b| b.to_json()).collect()),
        )]);
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        Ok(Self {
            batches: j
                .get("batches")?
                .as_arr()?
                .iter()
                .map(Batch::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
        })
    }

    pub fn total_tokens(&self) -> usize {
        self.batches.iter().map(|b| b.total_tokens()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Benchmark, WorkloadGen};

    #[test]
    fn save_load_roundtrip() {
        let mut gen = WorkloadGen::new(0, 2048);
        let mut trace = Trace::new();
        trace.record(gen.batch(Benchmark::Piqa));
        trace.record(gen.batch(Benchmark::Mbpp));
        let dir = crate::util::temp_dir("trace");
        let path = dir.join("trace.json");
        trace.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.batches.len(), 2);
        assert_eq!(back.total_tokens(), trace.total_tokens());
        assert_eq!(back.batches[0].prompt_lens, trace.batches[0].prompt_lens);
        assert_eq!(back.batches[1].token_ids, trace.batches[1].token_ids);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Trace::load(Path::new("/nonexistent/trace.json")).is_err());
    }
}
