//! Workload generation: the paper's eight evaluation benchmarks as
//! synthetic batch generators, plus trace record/replay.
//!
//! The paper evaluates on MMLU, PIQA, ARC-Easy, ARC-Challenge, HumanEval,
//! GSM-8K, BoolQ and MBPP via OpenCompass. Latency results depend on the
//! *token volume per batch* and its routing, not on prompt text, so each
//! benchmark is modelled as a distribution of prompt lengths whose batch
//! totals are calibrated so the Mixtral-based baseline lands at the
//! magnitude of paper Table II (see EXPERIMENTS.md for the comparison).
//! For execution mode the generator also emits synthetic token ids in the
//! artifact vocabulary.

pub mod arrivals;
pub mod trace;

pub use arrivals::{Arrival, ArrivalProcess};

use crate::util::{Json, Rng};

/// The paper's eight evaluation benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    Mmlu,
    Piqa,
    ArcEasy,
    ArcChallenge,
    Humaneval,
    Gsm8k,
    Boolq,
    Mbpp,
}

impl Benchmark {
    pub const ALL: [Benchmark; 8] = [
        Benchmark::Mmlu,
        Benchmark::Piqa,
        Benchmark::ArcEasy,
        Benchmark::ArcChallenge,
        Benchmark::Humaneval,
        Benchmark::Gsm8k,
        Benchmark::Boolq,
        Benchmark::Mbpp,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Mmlu => "MMLU",
            Benchmark::Piqa => "PIQA",
            Benchmark::ArcEasy => "ARC-E",
            Benchmark::ArcChallenge => "ARC-C",
            Benchmark::Humaneval => "Humaneval",
            Benchmark::Gsm8k => "GSM-8K",
            Benchmark::Boolq => "BoolQ",
            Benchmark::Mbpp => "MBPP",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Benchmark::ALL
            .iter()
            .copied()
            .find(|b| b.name().eq_ignore_ascii_case(s))
    }

    /// Prompts per evaluation batch (OpenCompass-style batching; MCQ
    /// benchmarks batch many short prompts, generation benchmarks few).
    pub fn prompts_per_batch(&self) -> usize {
        match self {
            Benchmark::Mmlu => 64,
            Benchmark::Piqa => 64,
            Benchmark::ArcEasy => 64,
            Benchmark::ArcChallenge => 64,
            Benchmark::Humaneval => 1,
            Benchmark::Gsm8k => 3,
            Benchmark::Boolq => 64,
            Benchmark::Mbpp => 2,
        }
    }

    /// Mean tokens per prompt. Chosen so `prompts × mean_tokens`
    /// reproduces the Table-II batch volumes (MMLU's 5-shot prompts are
    /// long; ARC/PIQA short; see module docs).
    pub fn mean_prompt_tokens(&self) -> usize {
        match self {
            Benchmark::Mmlu => 420,
            Benchmark::Piqa => 52,
            Benchmark::ArcEasy => 51,
            Benchmark::ArcChallenge => 56,
            Benchmark::Humaneval => 50,
            Benchmark::Gsm8k => 50,
            Benchmark::Boolq => 154,
            Benchmark::Mbpp => 38,
        }
    }

    /// Nominal tokens per batch.
    pub fn nominal_batch_tokens(&self) -> usize {
        self.prompts_per_batch() * self.mean_prompt_tokens()
    }
}

/// One generated batch: prompt lengths plus (optionally) token ids.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub benchmark: Benchmark,
    /// Token count per prompt.
    pub prompt_lens: Vec<usize>,
    /// Synthetic token ids (length = total tokens), for execution mode.
    pub token_ids: Vec<i32>,
}

impl Batch {
    pub fn total_tokens(&self) -> usize {
        self.prompt_lens.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("benchmark", Json::str(self.benchmark.name())),
            ("prompt_lens", Json::arr_usize(&self.prompt_lens)),
            ("token_ids", Json::arr_i32(&self.token_ids)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let name = j.get("benchmark")?.as_str()?;
        Ok(Self {
            benchmark: Benchmark::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{name}'"))?,
            prompt_lens: j.get("prompt_lens")?.as_usize_vec()?,
            token_ids: j
                .get("token_ids")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_f64()? as i32))
                .collect::<anyhow::Result<Vec<i32>>>()?,
        })
    }
}

/// Seeded batch generator.
pub struct WorkloadGen {
    rng: Rng,
    vocab: i32,
}

impl WorkloadGen {
    pub fn new(seed: u64, vocab: usize) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed ^ 0x3017_0ad5),
            vocab: vocab as i32,
        }
    }

    /// Draw one batch: prompt lengths vary ±30% (uniform) around the
    /// benchmark mean; ids are uniform over the vocabulary.
    pub fn batch(&mut self, bench: Benchmark) -> Batch {
        let mean = bench.mean_prompt_tokens() as f64;
        let prompt_lens: Vec<usize> = (0..bench.prompts_per_batch())
            .map(|_| {
                let f = self.rng.range_f64(0.7, 1.3);
                ((mean * f).round() as usize).max(1)
            })
            .collect();
        let total: usize = prompt_lens.iter().sum();
        let token_ids = (0..total).map(|_| self.rng.below_i32(self.vocab)).collect();
        Batch {
            benchmark: bench,
            prompt_lens,
            token_ids,
        }
    }

    /// Generate `n` batches.
    pub fn batches(&mut self, bench: Benchmark, n: usize) -> Vec<Batch> {
        (0..n).map(|_| self.batch(bench)).collect()
    }

    /// Synthetic router outputs for the analytic (Mixtral-scale) sim:
    /// softmax of `bias_k + N(0, sharpness²)` logits per token, where
    /// `bias_k ~ N(0, bias²)` is a per-call (per-block) expert-popularity
    /// offset. `sharpness` ≈ 1.5 matches published Mixtral router entropy
    /// (top-2 mass 0.6–0.8); `bias` > 0 reproduces the *load imbalance*
    /// of trained routers (Mixtral's per-domain expert counts are far
    /// from uniform — Jiang et al. 2024, Fig. 7), which is what makes
    /// uniform bandwidth allocation costly in the paper's ablation.
    pub fn synthetic_gate_weights(
        &mut self,
        n_tokens: usize,
        n_experts: usize,
        sharpness: f64,
    ) -> Vec<Vec<f64>> {
        self.synthetic_gate_weights_biased(n_tokens, n_experts, sharpness, 0.4)
    }

    /// [`Self::synthetic_gate_weights`] with explicit popularity bias.
    pub fn synthetic_gate_weights_biased(
        &mut self,
        n_tokens: usize,
        n_experts: usize,
        sharpness: f64,
        bias: f64,
    ) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        let mut spare = Vec::new();
        let mut offsets = Vec::new();
        self.synthetic_gate_weights_biased_into(
            n_tokens,
            n_experts,
            sharpness,
            bias,
            &mut out,
            &mut spare,
            &mut offsets,
        );
        out
    }

    /// [`Self::synthetic_gate_weights_biased`] into reused buffers — the
    /// DES dispatches one gate matrix per block, so the hot path calls
    /// this with per-cell scratch and allocates nothing at steady state.
    /// Single source of truth: the allocating variant delegates here, so
    /// RNG draw order (offsets first, then one normal per token × expert,
    /// row-major) and the softmax arithmetic are bit-identical by
    /// construction.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_gate_weights_biased_into(
        &mut self,
        n_tokens: usize,
        n_experts: usize,
        sharpness: f64,
        bias: f64,
        out: &mut Vec<Vec<f64>>,
        spare: &mut Vec<Vec<f64>>,
        offsets: &mut Vec<f64>,
    ) {
        offsets.clear();
        offsets.extend((0..n_experts).map(|_| bias * self.rng.normal()));
        crate::util::reshape_rows(out, spare, n_tokens, n_experts, 0.0);
        for row in out.iter_mut() {
            for (x, o) in row.iter_mut().zip(offsets.iter()) {
                *x = o + sharpness * self.rng.normal();
            }
            let max = row.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = (*x - max).exp();
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_volumes_match_calibration() {
        // The Table-II calibration targets (tokens per batch).
        assert_eq!(Benchmark::Mmlu.nominal_batch_tokens(), 26880);
        assert_eq!(Benchmark::Piqa.nominal_batch_tokens(), 3328);
        assert_eq!(Benchmark::ArcEasy.nominal_batch_tokens(), 3264);
        assert_eq!(Benchmark::ArcChallenge.nominal_batch_tokens(), 3584);
        assert_eq!(Benchmark::Humaneval.nominal_batch_tokens(), 50);
        assert_eq!(Benchmark::Gsm8k.nominal_batch_tokens(), 150);
        assert_eq!(Benchmark::Boolq.nominal_batch_tokens(), 9856);
        assert_eq!(Benchmark::Mbpp.nominal_batch_tokens(), 76);
    }

    #[test]
    fn batch_total_within_30pct_of_nominal() {
        let mut g = WorkloadGen::new(0, 2048);
        for b in Benchmark::ALL {
            let batch = g.batch(b);
            let total = batch.total_tokens() as f64;
            let nominal = b.nominal_batch_tokens() as f64;
            assert!(
                (total - nominal).abs() / nominal < 0.35,
                "{}: {total} vs nominal {nominal}",
                b.name()
            );
            assert_eq!(batch.token_ids.len(), batch.total_tokens());
        }
    }

    #[test]
    fn token_ids_in_vocab() {
        let mut g = WorkloadGen::new(1, 128);
        let b = g.batch(Benchmark::Piqa);
        assert!(b.token_ids.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn seeded_reproducibility() {
        let mut a = WorkloadGen::new(5, 2048);
        let mut b = WorkloadGen::new(5, 2048);
        let ba = a.batch(Benchmark::Boolq);
        let bb = b.batch(Benchmark::Boolq);
        assert_eq!(ba.prompt_lens, bb.prompt_lens);
        assert_eq!(ba.token_ids, bb.token_ids);
    }

    #[test]
    fn gate_weights_into_matches_allocating_variant() {
        // Same seed, same draw order, bit-identical rows — including
        // across blocks of varying token counts reusing one scratch set.
        let mut a = WorkloadGen::new(9, 2048);
        let mut b = WorkloadGen::new(9, 2048);
        let mut out = Vec::new();
        let mut spare = Vec::new();
        let mut offsets = Vec::new();
        for tokens in [100usize, 20, 150] {
            let fresh = a.synthetic_gate_weights_biased(tokens, 8, 1.5, 0.4);
            b.synthetic_gate_weights_biased_into(
                tokens,
                8,
                1.5,
                0.4,
                &mut out,
                &mut spare,
                &mut offsets,
            );
            assert_eq!(fresh, out, "tokens={tokens}");
        }
    }

    #[test]
    fn gate_weights_are_distributions() {
        let mut g = WorkloadGen::new(2, 2048);
        let w = g.synthetic_gate_weights(200, 8, 1.5);
        assert_eq!(w.len(), 200);
        for row in &w {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(row.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn gate_sharpness_controls_concentration() {
        let mut g = WorkloadGen::new(3, 2048);
        let top2_mass = |rows: &[Vec<f64>]| -> f64 {
            rows.iter()
                .map(|r| {
                    let mut v = r.clone();
                    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
                    v[0] + v[1]
                })
                .sum::<f64>()
                / rows.len() as f64
        };
        let soft = g.synthetic_gate_weights(500, 8, 0.5);
        let sharp = g.synthetic_gate_weights(500, 8, 3.0);
        assert!(top2_mass(&sharp) > top2_mass(&soft) + 0.15);
        // calibration default lands in the Mixtral-like band
        let cal = g.synthetic_gate_weights(500, 8, 1.5);
        let m = top2_mass(&cal);
        assert!((0.5..0.9).contains(&m), "top2 mass {m}");
    }

    #[test]
    fn from_name_roundtrip() {
        for b in Benchmark::ALL {
            assert_eq!(Benchmark::from_name(b.name()), Some(b));
        }
        assert_eq!(Benchmark::from_name("nope"), None);
    }
}
