//! # WDMoE — Wireless Distributed Mixture of Experts for LLMs
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"WDMoE: Wireless Distributed Mixture of Experts for Large Language
//! Models"* (Xue et al., 2024).
//!
//! The paper deploys an MoE LLM across a wireless edge network: the
//! attention mechanism and the gating network run on the MEC server at the
//! base station (BS), while each MoE layer's expert FFNs are distributed
//! over mobile devices reached through fading wireless links. This crate
//! implements the paper's system contribution:
//!
//! * [`wireless`] — the channel substrate: 3GPP-style path loss, Rayleigh
//!   block fading, Shannon rates (paper Eqs. (2)–(3)), and bandwidth
//!   allocators (uniform and the convex-optimal solution of problem P3).
//! * [`devices`] — the heterogeneous device fleet (compute capacity `C_k`,
//!   expert placement, jitter/failure injection).
//! * [`latency`] — the token-latency model: communication (Eq. (6)),
//!   computation (Eq. (7)), and the *attention waiting latency*
//!   `t^i = max_k q_k^i t_{i,k}` (Eqs. (9)–(11)).
//! * [`moe`] — gate-weight handling, the weight-to-latency ratio
//!   (WLR, Eq. (12)) and the expert-selection policies: vanilla top-k
//!   (the Mixtral baseline), the paper's Algorithm 1 (cosine-similarity
//!   threshold, WLR-guarded), and Algorithm 2 (the hardware-testbed
//!   history-driven policy).
//! * [`control`] — the shared control plane: [`control::LinkState`]
//!   (the single home of per-device link assembly) and the
//!   [`control::ControlPlane`] implementations — static uniform/optimal
//!   and the adaptive closed loop (epoch-cadence P3 re-solve from
//!   observed backlog, warm-started, plus replica autoscaling) — consumed
//!   by both simulators.
//! * [`coordinator`] — request router, dynamic batcher, and the
//!   block-by-block dispatch loop that walks tokens through
//!   attention → gate → (devices) experts → combine.
//! * [`cluster`] — the discrete-event multi-cell serving simulator:
//!   open-loop arrivals, expert replication under cache-capacity
//!   constraints, load-aware replica dispatch and per-device FIFO
//!   queues (`repro cluster`).
//! * [`exec`] — the deterministic parallel sweep engine: a scoped
//!   worker pool that runs independent sweep points concurrently and
//!   merges results in canonical order, so parallel output is
//!   byte-identical to serial.
//! * [`experiment`] — the typed experiment API: [`experiment::Axis`]
//!   (every sweepable knob behind one `apply` dispatch),
//!   [`experiment::Grid`] (cross-product expansion run on the `exec`
//!   pool) and the unified [`experiment::Record`] metric schema that
//!   every sweep CSV/JSON is written from (`repro sweep --axis …`).
//! * [`runtime`] — PJRT execution of the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text → compile once → execute on the
//!   request path; python never runs at serving time). The PJRT pieces
//!   are gated behind the off-by-default `pjrt` cargo feature.
//! * [`workload`] — synthetic benchmark workload generators calibrated to
//!   the paper's eight evaluation datasets.
//! * [`testbed`] — the Section-VI hardware-testbed simulation (measured
//!   latency history, Algorithm 2, WiFi-like channel process).
//! * [`metrics`] — latency recording and the table/figure formatting used
//!   by the `repro` binary.
//! * [`telemetry`] — deterministic, opt-in observability for the DES: a
//!   [`telemetry::Probe`] event stream (no-op by default on the hot
//!   path), a Chrome-trace request tracer and a sim-time timeline
//!   sampler (`repro trace`).
//!
//! See `DESIGN.md` for the per-experiment index and substitution notes,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod cluster;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod exec;
pub mod experiment;
pub mod util;
pub mod devices;
pub mod latency;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod model;
pub mod moe;
pub mod optim;
pub mod repro;
pub mod runtime;
pub mod telemetry;
pub mod testbed;
pub mod wireless;
pub mod workload;

pub use config::SystemConfig;
