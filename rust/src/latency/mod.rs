//! Token-processing latency model — paper §III (Eqs. (4)–(11)).
//!
//! The model composes:
//! * per-token communication latency `t_comm = L_comm/R_d + L_comm/R_u`
//!   (Eq. (6)) — the token embedding crosses the air interface once each
//!   way, with equal payload both directions (§III-A);
//! * per-token computation latency `t_comp = L_comp / C_k` (Eq. (7));
//! * per-device totals `t_k^i = q_k^i · t_{i,k}` (Eq. (10)) — every token
//!   has the same size and FLOP count, so the device total is count ×
//!   per-token latency;
//! * the **attention waiting latency** `t^i = max_k t_k^i` (Eq. (11)) —
//!   the next block's attention needs the full sequence, so the slowest
//!   device gates the block boundary (Fig. 3).

use crate::optim::solver::DeviceLink;
use crate::wireless::rate::shannon_rate;

/// Per-device, per-token latency vector for one MoE block — the
/// `t_j^i = [t_{j,1}, …, t_{j,U}]` the selection policy consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenLatencies {
    /// Seconds per token for each device (comm + comp), Eq. (8).
    pub per_token: Vec<f64>,
}

impl TokenLatencies {
    /// Evaluate Eq. (8) for every device at the given bandwidth split.
    pub fn from_links(links: &[DeviceLink], bandwidth: &[f64]) -> Self {
        assert_eq!(links.len(), bandwidth.len());
        Self {
            per_token: links
                .iter()
                .zip(bandwidth)
                .map(|(l, &b)| l.t_per_token(b))
                .collect(),
        }
    }

    pub fn n_devices(&self) -> usize {
        self.per_token.len()
    }
}

/// Communication-only per-token latency, Eq. (6).
pub fn t_comm_per_token(
    l_comm_bits: f64,
    b_hz: f64,
    p_down: f64,
    p_up: f64,
    g_down: f64,
    g_up: f64,
    n0: f64,
) -> f64 {
    let rd = shannon_rate(b_hz, p_down, g_down, n0);
    let ru = shannon_rate(b_hz, p_up, g_up, n0);
    if rd <= 0.0 || ru <= 0.0 {
        return f64::INFINITY;
    }
    l_comm_bits / rd + l_comm_bits / ru
}

/// Latency outcome of one MoE block under a given selection + allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockLatency {
    /// Tokens assigned per device, `q_k^i` (Eq. (9)).
    pub tokens_per_device: Vec<f64>,
    /// Device completion times `t_k^i` (Eq. (10)).
    pub per_device: Vec<f64>,
    /// Attention waiting latency `t^i = max_k t_k^i` (Eq. (11)).
    pub waiting: f64,
    /// Index of the bottleneck device (argmax).
    pub bottleneck: usize,
}

/// Compute Eqs. (9)–(11) for one block.
///
/// `counts[k]` is the number of tokens routed to device k; devices with
/// zero tokens contribute zero latency even if their per-token latency is
/// infinite (offline device with no load is harmless).
pub fn block_latency(lat: &TokenLatencies, counts: &[f64]) -> BlockLatency {
    assert_eq!(lat.n_devices(), counts.len(), "device arity mismatch");
    let per_device: Vec<f64> = counts
        .iter()
        .zip(&lat.per_token)
        .map(|(&q, &t)| if q > 0.0 { q * t } else { 0.0 })
        .collect();
    let (bottleneck, waiting) = per_device
        .iter()
        .copied()
        .enumerate()
        .fold((0usize, 0.0f64), |(bi, bv), (i, v)| {
            if v > bv {
                (i, v)
            } else {
                (bi, bv)
            }
        });
    BlockLatency {
        tokens_per_device: counts.to_vec(),
        per_device,
        waiting,
        bottleneck,
    }
}

/// [`tokens_per_device`] into a reused buffer (cleared first) — the DES
/// dispatches one selection per block per in-flight request, so the count
/// reduction must not allocate.
pub fn tokens_per_device_into(mask: &[Vec<bool>], n_devices: usize, counts: &mut Vec<f64>) {
    counts.clear();
    counts.resize(n_devices, 0.0);
    for row in mask {
        debug_assert_eq!(row.len(), n_devices);
        for (k, &sel) in row.iter().enumerate() {
            if sel {
                counts[k] += 1.0;
            }
        }
    }
}

/// Count tokens per device from a selection mask (J × U, row-major).
/// `mask[j][k]` true ⇔ token j routed to device k — the `q_{j,k}^i` of the
/// paper; returns `q_k^i = Σ_j q_{j,k}^i` (Eq. (9)).
pub fn tokens_per_device(mask: &[Vec<bool>], n_devices: usize) -> Vec<f64> {
    let mut counts = Vec::new();
    tokens_per_device_into(mask, n_devices, &mut counts);
    counts
}

/// End-to-end latency report across all MoE blocks of one batch.
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub per_block: Vec<BlockLatency>,
}

impl LatencyReport {
    /// Total attention waiting latency `Σ_i t^i` — the P1 objective.
    pub fn total_waiting(&self) -> f64 {
        self.per_block.iter().map(|b| b.waiting).sum()
    }

    /// Total tokens transmitted (sum over blocks and devices) — the
    /// network load the expert-selection policy reduces.
    pub fn total_token_transmissions(&self) -> f64 {
        self.per_block
            .iter()
            .map(|b| b.tokens_per_device.iter().sum::<f64>())
            .sum()
    }

    pub fn push(&mut self, b: BlockLatency) {
        self.per_block.push(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lat(v: &[f64]) -> TokenLatencies {
        TokenLatencies {
            per_token: v.to_vec(),
        }
    }

    #[test]
    fn eq6_comm_latency_symmetric_payload() {
        // downlink and uplink carry the same L_comm (same tensor shape)
        let t = t_comm_per_token(65536.0, 12.5e6, 10.0, 0.2, 1e-8, 1e-8, 3.98e-21);
        assert!(t.is_finite() && t > 0.0);
        // uplink slower than downlink (0.2 W vs 10 W) ⇒ total > 2× downlink-only
        let rd = shannon_rate(12.5e6, 10.0, 1e-8, 3.98e-21);
        assert!(t > 2.0 * 65536.0 / rd);
    }

    #[test]
    fn eq10_scales_with_count() {
        let l = lat(&[2e-3, 1e-3]);
        let b = block_latency(&l, &[10.0, 50.0]);
        assert_eq!(b.per_device[0], 10.0 * 2e-3);
        assert_eq!(b.per_device[1], 50.0 * 1e-3);
    }

    #[test]
    fn eq11_max_is_waiting() {
        let l = lat(&[2e-3, 1e-3, 5e-3]);
        let b = block_latency(&l, &[10.0, 10.0, 10.0]);
        assert_eq!(b.waiting, 0.05);
        assert_eq!(b.bottleneck, 2);
    }

    #[test]
    fn zero_count_ignores_infinite_latency() {
        let l = lat(&[1e-3, f64::INFINITY]);
        let b = block_latency(&l, &[10.0, 0.0]);
        assert_eq!(b.per_device[1], 0.0);
        assert_eq!(b.waiting, 0.01);
        assert_eq!(b.bottleneck, 0);
    }

    #[test]
    fn empty_block_zero_waiting() {
        let l = lat(&[1e-3, 2e-3]);
        let b = block_latency(&l, &[0.0, 0.0]);
        assert_eq!(b.waiting, 0.0);
    }

    #[test]
    fn mask_counting_matches_eq9() {
        let mask = vec![
            vec![true, false, true],
            vec![true, true, false],
            vec![false, false, true],
        ];
        assert_eq!(tokens_per_device(&mask, 3), vec![2.0, 1.0, 2.0]);
    }

    #[test]
    fn report_total_is_sum_of_maxima() {
        let l = lat(&[1e-3, 2e-3]);
        let mut r = LatencyReport::default();
        r.push(block_latency(&l, &[5.0, 5.0])); // waiting = 0.01
        r.push(block_latency(&l, &[10.0, 1.0])); // waiting = 0.01
        assert!((r.total_waiting() - 0.02).abs() < 1e-12);
        assert_eq!(r.total_token_transmissions(), 21.0);
    }
}
