//! Dynamic request batching.
//!
//! The BS aggregates concurrent user prompts into token batches before
//! walking them through the MoE blocks (the paper's `J` is "the total
//! number of input tokens of all prompts at present", §II-A). The batcher
//! greedily packs queued requests up to a token budget; a batch is also
//! closed when the oldest request has waited past `max_wait`.
//!
//! Waiting time is measured through the [`Clock`] abstraction: serving
//! uses the default [`SystemClock`], while tests and the `cluster`
//! discrete-event simulator drive the same logic with a [`VirtualClock`]
//! so timeout behaviour is deterministic.

use crate::util::clock::{Clock, SystemClock, VirtualClock};
use std::collections::VecDeque;
use std::time::Duration;

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Token budget per batch (the AOT artifact's padded `J` in execution
    /// mode; unconstrained for the analytic sim).
    pub max_tokens: usize,
    /// Max prompts per batch.
    pub max_prompts: usize,
    /// Close a batch once the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_tokens: 256,
            max_prompts: 64,
            max_wait: Duration::from_millis(20),
        }
    }
}

/// A queued prompt.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    pub token_ids: Vec<i32>,
    /// Enqueue instant on the batcher's clock (elapsed since its epoch).
    pub enqueued: Duration,
}

/// Greedy FIFO token-budget batcher.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    clock: Box<dyn Clock>,
    queue: VecDeque<QueuedRequest>,
    next_id: u64,
}

impl DynamicBatcher {
    /// Batcher on wall-clock time (serving path).
    pub fn new(cfg: BatcherConfig) -> Self {
        Self::with_clock(cfg, Box::new(SystemClock::new()))
    }

    /// Batcher on an explicit clock (tests, discrete-event simulation).
    pub fn with_clock(cfg: BatcherConfig, clock: Box<dyn Clock>) -> Self {
        Self {
            cfg,
            clock,
            queue: VecDeque::new(),
            next_id: 0,
        }
    }

    /// Batcher sharing the given virtual clock.
    pub fn with_virtual_clock(cfg: BatcherConfig, clock: VirtualClock) -> Self {
        Self::with_clock(cfg, Box::new(clock))
    }

    /// Enqueue a prompt; returns its request id. Prompts longer than the
    /// token budget are truncated to fit (the serving model's AOT shape
    /// is fixed; long prompts would need a larger artifact).
    pub fn push(&mut self, mut token_ids: Vec<i32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        token_ids.truncate(self.cfg.max_tokens);
        self.queue.push_back(QueuedRequest {
            id,
            token_ids,
            enqueued: self.clock.now(),
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Whether a batch should be closed now: budget fillable or timeout.
    pub fn ready(&self) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        let tokens: usize = self.queue.iter().map(|r| r.token_ids.len()).sum();
        tokens >= self.cfg.max_tokens
            || self.queue.len() >= self.cfg.max_prompts
            || self.queue.front().is_some_and(|r| {
                self.clock.now().saturating_sub(r.enqueued) >= self.cfg.max_wait
            })
    }

    /// Pop the next batch (FIFO, greedy under the token budget). Returns
    /// `None` when the queue is empty. Always returns at least one
    /// request if any are queued.
    pub fn pop_batch(&mut self) -> Option<Vec<QueuedRequest>> {
        if self.queue.is_empty() {
            return None;
        }
        let mut batch = Vec::new();
        let mut tokens = 0usize;
        while let Some(front) = self.queue.front() {
            let len = front.token_ids.len();
            if !batch.is_empty()
                && (tokens + len > self.cfg.max_tokens || batch.len() >= self.cfg.max_prompts)
            {
                break;
            }
            tokens += len;
            batch.push(self.queue.pop_front().unwrap());
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_tokens: usize, max_prompts: usize) -> BatcherConfig {
        BatcherConfig {
            max_tokens,
            max_prompts,
            max_wait: Duration::from_secs(3600),
        }
    }

    #[test]
    fn empty_queue_no_batch() {
        let mut b = DynamicBatcher::new(cfg(100, 10));
        assert!(b.pop_batch().is_none());
        assert!(!b.ready());
    }

    #[test]
    fn greedy_packs_under_budget() {
        let mut b = DynamicBatcher::new(cfg(100, 10));
        b.push(vec![0; 40]);
        b.push(vec![0; 40]);
        b.push(vec![0; 40]);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 2, "two 40-token prompts fit in 100");
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn oversized_prompt_truncated_not_stuck() {
        let mut b = DynamicBatcher::new(cfg(50, 10));
        b.push(vec![0; 500]);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].token_ids.len(), 50);
    }

    #[test]
    fn respects_max_prompts() {
        let mut b = DynamicBatcher::new(cfg(1000, 3));
        for _ in 0..5 {
            b.push(vec![0; 10]);
        }
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn fifo_ids_preserved() {
        let mut b = DynamicBatcher::new(cfg(100, 10));
        let a = b.push(vec![0; 10]);
        let c = b.push(vec![0; 10]);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch[0].id, a);
        assert_eq!(batch[1].id, c);
    }

    #[test]
    fn ready_on_budget_fill() {
        let mut b = DynamicBatcher::new(cfg(20, 10));
        b.push(vec![0; 10]);
        assert!(!b.ready());
        b.push(vec![0; 10]);
        assert!(b.ready());
    }

    #[test]
    fn ready_on_timeout() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_tokens: 1000,
            max_prompts: 100,
            max_wait: Duration::from_millis(0),
        });
        b.push(vec![0; 1]);
        assert!(b.ready(), "zero max_wait means immediately ready");
    }

    #[test]
    fn virtual_clock_timeout_is_deterministic() {
        let clock = VirtualClock::new();
        let mut b = DynamicBatcher::with_virtual_clock(
            BatcherConfig {
                max_tokens: 1000,
                max_prompts: 100,
                max_wait: Duration::from_millis(10),
            },
            clock.clone(),
        );
        b.push(vec![0; 1]);
        assert!(!b.ready(), "no virtual time has passed");
        clock.advance(Duration::from_millis(9));
        assert!(!b.ready(), "9 ms < max_wait");
        clock.advance(Duration::from_millis(1));
        assert!(b.ready(), "exactly max_wait elapsed");
    }

    #[test]
    fn virtual_clock_timeout_tracks_oldest_request() {
        let clock = VirtualClock::new();
        let mut b = DynamicBatcher::with_virtual_clock(
            BatcherConfig {
                max_tokens: 1000,
                max_prompts: 100,
                max_wait: Duration::from_millis(10),
            },
            clock.clone(),
        );
        b.push(vec![0; 1]);
        clock.advance(Duration::from_millis(6));
        b.push(vec![0; 1]); // newer request must not reset the deadline
        clock.advance(Duration::from_millis(4));
        assert!(b.ready(), "oldest request has waited max_wait");
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].enqueued, Duration::ZERO);
        assert_eq!(batch[1].enqueued, Duration::from_millis(6));
    }
}
