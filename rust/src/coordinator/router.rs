//! Request router: the serving front-end.
//!
//! Users submit prompts; the router batches them ([`DynamicBatcher`]),
//! hands batches to a [`BatchEngine`] (the PJRT-backed serving model, or
//! a simulator-backed engine in tests), and resolves each request with
//! its completion plus the latency accounting of the batch it rode in.
//!
//! Concurrency model: a dedicated serving thread owns the engine (PJRT
//! execution is synchronous); submission handles are cloneable and
//! blocking-wait on a per-request channel. (The offline build environment
//! has no tokio — see DESIGN.md §Substitutions — so the loop uses std
//! threads and mpsc channels; the architecture is identical.)

use super::batcher::{BatcherConfig, DynamicBatcher};
use crate::latency::LatencyReport;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

/// A user prompt entering the system.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub token_ids: Vec<i32>,
}

/// Per-prompt result.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    /// Argmax next-token prediction at the prompt's final position.
    pub next_token: i32,
    /// Simulated wireless latency of the batch this prompt rode in (ms).
    pub batch_latency_ms: f64,
    /// Wall-clock compute time of the batch (ms) — PJRT execution time,
    /// kept separate from the simulated air-interface latency.
    pub batch_compute_ms: f64,
    /// How many prompts shared the batch.
    pub batch_size: usize,
}

/// Outcome of running one batch through the engine.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Argmax next token per prompt.
    pub next_tokens: Vec<i32>,
    /// Simulated wireless latency report.
    pub report: LatencyReport,
    /// Wall-clock milliseconds spent in compute.
    pub compute_ms: f64,
}

/// Anything that can execute a batch of prompts: the PJRT serving model,
/// or an analytic-simulation engine.
///
/// Engines are constructed *inside* the serving thread (PJRT handles are
/// not `Send`), so there is no `Send` bound here — `spawn_router` takes a
/// sendable factory instead.
pub trait BatchEngine {
    /// `prompt_lens[i]` tokens of prompt i, concatenated in `token_ids`.
    fn run_batch(&mut self, token_ids: &[i32], prompt_lens: &[usize]) -> anyhow::Result<BatchResult>;
}

struct Pending {
    req: InferenceRequest,
    resp: mpsc::Sender<anyhow::Result<InferenceResponse>>,
}

/// Handle for submitting requests to a running router.
#[derive(Clone)]
pub struct RouterHandle {
    tx: mpsc::Sender<Pending>,
}

impl RouterHandle {
    /// Submit a prompt and block until its response arrives.
    pub fn infer(&self, req: InferenceRequest) -> anyhow::Result<InferenceResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Pending { req, resp: tx })
            .map_err(|_| anyhow::anyhow!("router stopped"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("router dropped request"))?
    }

    /// Submit without waiting; returns the receiver for the response.
    pub fn infer_async(
        &self,
        req: InferenceRequest,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<InferenceResponse>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Pending { req, resp: tx })
            .map_err(|_| anyhow::anyhow!("router stopped"))?;
        Ok(rx)
    }
}

/// Spawn the serving loop on its own thread; returns a cloneable handle.
/// The engine factory runs on the serving thread (PJRT clients are not
/// `Send`). The loop exits when every handle has been dropped; a factory
/// failure fails every request.
pub fn spawn_router<E: BatchEngine>(
    factory: impl FnOnce() -> anyhow::Result<E> + Send + 'static,
    cfg: BatcherConfig,
) -> RouterHandle {
    let (tx, rx) = mpsc::channel::<Pending>();
    let max_wait = cfg.max_wait;
    thread::spawn(move || {
        let mut engine = match factory() {
            Ok(e) => e,
            Err(e) => {
                // Fail every request that ever arrives.
                while let Ok(p) = rx.recv() {
                    let _ = p.resp.send(Err(anyhow::anyhow!("engine init failed: {e}")));
                }
                return;
            }
        };
        let mut batcher = DynamicBatcher::new(cfg);
        let mut waiting: Vec<Pending> = Vec::new();
        loop {
            // Block for the first request (or exit when all senders drop).
            if waiting.is_empty() {
                match rx.recv() {
                    Ok(p) => {
                        batcher.push(p.req.token_ids.clone());
                        waiting.push(p);
                    }
                    Err(_) => break,
                }
            }
            // Drain more until the batcher is ready or max_wait elapses.
            // Sanctioned wall-clock read: the serving router batches
            // against real arrival time; nothing simulated depends on it.
            #[allow(clippy::disallowed_methods)]
            let deadline = Instant::now() + max_wait;
            while !batcher.ready() {
                #[allow(clippy::disallowed_methods)]
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(p) => {
                        batcher.push(p.req.token_ids.clone());
                        waiting.push(p);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            let Some(batch) = batcher.pop_batch() else {
                continue;
            };
            let n = batch.len();
            let token_ids: Vec<i32> = batch.iter().flat_map(|r| r.token_ids.clone()).collect();
            let prompt_lens: Vec<usize> = batch.iter().map(|r| r.token_ids.len()).collect();
            let result = engine.run_batch(&token_ids, &prompt_lens);
            let to_resolve: Vec<Pending> = waiting.drain(..n).collect();
            match result {
                Ok(res) => {
                    let lat_ms = res.report.total_waiting() * 1e3;
                    for (i, p) in to_resolve.into_iter().enumerate() {
                        let _ = p.resp.send(Ok(InferenceResponse {
                            next_token: res.next_tokens.get(i).copied().unwrap_or(-1),
                            batch_latency_ms: lat_ms,
                            batch_compute_ms: res.compute_ms,
                            batch_size: n,
                        }));
                    }
                }
                Err(e) => {
                    for p in to_resolve {
                        let _ = p.resp.send(Err(anyhow::anyhow!("engine failed: {e}")));
                    }
                }
            }
        }
    });
    RouterHandle { tx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::{BlockLatency, LatencyReport};
    use std::time::Duration;

    /// Engine that echoes the first token of each prompt and reports a
    /// fixed 1 ms of simulated latency.
    struct EchoEngine;

    impl BatchEngine for EchoEngine {
        fn run_batch(
            &mut self,
            token_ids: &[i32],
            prompt_lens: &[usize],
        ) -> anyhow::Result<BatchResult> {
            let mut next = Vec::new();
            let mut off = 0;
            for &l in prompt_lens {
                next.push(token_ids[off]);
                off += l;
            }
            let mut report = LatencyReport::default();
            report.push(BlockLatency {
                tokens_per_device: vec![1.0],
                per_device: vec![1e-3],
                waiting: 1e-3,
                bottleneck: 0,
            });
            Ok(BatchResult {
                next_tokens: next,
                report,
                compute_ms: 0.1,
            })
        }
    }

    /// Engine that always fails — error propagation test.
    struct FailEngine;

    impl BatchEngine for FailEngine {
        fn run_batch(&mut self, _: &[i32], _: &[usize]) -> anyhow::Result<BatchResult> {
            anyhow::bail!("boom")
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let h = spawn_router(|| Ok(EchoEngine), BatcherConfig::default());
        let r = h
            .infer(InferenceRequest {
                token_ids: vec![7, 8, 9],
            })
            .unwrap();
        assert_eq!(r.next_token, 7);
        assert!((r.batch_latency_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_requests_batched() {
        let cfg = BatcherConfig {
            max_tokens: 1000,
            max_prompts: 64,
            max_wait: Duration::from_millis(50),
        };
        let h = spawn_router(|| Ok(EchoEngine), cfg);
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(
                h.infer_async(InferenceRequest {
                    token_ids: vec![i, i],
                })
                .unwrap(),
            );
        }
        let mut sizes = Vec::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.next_token, i as i32);
            sizes.push(r.batch_size);
        }
        // at least some requests shared a batch
        assert!(sizes.iter().any(|&s| s > 1), "no batching happened: {sizes:?}");
    }

    #[test]
    fn engine_errors_propagate() {
        let h = spawn_router(|| Ok(FailEngine), BatcherConfig::default());
        let err = h
            .infer(InferenceRequest { token_ids: vec![1] })
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
        // The router survives the failure and serves subsequent requests
        // (FailEngine keeps failing, but responses keep coming).
        let err2 = h
            .infer(InferenceRequest { token_ids: vec![2] })
            .unwrap_err();
        assert!(err2.to_string().contains("engine failed"));
    }

    #[test]
    fn requests_preserve_order_within_batch() {
        let h = spawn_router(|| Ok(EchoEngine), BatcherConfig::default());
        let rx1 = h.infer_async(InferenceRequest { token_ids: vec![1] }).unwrap();
        let rx2 = h.infer_async(InferenceRequest { token_ids: vec![2] }).unwrap();
        assert_eq!(rx1.recv().unwrap().unwrap().next_token, 1);
        assert_eq!(rx2.recv().unwrap().unwrap().next_token, 2);
    }
}
