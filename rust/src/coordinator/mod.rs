//! The WDMoE coordinator — the paper's Layer-3 system contribution.
//!
//! * [`sim`] — the analytic wireless simulator: walks a batch through all
//!   `I` MoE blocks, running gate → selection policy → bandwidth
//!   allocation → attention-waiting-latency accounting exactly as
//!   §III–IV prescribe. Every paper table/figure harness runs on it.
//! * [`batcher`] — dynamic request batching for the serving path.
//! * [`router`] — request/response types and the async serving loop that
//!   ties the batcher, the PJRT model and the policies together.

pub mod batcher;
pub mod router;
pub mod sim;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use router::{InferenceRequest, InferenceResponse};
pub use sim::{SimOutcome, Simulator, Variant};
