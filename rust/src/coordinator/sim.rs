//! The analytic WDMoE simulator: paper §III–§IV end to end.
//!
//! For one batch of `J` tokens the simulator:
//!
//! 1. draws gate weights per MoE block (synthetic router, calibrated to
//!    Mixtral-like concentration — execution mode uses the real gate);
//! 2. runs the expert-selection policy with per-token latencies estimated
//!    under *uniform* bandwidth (§IV-A: selection assumes even split);
//! 3. given the full selection `Q`, allocates bandwidth (uniform baseline
//!    or the convex-optimal P3 solution) once for the batch — mirroring
//!    the paper's "given the expert selection Q, the upper level
//!    optimization" structure;
//! 4. evaluates the final attention waiting latency per block (Eqs.
//!    (9)–(11)) under the allocated bandwidth.
//!
//! Link assembly and allocation go through the shared control layer: a
//! [`crate::control::LinkState`] is built per *arm* — from the channel
//! realization current at [`Simulator::make_arm`] time — and a
//! [`ControlPlane`] matching the variant's allocator serves the
//! per-block solves, the same code path the cluster DES uses. Under
//! fading, pair one fresh arm with each batch (as [`Simulator::run_variant`]
//! does) so every batch sees its own draw; reusing an arm across batches
//! freezes its realization.
//!
//! The four ablation arms of paper Fig. 7 / Table II are expressible as
//! [`Variant`]s: policy × allocator.

use crate::config::{AllocatorKind, PolicyKind, SystemConfig};
use crate::control::{self, ControlOptions, ControlPlane, LinkState};
use crate::devices::Fleet;
use crate::latency::{block_latency, LatencyReport, TokenLatencies};
use crate::moe::selection::{make_policy, SelectionContext, SelectionPolicy};
use crate::moe::{total_wlr, GateWeights, Selection};
use crate::optim::PerBlockLoad;
use crate::wireless::{ChannelRealization, ChannelSimulator};
use crate::workload::WorkloadGen;

/// A (selection policy, bandwidth allocator) arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Variant {
    pub policy: PolicyKind,
    pub allocator: AllocatorKind,
}

impl Variant {
    /// The paper's four arms (Fig. 7 / Table II).
    pub fn mixtral_based() -> Self {
        Self {
            policy: PolicyKind::VanillaTopK,
            allocator: AllocatorKind::Uniform,
        }
    }
    pub fn wdmoe_no_bandwidth() -> Self {
        Self {
            policy: PolicyKind::Wdmoe,
            allocator: AllocatorKind::Uniform,
        }
    }
    pub fn wdmoe_no_selection() -> Self {
        Self {
            policy: PolicyKind::VanillaTopK,
            allocator: AllocatorKind::Optimal,
        }
    }
    pub fn wdmoe_full() -> Self {
        Self {
            policy: PolicyKind::Wdmoe,
            allocator: AllocatorKind::Optimal,
        }
    }

    pub fn label(&self) -> &'static str {
        match (self.policy, self.allocator) {
            (PolicyKind::VanillaTopK, AllocatorKind::Uniform) => "Mixtral-based Method",
            (PolicyKind::Wdmoe, AllocatorKind::Uniform) => "WDMoE w./o bandwidth allocation",
            (PolicyKind::VanillaTopK, AllocatorKind::Optimal) => "WDMoE w./o expert selection",
            (PolicyKind::Wdmoe, AllocatorKind::Optimal) => "WDMoE",
            (PolicyKind::Testbed, _) => "WDMoE-testbed",
            (PolicyKind::Random, _) => "Random",
        }
    }
}

/// Result of simulating one batch.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub report: LatencyReport,
    /// Mean bandwidth split across blocks (Hz).
    pub bandwidth: Vec<f64>,
    /// Per-block bandwidth splits (the BS re-allocates spectrum each MoE
    /// block as token routing shifts — paper Fig. 4's "dynamically ...
    /// optimize the bandwidth allocation based on gating network output").
    pub bandwidth_per_block: Vec<Vec<f64>>,
    /// Per-block selections (kept for routing statistics / Fig. 8).
    pub selections: Vec<Selection>,
    /// Per-block gate weights (for capability probes).
    pub gates: Vec<GateWeights>,
    /// Total WLR across blocks under the final latencies.
    pub wlr_total: f64,
}

impl SimOutcome {
    /// Total attention waiting latency in milliseconds — the number the
    /// paper's tables report ("Latency/batch (ms)").
    pub fn latency_ms(&self) -> f64 {
        self.report.total_waiting() * 1e3
    }
}

/// The simulator. Holds the channel process, fleet and synthetic router.
pub struct Simulator {
    pub cfg: SystemConfig,
    channel: ChannelSimulator,
    fleet: Fleet,
    gates: WorkloadGen,
    /// Use fading draws (true) or the expected channel (false). The paper
    /// tables are deterministic given the mean channel; fading is used by
    /// the testbed harness and robustness tests.
    pub fading: bool,
    /// Router concentration for synthetic gate weights.
    pub gate_sharpness: f64,
    /// Per-block expert-popularity bias std (trained-router imbalance).
    pub gate_bias: f64,
}

impl Simulator {
    pub fn new(cfg: SystemConfig) -> Self {
        cfg.validate().expect("invalid SystemConfig");
        let channel = ChannelSimulator::new(&cfg.channel, &cfg.devices, cfg.seed);
        let fleet = Fleet::new(&cfg.devices, cfg.seed);
        let gates = WorkloadGen::new(cfg.seed.wrapping_add(1), cfg.model.vocab);
        Self {
            cfg,
            channel,
            fleet,
            gates,
            fading: false,
            gate_sharpness: 1.5,
            gate_bias: 0.4,
        }
    }

    /// Access the fleet (failure injection in tests/harnesses).
    pub fn fleet_mut(&mut self) -> &mut Fleet {
        &mut self.fleet
    }

    fn realization(&self) -> ChannelRealization {
        if self.fading {
            self.channel.realization().clone()
        } else {
            self.channel.expected_realization()
        }
    }

    /// Build a policy/control-plane pair for a variant. The plane owns
    /// the batch's [`LinkState`] (links assembled from the *current*
    /// channel realization, so fading draws are honoured).
    pub fn make_arm(&self, v: Variant) -> (Box<dyn SelectionPolicy>, Box<dyn ControlPlane>) {
        let policy = make_policy(v.policy, &self.cfg.policy, self.cfg.n_devices(), self.cfg.seed);
        (policy, self.make_plane(v.allocator))
    }

    /// Control plane matching an allocator kind. Link/t_per_token
    /// assembly lives in [`LinkState`] — shared with the cluster DES, not
    /// duplicated here. The paper's setup has no replication (expert k on
    /// device k), hence cache capacity 1.
    pub fn make_plane(&self, allocator: AllocatorKind) -> Box<dyn ControlPlane> {
        let l_comp = self.cfg.model.l_comp_flops(self.cfg.activation_eta);
        let t_comp = self.fleet.t_comp_nominal(l_comp);
        let realization = self.realization();
        let state = LinkState::new(
            &self.cfg.channel,
            &realization,
            &t_comp,
            self.cfg.model.l_comm_bits(self.cfg.channel.quant_bits),
        );
        control::make_plane(
            allocator.into(),
            state,
            self.cfg.model.n_experts,
            1,
            ControlOptions::default(),
        )
    }

    /// Simulate one batch of `n_tokens` through all `I` blocks under the
    /// given policy/control plane. Gate weights are drawn fresh per block
    /// (same stream for a given simulator seed and call order, so two
    /// variants compare on identical routing when run on fresh simulators
    /// with the same seed).
    pub fn run_batch(
        &mut self,
        n_tokens: usize,
        policy: &mut dyn SelectionPolicy,
        plane: &mut dyn ControlPlane,
    ) -> SimOutcome {
        let u = self.cfg.n_devices();
        let blocks = self.cfg.model.n_blocks;
        let online = self.fleet.online_mask();

        // Uniform-bandwidth latency estimate for the selection policy
        // (§IV-A: selection assumes the even split, whatever the
        // allocator later decides).
        let est = TokenLatencies {
            per_token: plane.state().uniform_t_per_token(),
        };

        // Phase 1: per-block gating + expert selection.
        let mut selections = Vec::with_capacity(blocks);
        let mut gates_out = Vec::with_capacity(blocks);
        let mut loads = Vec::with_capacity(blocks);
        for _ in 0..blocks {
            let gate = GateWeights::new(self.gates.synthetic_gate_weights_biased(
                n_tokens,
                u,
                self.gate_sharpness,
                self.gate_bias,
            ));
            let ctx = SelectionContext {
                latencies: &est,
                top_k: self.cfg.model.top_k,
                online: &online,
            };
            let sel = policy.select(&gate, &ctx);
            loads.push(PerBlockLoad {
                tokens: sel.tokens_per_device(),
            });
            selections.push(sel);
            gates_out.push(gate);
        }

        // Phase 2+3: per-block bandwidth allocation + latency. The BS
        // re-splits spectrum at each block boundary for that block's
        // routing (paper Fig. 4); each block's allocation solves P3 for
        // its own load vector. The split lands in one reused buffer (the
        // plane's workspace keeps the solve itself allocation-free); only
        // the per-block record below copies it out.
        let mut report = LatencyReport::default();
        let mut wlr_total = 0.0;
        let mut bandwidth_per_block = Vec::with_capacity(blocks);
        let mut mean_bw = vec![0.0; u];
        let mut bw = Vec::with_capacity(u);
        for (i, sel) in selections.iter().enumerate() {
            plane.allocate_into(std::slice::from_ref(&loads[i]), &mut bw);
            let final_lat = plane.state().token_latencies(&bw);
            let bl = block_latency(&final_lat, &loads[i].tokens);
            // Algorithm-2 feedback: observed per-token latency per device.
            for k in 0..u {
                if loads[i].tokens[k] > 0.0 {
                    policy.observe(k, final_lat.per_token[k]);
                }
                mean_bw[k] += bw[k] / blocks as f64;
            }
            wlr_total += total_wlr(sel, &final_lat);
            bandwidth_per_block.push(bw.clone());
            report.push(bl);
            self.channel.advance_block();
        }

        SimOutcome {
            report,
            bandwidth: mean_bw,
            bandwidth_per_block,
            selections,
            gates: gates_out,
            wlr_total,
        }
    }

    /// Convenience: run a variant on a fresh policy/plane pair.
    pub fn run_variant(&mut self, n_tokens: usize, v: Variant) -> SimOutcome {
        let (mut policy, mut plane) = self.make_arm(v);
        self.run_batch(n_tokens, policy.as_mut(), plane.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> Simulator {
        Simulator::new(SystemConfig::paper_simulation())
    }

    #[test]
    fn mixtral_baseline_runs_and_is_positive() {
        let out = sim().run_variant(1000, Variant::mixtral_based());
        assert!(out.latency_ms() > 0.0);
        assert_eq!(out.report.per_block.len(), 32);
        assert_eq!(out.selections.len(), 32);
        // top-2 on every token
        let total: f64 = out.report.total_token_transmissions();
        assert_eq!(total, 2.0 * 1000.0 * 32.0);
    }

    #[test]
    fn wdmoe_beats_mixtral_baseline() {
        // Fresh simulators with the same seed see the same gate stream.
        let a = sim().run_variant(1000, Variant::mixtral_based());
        let b = sim().run_variant(1000, Variant::wdmoe_full());
        assert!(
            b.latency_ms() < a.latency_ms() * 0.8,
            "WDMoE {:.1}ms should clearly beat Mixtral-based {:.1}ms",
            b.latency_ms(),
            a.latency_ms()
        );
    }

    #[test]
    fn ablation_ordering_holds() {
        // Paper Table II ordering: Mixtral ≥ w/o BW ≥ w/o selection ≥ full
        // (bandwidth allocation is the bigger lever, §V-C).
        let m = sim().run_variant(800, Variant::mixtral_based()).latency_ms();
        let nb = sim().run_variant(800, Variant::wdmoe_no_bandwidth()).latency_ms();
        let ns = sim().run_variant(800, Variant::wdmoe_no_selection()).latency_ms();
        let f = sim().run_variant(800, Variant::wdmoe_full()).latency_ms();
        assert!(nb <= m, "w/o BW {nb} > Mixtral {m}");
        assert!(ns <= nb, "w/o sel {ns} > w/o BW {nb} (BW is the bigger lever)");
        assert!(f <= ns * 1.02, "full {f} should be at or below w/o sel {ns}");
    }

    #[test]
    fn selection_reduces_transmissions() {
        let a = sim().run_variant(500, Variant::mixtral_based());
        let b = sim().run_variant(500, Variant::wdmoe_no_bandwidth());
        assert!(
            b.report.total_token_transmissions() < a.report.total_token_transmissions(),
            "Alg1 must shed token transmissions"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sim().run_variant(300, Variant::wdmoe_full());
        let b = sim().run_variant(300, Variant::wdmoe_full());
        assert_eq!(a.latency_ms(), b.latency_ms());
        assert_eq!(a.bandwidth, b.bandwidth);
    }

    #[test]
    fn offline_device_gets_no_tokens_and_run_survives() {
        let mut s = sim();
        s.fleet_mut().set_online(7, false);
        let out = s.run_variant(400, Variant::wdmoe_full());
        for sel in &out.selections {
            assert_eq!(sel.tokens_per_device()[7], 0.0);
        }
        assert!(out.latency_ms().is_finite());
    }

    #[test]
    fn more_bandwidth_less_latency() {
        let mut cfg = SystemConfig::paper_simulation();
        cfg.channel.total_bandwidth_hz = 20e6;
        let lo = Simulator::new(cfg.clone()).run_variant(500, Variant::wdmoe_full());
        cfg.channel.total_bandwidth_hz = 200e6;
        let hi = Simulator::new(cfg).run_variant(500, Variant::wdmoe_full());
        assert!(hi.latency_ms() < lo.latency_ms());
    }

    #[test]
    fn wlr_reported_positive() {
        let out = sim().run_variant(200, Variant::wdmoe_full());
        assert!(out.wlr_total > 0.0);
    }
}
