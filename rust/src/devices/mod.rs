//! Device fleet: the mobile devices hosting expert networks.
//!
//! Paper §II-B: each device is "equipped with at least one GPU" and runs
//! the expert network(s) placed on it; device k hosts expert k of every
//! MoE layer in the Section-V setup. The fleet tracks per-device compute
//! capacity `C_k` (Eq. (7)), optional multiplicative compute jitter (the
//! "variations in mobile device workloads" of §III-B), and an
//! online/offline flag for failure-injection tests.

use crate::config::DeviceConfig;
use crate::util::Rng;

/// Runtime state of one device.
#[derive(Debug, Clone)]
pub struct DeviceState {
    pub cfg: DeviceConfig,
    /// Device currently reachable; offline devices must receive no tokens.
    pub online: bool,
}

/// The fleet of expert-hosting devices.
pub struct Fleet {
    devices: Vec<DeviceState>,
    rng: Rng,
}

impl Fleet {
    pub fn new(configs: &[DeviceConfig], seed: u64) -> Self {
        Self {
            devices: configs
                .iter()
                .map(|c| DeviceState {
                    cfg: c.clone(),
                    online: true,
                })
                .collect(),
            rng: Rng::seed_from_u64(seed ^ 0x0dec_1ce5),
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, k: usize) -> &DeviceState {
        &self.devices[k]
    }

    pub fn iter(&self) -> impl Iterator<Item = &DeviceState> {
        self.devices.iter()
    }

    /// Mark a device offline (failure injection) or back online.
    pub fn set_online(&mut self, k: usize, online: bool) {
        self.devices[k].online = online;
    }

    pub fn online_mask(&self) -> Vec<bool> {
        self.devices.iter().map(|d| d.online).collect()
    }

    pub fn n_online(&self) -> usize {
        self.devices.iter().filter(|d| d.online).count()
    }

    /// Effective compute capacity for this block: `C_k` perturbed by the
    /// configured jitter (clamped to stay positive). Offline devices
    /// report zero capacity.
    pub fn effective_flops(&mut self, k: usize) -> f64 {
        let d = &self.devices[k];
        if !d.online {
            return 0.0;
        }
        if d.cfg.compute_jitter == 0.0 {
            return d.cfg.compute_flops;
        }
        let z = self.rng.normal();
        let d = &self.devices[k];
        let factor = (1.0 + d.cfg.compute_jitter * z).max(0.2);
        d.cfg.compute_flops * factor
    }

    /// Compute seconds per token for every device given `L_comp` FLOPs —
    /// Eq. (7): `t_comp = L_comp / C_k`. Offline devices get `inf`.
    pub fn t_comp_per_token(&mut self, l_comp_flops: f64) -> Vec<f64> {
        (0..self.devices.len())
            .map(|k| {
                let c = self.effective_flops(k);
                if c <= 0.0 {
                    f64::INFINITY
                } else {
                    l_comp_flops / c
                }
            })
            .collect()
    }

    /// Deterministic (jitter-free, all-online assumed-capacity) variant
    /// used by the paper-table harnesses.
    pub fn t_comp_nominal(&self, l_comp_flops: f64) -> Vec<f64> {
        self.devices
            .iter()
            .map(|d| {
                if d.online {
                    l_comp_flops / d.cfg.compute_flops
                } else {
                    f64::INFINITY
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn fleet() -> Fleet {
        Fleet::new(&SystemConfig::paper_simulation().devices, 0)
    }

    #[test]
    fn nominal_matches_eq7() {
        let f = fleet();
        let l = 1e9;
        let t = f.t_comp_nominal(l);
        for (k, d) in f.iter().enumerate() {
            assert_eq!(t[k], l / d.cfg.compute_flops);
        }
    }

    #[test]
    fn zero_jitter_is_deterministic() {
        let mut f = fleet();
        let a = f.effective_flops(0);
        let b = f.effective_flops(0);
        assert_eq!(a, b);
        assert_eq!(a, f.device(0).cfg.compute_flops);
    }

    #[test]
    fn jitter_perturbs_but_stays_positive() {
        let cfgs = SystemConfig::paper_testbed().devices;
        let mut f = Fleet::new(&cfgs, 3);
        let mut distinct = false;
        let nominal = f.device(0).cfg.compute_flops;
        let mut prev = f.effective_flops(0);
        for _ in 0..100 {
            let c = f.effective_flops(0);
            assert!(c > 0.0);
            if (c - prev).abs() > 1.0 {
                distinct = true;
            }
            prev = c;
        }
        assert!(distinct, "jitter produced constant capacity {nominal}");
    }

    #[test]
    fn offline_device_reports_zero_then_inf_latency() {
        let mut f = fleet();
        f.set_online(3, false);
        assert_eq!(f.effective_flops(3), 0.0);
        let t = f.t_comp_per_token(1e9);
        assert!(t[3].is_infinite());
        assert!(t[2].is_finite());
        assert_eq!(f.n_online(), 7);
        f.set_online(3, true);
        assert_eq!(f.n_online(), 8);
    }

    #[test]
    fn seeded_jitter_reproducible() {
        let cfgs = SystemConfig::paper_testbed().devices;
        let mut a = Fleet::new(&cfgs, 11);
        let mut b = Fleet::new(&cfgs, 11);
        for _ in 0..10 {
            assert_eq!(a.effective_flops(2), b.effective_flops(2));
        }
    }
}
