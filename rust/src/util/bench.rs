//! Micro-benchmark harness — replaces `criterion` (unavailable offline).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary built on this:
//! warm-up, then timed iterations until a wall-clock budget is spent,
//! reporting mean / p50 / p95 per-iteration time with a black-box guard
//! against dead-code elimination.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>10}   p50 {:>10}   p95 {:>10}   ({} iters)",
            self.name,
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.p50_ns),
            Self::fmt_ns(self.p95_ns),
            self.iterations
        );
    }
}

/// Time `f` repeatedly for ~`budget` (after one warm-up call) and report.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    black_box(f()); // warm-up (fills caches, triggers lazy init)
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        black_box(f());
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    let mut sorted = samples_ns.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iterations: samples_ns.len(),
        mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
    };
    r.report();
    r
}

/// Default per-benchmark budget, overridable via WDMOE_BENCH_MS.
pub fn default_budget() -> Duration {
    let ms = std::env::var("WDMOE_BENCH_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(300);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(10), || {
            (0..100).sum::<u64>()
        });
        assert!(r.iterations >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }
}
