//! Micro-benchmark harness — replaces `criterion` (unavailable offline).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary built on this:
//! warm-up, then timed iterations until a wall-clock budget is spent,
//! reporting mean / p50 / p95 per-iteration time with a black-box guard
//! against dead-code elimination.

use crate::util::Json;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional derived throughput `(unit, value)` — e.g. the DES
    /// harness reports simulated events per wall second.
    pub throughput: Option<(String, f64)>,
}

impl BenchResult {
    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) {
        let extra = match &self.throughput {
            Some((unit, v)) => format!("   {v:.0} {unit}"),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10}   p50 {:>10}   p95 {:>10}   ({} iters){extra}",
            self.name,
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.p50_ns),
            Self::fmt_ns(self.p95_ns),
            self.iterations
        );
    }

    /// JSON record for `repro bench --json` (BENCH_cluster.json).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
        ];
        if let Some((unit, v)) = &self.throughput {
            fields.push((
                "throughput",
                Json::obj(vec![("unit", Json::str(unit)), ("value", Json::Num(*v))]),
            ));
        }
        Json::obj(fields)
    }
}

/// Time `f` repeatedly for ~`budget` (after one warm-up call) and report.
pub fn bench<T>(name: &str, budget: Duration, f: impl FnMut() -> T) -> BenchResult {
    let r = bench_quiet(name, budget, f);
    r.report();
    r
}

/// [`bench`] without the report — for harnesses that attach a derived
/// metric (e.g. events/sec) to the result before printing it once.
// The bench timer is a sanctioned wall-clock boundary: it measures the
// host, never feeds simulated state.
#[allow(clippy::disallowed_methods)]
pub fn bench_quiet<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    black_box(f()); // warm-up (fills caches, triggers lazy init)
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        black_box(f());
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    let mut sorted = samples_ns.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
    BenchResult {
        name: name.to_string(),
        iterations: samples_ns.len(),
        mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        throughput: None,
    }
}

/// Default per-benchmark budget, overridable via WDMOE_BENCH_MS.
// Sanctioned env read: a bench-budget knob, outside any simulated state.
#[allow(clippy::disallowed_methods)]
pub fn default_budget() -> Duration {
    let ms = std::env::var("WDMOE_BENCH_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(300);
    Duration::from_millis(ms)
}

/// Tiny budget for smoke runs (`repro bench --smoke` in CI): just enough
/// iterations to prove the harnesses still run, not to produce stable
/// numbers.
pub fn smoke_budget() -> Duration {
    Duration::from_millis(10)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(10), || {
            (0..100).sum::<u64>()
        });
        assert!(r.iterations >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    #[test]
    fn json_record_roundtrips() {
        let mut r = bench("j", Duration::from_millis(1), || 1u64);
        r.throughput = Some(("events_per_sec".to_string(), 1234.5));
        let j = r.to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "j");
        assert!(back.get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        let t = back.get("throughput").unwrap();
        assert_eq!(t.get("unit").unwrap().as_str().unwrap(), "events_per_sec");
        assert_eq!(t.get("value").unwrap().as_f64().unwrap(), 1234.5);
    }
}
