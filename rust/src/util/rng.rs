//! Seeded PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Replaces `rand`/`rand_chacha` (unavailable offline). Not cryptographic;
//! statistically solid for simulation (Blackman & Vigna 2019). All
//! simulation randomness in this crate flows through this generator, so
//! runs are reproducible from a single `u64` seed.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (recommended by the xoshiro authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
            spare_normal: None,
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics on n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for the n << 2^64 used in simulation.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i32 in [0, n).
    pub fn below_i32(&mut self, n: i32) -> i32 {
        assert!(n > 0);
        (self.next_u64() % n as u64) as i32
    }

    /// Standard normal via Box–Muller (both outputs used; the second is
    /// cached, halving the trig/log cost on sequential draws).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Rayleigh-distributed amplitude with the given *mean*:
    /// scale `sigma = mean / sqrt(pi/2)`, inverse-CDF sampling.
    pub fn rayleigh_with_mean(&mut self, mean: f64) -> f64 {
        let sigma = mean / (std::f64::consts::PI / 2.0).sqrt();
        let u = self.f64().max(f64::MIN_POSITIVE);
        sigma * (-2.0 * u.ln()).sqrt()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_with_good_mean() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn rayleigh_mean_matches() {
        let mut r = Rng::seed_from_u64(4);
        let target = 3.7e-5;
        let n = 200_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.rayleigh_with_mean(target);
        }
        let mean = sum / n as f64;
        assert!((mean - target).abs() / target < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle did nothing");
    }
}
