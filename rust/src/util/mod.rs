//! Self-contained utilities replacing crates unavailable in the offline
//! build environment (see DESIGN.md §Substitutions): a seeded PRNG
//! (`rand`), a minimal JSON parser/writer (`serde_json`), a temp-dir
//! helper (`tempfile`), and a micro-benchmark timer (`criterion`).

pub mod bench;
pub mod clock;
pub mod json;
pub mod rng;

pub use clock::{Clock, SystemClock, VirtualClock};
pub use json::Json;
pub use rng::Rng;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique, created temp directory (best-effort cleanup is the caller's
/// business; tests leave them under the system temp dir).
pub fn temp_dir(prefix: &str) -> PathBuf {
    let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("wdmoe-{prefix}-{pid}-{n}"));
    std::fs::create_dir_all(&dir).expect("creating temp dir");
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_dirs_unique_and_exist() {
        let a = temp_dir("t");
        let b = temp_dir("t");
        assert_ne!(a, b);
        assert!(a.exists() && b.exists());
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
