//! Self-contained utilities replacing crates unavailable in the offline
//! build environment (see DESIGN.md §Substitutions): a seeded PRNG
//! (`rand`), a minimal JSON parser/writer (`serde_json`), a temp-dir
//! helper (`tempfile`), and a micro-benchmark timer (`criterion`).

pub mod bench;
pub mod clock;
pub mod json;
pub mod rng;

pub use clock::{Clock, SystemClock, VirtualClock};
pub use json::Json;
pub use rng::Rng;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique, created temp directory (best-effort cleanup is the caller's
/// business; tests leave them under the system temp dir).
pub fn temp_dir(prefix: &str) -> PathBuf {
    let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("wdmoe-{prefix}-{pid}-{n}"));
    std::fs::create_dir_all(&dir).expect("creating temp dir");
    dir
}

/// Reshape a row-major matrix to `rows` rows of `cols` elements each set
/// to `fill`, recycling spare row buffers through `spare` instead of
/// freeing them: shrinking moves excess rows into the pool, growing
/// pulls them back out. Once the pool has seen the high-water row count
/// (and each recycled row the high-water column count), reshaping is
/// allocation-free — the building block of the per-block gate/selection
/// scratch in the cluster DES hot path.
pub fn reshape_rows<T: Clone>(
    matrix: &mut Vec<Vec<T>>,
    spare: &mut Vec<Vec<T>>,
    rows: usize,
    cols: usize,
    fill: T,
) {
    while matrix.len() > rows {
        if let Some(row) = matrix.pop() {
            spare.push(row);
        }
    }
    while matrix.len() < rows {
        matrix.push(spare.pop().unwrap_or_default());
    }
    for row in matrix.iter_mut() {
        row.clear();
        row.resize(cols, fill.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_rows_recycles_buffers() {
        let mut m: Vec<Vec<f64>> = Vec::new();
        let mut spare: Vec<Vec<f64>> = Vec::new();
        reshape_rows(&mut m, &mut spare, 3, 4, 0.0);
        assert_eq!(m, vec![vec![0.0; 4]; 3]);
        m[0][0] = 7.0;
        // Shrink: the excess row moves to the pool, not the allocator.
        reshape_rows(&mut m, &mut spare, 1, 4, 0.0);
        assert_eq!(m, vec![vec![0.0; 4]; 1]);
        assert_eq!(spare.len(), 2);
        let spare_caps: Vec<usize> = spare.iter().map(|r| r.capacity()).collect();
        assert!(spare_caps.iter().all(|&c| c >= 4));
        // Grow again: rows come back from the pool with their capacity.
        reshape_rows(&mut m, &mut spare, 3, 2, 1.5);
        assert_eq!(m, vec![vec![1.5; 2]; 3]);
        assert!(spare.is_empty());
    }

    #[test]
    fn temp_dirs_unique_and_exist() {
        let a = temp_dir("t");
        let b = temp_dir("t");
        assert_ne!(a, b);
        assert!(a.exists() && b.exists());
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
