//! Time sources: a `Clock` trait over wall and virtual time.
//!
//! Every component that asks "how long has X waited" goes through
//! [`Clock`] instead of touching [`Instant`] directly, so the same code
//! runs against real time in serving ([`SystemClock`]) and against the
//! discrete-event simulator's virtual time ([`VirtualClock`]) in tests
//! and in the `cluster` subsystem — deterministically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic time source. `now()` is elapsed time since the clock's
/// epoch (creation for [`SystemClock`], t=0 for [`VirtualClock`]).
pub trait Clock: Send {
    fn now(&self) -> Duration;
}

/// Wall-clock time, anchored at construction.
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    // The one sanctioned wall-clock read: everything else goes through
    // the Clock trait so simulations can substitute VirtualClock.
    #[allow(clippy::disallowed_methods)]
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
        }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Duration {
        self.origin.elapsed()
    }
}

/// Manually-advanced virtual time with nanosecond resolution.
///
/// Clones share the same underlying counter, so a simulator can hold one
/// handle and advance it while a batcher holds another and reads it. Time
/// never goes backwards: advancing to an earlier instant is a no-op.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    nanos: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in nanoseconds since t=0.
    pub fn nanos(&self) -> u64 {
        self.nanos.load(Ordering::SeqCst)
    }

    /// Move the clock forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.nanos
            .fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Move the clock forward to the absolute instant `t_nanos`
    /// (monotone: earlier instants leave the clock unchanged).
    pub fn advance_to_nanos(&self, t_nanos: u64) {
        self.nanos.fetch_max(t_nanos, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_monotone() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_starts_at_zero_and_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(5));
        c.advance_to_nanos(7_000_000);
        assert_eq!(c.now(), Duration::from_millis(7));
    }

    #[test]
    fn virtual_clock_never_rewinds() {
        let c = VirtualClock::new();
        c.advance_to_nanos(10_000);
        c.advance_to_nanos(4_000);
        assert_eq!(c.nanos(), 10_000);
    }

    #[test]
    fn clones_share_time() {
        let a = VirtualClock::new();
        let b = a.clone();
        a.advance(Duration::from_secs(1));
        assert_eq!(b.now(), Duration::from_secs(1));
    }
}
