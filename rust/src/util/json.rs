//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Replaces `serde_json` (unavailable offline). Covers the full JSON
//! grammar except exotic float formats; used for `manifest.json`, workload
//! traces, and system-config files.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic serialisation.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers → Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of integers → Vec<usize>.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_i32(v: &[i32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ------------------------------------------------------------- writer

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------- parser

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected ',' or ']' (found {:?})", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected ',' or '}}' (found {:?})", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("ints", Json::arr_usize(&[1, 2, 3])),
            ("f", Json::Num(0.5)),
            ("s", Json::str("a \"quoted\" \\ value\n")),
            ("nested", Json::obj(vec![("x", Json::Bool(false))])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
 "config": {"vocab": 2048, "d_model": 256, "total_params": 27800832},
 "artifacts": {"gate": {"file": "gate.hlo.txt", "args": [{"shape": [256, 256], "dtype": "float32"}]}},
 "weights": {"file": "weights.bin", "dtype": "f32",
  "tensors": [{"name": "emb", "shape": [2048, 256], "offset": 0}]}
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("config").unwrap().get("vocab").unwrap().as_usize().unwrap(), 2048);
        let args = j
            .get("artifacts")
            .unwrap()
            .get("gate")
            .unwrap()
            .get("args")
            .unwrap();
        assert_eq!(args.as_arr().unwrap()[0].get("shape").unwrap().as_usize_vec().unwrap(), vec![256, 256]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_serialise_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("b").unwrap_err().to_string().contains("missing key"));
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }
}
