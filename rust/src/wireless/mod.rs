//! Wireless substrate: path loss, Rayleigh fading, Shannon rates, and
//! bandwidth allocation — the physics behind paper Eqs. (2)–(3) and the
//! upper-level optimization P3.

pub mod bandwidth;
pub mod channel;
pub mod rate;

pub use bandwidth::{BandwidthAllocator, OptimalAllocator, UniformAllocator};
pub use channel::{ChannelRealization, ChannelSimulator, LinkGains};
pub use rate::shannon_rate;
