//! Shannon-capacity link rates — paper Eqs. (2) and (3).

/// Achievable rate in bit/s over a bandwidth `b_hz` link:
///
/// `R = B · log2(1 + P·g / (N0·B))`   (paper Eqs. (2)/(3))
///
/// * `b_hz` — allocated bandwidth `B_k` (Hz)
/// * `power_w` — transmit power `P` (W)
/// * `gain` — channel power gain `g` (linear, dimensionless)
/// * `n0_w_per_hz` — noise PSD `N_0` (W/Hz)
///
/// Returns 0 for zero bandwidth — the true limit: B·log2(1+c/B) → 0 as
/// B→0+, since the log grows only logarithmically in 1/B.
pub fn shannon_rate(b_hz: f64, power_w: f64, gain: f64, n0_w_per_hz: f64) -> f64 {
    if b_hz <= 0.0 {
        return 0.0;
    }
    let snr = power_w * gain / (n0_w_per_hz * b_hz);
    b_hz * (1.0 + snr).log2()
}

/// Derivative dR/dB — used by the bandwidth optimiser's gradients.
///
/// `R'(B) = log2(1 + c/B) - (c / ln2) / (B + c)` with `c = P·g/N0`
/// (paper Eq. (28) rearranged). Positive and decreasing: R is increasing
/// and concave in B.
pub fn shannon_rate_deriv(b_hz: f64, power_w: f64, gain: f64, n0_w_per_hz: f64) -> f64 {
    let c = power_w * gain / n0_w_per_hz; // Hz
    if b_hz <= 0.0 {
        return f64::INFINITY;
    }
    (1.0 + c / b_hz).log2() - c / std::f64::consts::LN_2 / (b_hz + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    const N0: f64 = 3.98e-21;

    #[test]
    fn zero_bandwidth_zero_rate() {
        assert_eq!(shannon_rate(0.0, 10.0, 1e-9, N0), 0.0);
    }

    #[test]
    fn rate_increasing_in_bandwidth() {
        let mut prev = 0.0;
        for b in [1e6, 5e6, 10e6, 50e6, 100e6] {
            let r = shannon_rate(b, 10.0, 1e-9, N0);
            assert!(r > prev, "rate not increasing at B={b}");
            prev = r;
        }
    }

    #[test]
    fn rate_concave_in_bandwidth() {
        // midpoint test: R((a+b)/2) >= (R(a)+R(b))/2
        let (a, b) = (5e6, 80e6);
        let ra = shannon_rate(a, 10.0, 1e-9, N0);
        let rb = shannon_rate(b, 10.0, 1e-9, N0);
        let rm = shannon_rate((a + b) / 2.0, 10.0, 1e-9, N0);
        assert!(rm >= (ra + rb) / 2.0);
    }

    #[test]
    fn rate_increasing_in_power_and_gain() {
        let base = shannon_rate(10e6, 1.0, 1e-9, N0);
        assert!(shannon_rate(10e6, 2.0, 1e-9, N0) > base);
        assert!(shannon_rate(10e6, 1.0, 2e-9, N0) > base);
    }

    #[test]
    fn deriv_matches_finite_difference() {
        let (p, g) = (10.0, 1e-9);
        for b in [1e6, 12.5e6, 60e6] {
            let h = b * 1e-6;
            let fd = (shannon_rate(b + h, p, g, N0) - shannon_rate(b - h, p, g, N0)) / (2.0 * h);
            let an = shannon_rate_deriv(b, p, g, N0);
            assert!(
                (fd - an).abs() / fd.abs() < 1e-4,
                "B={b}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn deriv_positive_decreasing() {
        let (p, g) = (10.0, 1e-9);
        let d1 = shannon_rate_deriv(1e6, p, g, N0);
        let d2 = shannon_rate_deriv(50e6, p, g, N0);
        assert!(d1 > d2 && d2 > 0.0);
    }

    #[test]
    fn realistic_cell_edge_rate_sane() {
        // 12.5 MHz slice, 10 W BS, 100 m path loss at 3.5 GHz.
        let pl_db = 32.4 + 20.0 * 3.5f64.log10() + 20.0 * 100f64.log10();
        let g = 10f64.powf(-pl_db / 10.0);
        let r = shannon_rate(12.5e6, 10.0, g, N0);
        assert!(r > 50e6 && r < 1e9, "rate {r} outside sane range");
    }
}
