//! Channel model: 3GPP-style path loss + Rayleigh block fading.
//!
//! Paper §V-A: "We consider Rayleigh fading channels with a mean
//! `10^{-PL(d)/20}`, where the path loss is
//! `PL(d) (dB) = 32.4 + 20 log10(f_carrier) + 20 log10(d)`", with the
//! carrier in GHz and the distance in metres (3GPP TR 38.901 free-space
//! form). The fading amplitude is Rayleigh with the stated mean; the power
//! gain fed into the Shannon rate is the squared amplitude.

use crate::config::{ChannelConfig, DeviceConfig};
use crate::util::Rng;

/// Free-space path loss in dB (paper §V-A).
pub fn path_loss_db(distance_m: f64, carrier_ghz: f64) -> f64 {
    32.4 + 20.0 * carrier_ghz.log10() + 20.0 * distance_m.log10()
}

/// Mean fading amplitude for a device at `distance_m` — `10^{-PL/20}`.
pub fn mean_amplitude(distance_m: f64, carrier_ghz: f64) -> f64 {
    10f64.powf(-path_loss_db(distance_m, carrier_ghz) / 20.0)
}

/// Up/downlink power gains for one device in one coherence interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkGains {
    /// `g_{BS,k}` — downlink power gain.
    pub down: f64,
    /// `g_{k,BS}` — uplink power gain.
    pub up: f64,
}

/// One realization of the fading process across the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelRealization {
    pub gains: Vec<LinkGains>,
}

impl ChannelRealization {
    pub fn n_devices(&self) -> usize {
        self.gains.len()
    }
}

/// Seeded Rayleigh block-fading simulator.
///
/// `fading_blocks` in [`ChannelConfig`] sets the coherence length in MoE
/// blocks: 0 means one draw for the whole run (static channel — what the
/// paper's deterministic latency tables assume); k > 0 redraws every k
/// blocks (used for fading ablations and the testbed's channel variation).
pub struct ChannelSimulator {
    cfg: ChannelConfig,
    mean_amp: Vec<f64>,
    rng: Rng,
    current: ChannelRealization,
    blocks_since_draw: usize,
}

impl ChannelSimulator {
    pub fn new(cfg: &ChannelConfig, devices: &[DeviceConfig], seed: u64) -> Self {
        let mean_amp: Vec<f64> = devices
            .iter()
            .map(|d| mean_amplitude(d.distance_m, cfg.carrier_ghz))
            .collect();
        let mut rng = Rng::seed_from_u64(seed);
        let current = Self::draw(&mean_amp, &mut rng);
        Self {
            cfg: cfg.clone(),
            mean_amp,
            rng,
            current,
            blocks_since_draw: 0,
        }
    }

    fn draw(mean_amp: &[f64], rng: &mut Rng) -> ChannelRealization {
        let gains = mean_amp
            .iter()
            .map(|&mu| {
                let ad = rng.rayleigh_with_mean(mu);
                let au = rng.rayleigh_with_mean(mu);
                LinkGains {
                    down: ad * ad,
                    up: au * au,
                }
            })
            .collect();
        ChannelRealization { gains }
    }

    /// The realization in effect for the current MoE block.
    pub fn realization(&self) -> &ChannelRealization {
        &self.current
    }

    /// Advance one MoE block; redraws fading at coherence boundaries.
    pub fn advance_block(&mut self) {
        if self.cfg.fading_blocks == 0 {
            return; // static channel
        }
        self.blocks_since_draw += 1;
        if self.blocks_since_draw >= self.cfg.fading_blocks {
            self.current = Self::draw(&self.mean_amp, &mut self.rng);
            self.blocks_since_draw = 0;
        }
    }

    /// Deterministic expected-gain realization (no fading): power gain
    /// `E[a]^2` per link. Used by the paper-table harnesses, which model
    /// the channel through its mean as the paper's closed-form latencies do.
    pub fn expected_realization(&self) -> ChannelRealization {
        let gains = self
            .mean_amp
            .iter()
            .map(|&mu| LinkGains {
                down: mu * mu,
                up: mu * mu,
            })
            .collect();
        ChannelRealization { gains }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn sim(seed: u64) -> ChannelSimulator {
        let cfg = SystemConfig::paper_simulation();
        ChannelSimulator::new(&cfg.channel, &cfg.devices, seed)
    }

    #[test]
    fn path_loss_reference_value() {
        // 3.5 GHz, 100 m: 32.4 + 10.88 + 40.0 = 83.28 dB
        let pl = path_loss_db(100.0, 3.5);
        assert!((pl - 83.28).abs() < 0.01, "pl={pl}");
    }

    #[test]
    fn path_loss_monotone_in_distance() {
        assert!(path_loss_db(200.0, 3.5) > path_loss_db(100.0, 3.5));
        assert!(path_loss_db(100.0, 5.0) > path_loss_db(100.0, 3.5));
    }

    #[test]
    fn rayleigh_mean_matches_target() {
        // Monte-Carlo: sample mean amplitude ≈ 10^{-PL/20}.
        let mu = mean_amplitude(100.0, 3.5);
        let mut rng = Rng::seed_from_u64(42);
        let n = 200_000;
        let sum: f64 = (0..n)
            .map(|_| rng.rayleigh_with_mean(mu))
            .sum();
        let got = sum / n as f64;
        assert!(
            (got - mu).abs() / mu < 0.01,
            "mean amp {got} vs target {mu}"
        );
    }

    #[test]
    fn gains_positive_and_ordered_by_distance_in_expectation() {
        let s = sim(0);
        let exp = s.expected_realization();
        // devices are ordered by increasing distance in the preset
        for w in exp.gains.windows(2) {
            assert!(w[0].down > w[1].down);
        }
        for g in &exp.gains {
            assert!(g.down > 0.0 && g.up > 0.0);
        }
    }

    #[test]
    fn static_channel_never_redraws() {
        let mut s = sim(1);
        let before = s.realization().clone();
        for _ in 0..64 {
            s.advance_block();
        }
        assert_eq!(&before, s.realization());
    }

    #[test]
    fn fading_redraws_at_coherence_boundary() {
        let cfg = SystemConfig::paper_simulation();
        let mut ch = cfg.channel.clone();
        ch.fading_blocks = 2;
        let mut s = ChannelSimulator::new(&ch, &cfg.devices, 7);
        let first = s.realization().clone();
        s.advance_block();
        assert_eq!(&first, s.realization(), "redraw before coherence end");
        s.advance_block();
        assert_ne!(&first, s.realization(), "no redraw at coherence end");
    }

    #[test]
    fn seeded_determinism() {
        let a = sim(9).realization().clone();
        let b = sim(9).realization().clone();
        assert_eq!(a, b);
        let c = sim(10).realization().clone();
        assert_ne!(a, c);
    }

    #[test]
    fn uplink_downlink_independent() {
        let s = sim(3);
        for g in &s.realization().gains {
            assert_ne!(g.up, g.down);
        }
    }
}
