//! Bandwidth allocation strategies — the upper level of the paper's
//! bilevel optimization.
//!
//! [`UniformAllocator`] is the baseline the paper calls "the Mixtral-based
//! method ... allocates bandwidth evenly"; [`OptimalAllocator`] solves
//! problem P3 with the convex solver in [`crate::optim`].

use crate::config::ChannelConfig;
use crate::optim::solver::DeviceLink;
use crate::optim::{minimize_sum_max, PerBlockLoad, SolverOptions};
use crate::wireless::channel::ChannelRealization;

/// Context handed to an allocator: everything Eq. (19) needs.
#[derive(Debug, Clone)]
pub struct AllocationInput<'a> {
    pub channel_cfg: &'a ChannelConfig,
    pub realization: &'a ChannelRealization,
    /// Token counts `q_k^i` per block per device (the expert selection).
    pub loads: &'a [PerBlockLoad],
    /// Compute seconds per token per device (`L_comp / C_k`).
    pub t_comp_per_token: &'a [f64],
    /// Payload per token per direction in bits (`L_comm = eps·m`, Eq. (4)).
    pub l_comm_bits: f64,
}

impl AllocationInput<'_> {
    /// Number of devices `U`.
    pub fn n_devices(&self) -> usize {
        self.realization.gains.len()
    }

    /// Assemble per-device [`DeviceLink`]s for the solver / latency model.
    pub fn links(&self) -> Vec<DeviceLink> {
        let n0 = self.channel_cfg.noise_w_per_hz();
        self.realization
            .gains
            .iter()
            .zip(self.t_comp_per_token)
            .map(|(g, &tc)| DeviceLink {
                p_down: self.channel_cfg.bs_power_w,
                p_up: self.channel_cfg.device_power_w,
                g_down: g.down,
                g_up: g.up,
                n0,
                l_comm_bits: self.l_comm_bits,
                t_comp_per_token: tc,
            })
            .collect()
    }
}

/// Bandwidth allocator interface.
pub trait BandwidthAllocator: Send + Sync {
    /// Split `total_hz` across the devices; returns `B_k` summing to total.
    fn allocate(&self, input: &AllocationInput<'_>, total_hz: f64) -> Vec<f64>;
    fn name(&self) -> &'static str;
}

/// Even split `B_k = B/U` — paper baseline.
pub struct UniformAllocator;

impl BandwidthAllocator for UniformAllocator {
    fn allocate(&self, input: &AllocationInput<'_>, total_hz: f64) -> Vec<f64> {
        let u = input.n_devices();
        vec![total_hz / u as f64; u]
    }
    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Convex-optimal allocation (problem P3).
pub struct OptimalAllocator {
    pub opts: SolverOptions,
}

impl Default for OptimalAllocator {
    fn default() -> Self {
        Self {
            opts: SolverOptions::default(),
        }
    }
}

impl BandwidthAllocator for OptimalAllocator {
    fn allocate(&self, input: &AllocationInput<'_>, total_hz: f64) -> Vec<f64> {
        let links = input.links();
        minimize_sum_max(&links, input.loads, total_hz, &self.opts).bandwidth
    }
    fn name(&self) -> &'static str {
        "optimal"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::optim::solver::exact_objective;
    use crate::wireless::channel::ChannelSimulator;

    fn setup() -> (SystemConfig, ChannelRealization, Vec<f64>) {
        let cfg = SystemConfig::paper_simulation();
        let sim = ChannelSimulator::new(&cfg.channel, &cfg.devices, 0);
        let real = sim.expected_realization();
        let l_comp = cfg.model.l_comp_flops(cfg.activation_eta);
        let t_comp: Vec<f64> = cfg.devices.iter().map(|d| l_comp / d.compute_flops).collect();
        (cfg, real, t_comp)
    }

    #[test]
    fn uniform_splits_evenly() {
        let (cfg, real, t_comp) = setup();
        let loads = vec![PerBlockLoad { tokens: vec![10.0; 8] }];
        let input = AllocationInput {
            channel_cfg: &cfg.channel,
            realization: &real,
            loads: &loads,
            t_comp_per_token: &t_comp,
            l_comm_bits: cfg.model.l_comm_bits(cfg.channel.quant_bits),
        };
        let b = UniformAllocator.allocate(&input, 100e6);
        assert_eq!(b.len(), 8);
        for &bk in &b {
            assert!((bk - 12.5e6).abs() < 1e-6);
        }
    }

    #[test]
    fn optimal_beats_uniform_on_paper_fleet() {
        let (cfg, real, t_comp) = setup();
        let loads: Vec<PerBlockLoad> = (0..4)
            .map(|i| PerBlockLoad {
                tokens: (0..8).map(|k| (20 + (i * 3 + k * 5) % 40) as f64).collect(),
            })
            .collect();
        let input = AllocationInput {
            channel_cfg: &cfg.channel,
            realization: &real,
            loads: &loads,
            t_comp_per_token: &t_comp,
            l_comm_bits: cfg.model.l_comm_bits(cfg.channel.quant_bits),
        };
        let links = input.links();
        let b_uni = UniformAllocator.allocate(&input, 100e6);
        let b_opt = OptimalAllocator::default().allocate(&input, 100e6);
        let o_uni = exact_objective(&links, &loads, &b_uni);
        let o_opt = exact_objective(&links, &loads, &b_opt);
        assert!(
            o_opt < o_uni * 0.8,
            "optimal {o_opt} vs uniform {o_uni}: expected >20% gain on heterogeneous fleet"
        );
    }

    #[test]
    fn far_device_gets_more_bandwidth() {
        // With equal loads, the distance-350m device needs more spectrum
        // than the 60m one to equalise latency.
        let (cfg, real, t_comp) = setup();
        let loads = vec![PerBlockLoad { tokens: vec![50.0; 8] }];
        let input = AllocationInput {
            channel_cfg: &cfg.channel,
            realization: &real,
            loads: &loads,
            t_comp_per_token: &t_comp,
            l_comm_bits: cfg.model.l_comm_bits(cfg.channel.quant_bits),
        };
        let b = OptimalAllocator::default().allocate(&input, 100e6);
        assert!(
            b[7] > b[0],
            "far device should get more bandwidth: {b:?}"
        );
    }
}
