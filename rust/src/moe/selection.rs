//! Expert-selection policies — the lower level of the bilevel problem.
//!
//! * [`VanillaTopK`] — plain top-k on gate weights; the paper's
//!   "Mixtral-based method" baseline.
//! * [`WdmoePolicy`] — paper **Algorithm 1**: starting from top-2, drop
//!   the lowest-weight expert of tokens whose weight/latency cosine
//!   similarity falls below an escalating threshold θ, guarded by the
//!   total WLR (stop once WLR has improved by the configured factor).
//! * [`TestbedPolicy`] — paper **Algorithm 2** (§VI-C): predict per-device
//!   completion times from measured latency history, identify the
//!   bottleneck device (`> bottleneck_factor ×` third quartile), and shed
//!   its lowest-weight tokens up to the computed drop budget.
//! * [`RandomPolicy`] — uniform-random k experts; ablation sanity floor.

use super::gate::{GateWeights, Selection};
use super::wlr::total_wlr;
use crate::config::PolicyConfig;
use crate::latency::TokenLatencies;
use crate::util::Rng;

/// Everything a policy may consult when selecting experts.
pub struct SelectionContext<'a> {
    /// Per-device per-token latency estimates under *uniform* bandwidth —
    /// §IV-A: the BS "computes the latency based on (8), assuming
    /// bandwidth is evenly distributed".
    pub latencies: &'a TokenLatencies,
    /// Default routing fan-out (Mixtral: 2).
    pub top_k: usize,
    /// Devices currently online; offline devices must receive no tokens.
    pub online: &'a [bool],
}

/// Reusable buffers for [`SelectionPolicy::select_into`]: spare row
/// pools for the selection matrices (recycled across blocks of varying
/// token counts) plus the per-token cosine cache Algorithm 1 needs.
/// One instance per cell lives in the DES; at steady state a
/// `select_into` call allocates nothing.
#[derive(Default)]
pub struct SelectScratch {
    pub spare_mask: Vec<Vec<bool>>,
    pub spare_weights: Vec<Vec<f64>>,
    pub cos: Vec<f64>,
}

/// An expert-selection policy.
pub trait SelectionPolicy: Send {
    fn select(&mut self, gate: &GateWeights, ctx: &SelectionContext<'_>) -> Selection;
    /// [`Self::select`] into a reused selection. The default falls back
    /// to the allocating path (correct for every policy); the hot-path
    /// policies ([`VanillaTopK`], [`WdmoePolicy`]) override it with an
    /// allocation-free implementation producing bit-identical output.
    fn select_into(
        &mut self,
        gate: &GateWeights,
        ctx: &SelectionContext<'_>,
        out: &mut Selection,
        _scratch: &mut SelectScratch,
    ) {
        *out = self.select(gate, ctx);
    }
    fn name(&self) -> &'static str;
    /// Feed back a measured per-token latency for device `k` (Algorithm 2
    /// history; no-op for the other policies).
    fn observe(&mut self, _device: usize, _latency_per_token: f64) {}
}

/// Re-route tokens away from offline devices: any token whose selected
/// expert is offline falls back to its best online expert.
fn enforce_online(sel: &mut Selection, gate: &GateWeights, online: &[bool]) {
    let n = sel.n_experts();
    for j in 0..sel.n_tokens() {
        for k in 0..n {
            if sel.mask[j][k] && !online[k] {
                sel.mask[j][k] = false;
                sel.weights[j][k] = 0.0;
            }
        }
        if sel.fanout(j) == 0 {
            // fall back to the best online expert (constraint 16)
            if let Some(best) = (0..n)
                .filter(|&k| online[k])
                .max_by(|&a, &b| gate.weights[j][a].total_cmp(&gate.weights[j][b]))
            {
                sel.mask[j][best] = true;
                sel.weights[j][best] = gate.weights[j][best];
            }
        }
    }
}

// ------------------------------------------------------------- VanillaTopK

/// Plain top-k routing — the Mixtral baseline.
pub struct VanillaTopK;

impl SelectionPolicy for VanillaTopK {
    fn select(&mut self, gate: &GateWeights, ctx: &SelectionContext<'_>) -> Selection {
        let mut sel = Selection::empty();
        self.select_into(gate, ctx, &mut sel, &mut SelectScratch::default());
        sel
    }
    fn select_into(
        &mut self,
        gate: &GateWeights,
        ctx: &SelectionContext<'_>,
        out: &mut Selection,
        scratch: &mut SelectScratch,
    ) {
        Selection::top_k_into(
            gate,
            ctx.top_k,
            out,
            &mut scratch.spare_mask,
            &mut scratch.spare_weights,
        );
        enforce_online(out, gate, ctx.online);
    }
    fn name(&self) -> &'static str {
        "vanilla-topk"
    }
}

// ------------------------------------------------------------ WdmoePolicy

/// Paper Algorithm 1.
pub struct WdmoePolicy {
    cfg: PolicyConfig,
}

impl WdmoePolicy {
    pub fn new(cfg: PolicyConfig) -> Self {
        Self { cfg }
    }

    /// Cosine similarity between a token's gate-weight vector and the
    /// per-device latency vector (paper Eq. (18)). Both vectors are
    /// non-negative, so the value lies in [0, 1].
    pub fn cosine(weights: &[f64], lat: &[f64]) -> f64 {
        let dot: f64 = weights.iter().zip(lat).map(|(w, t)| w * t).sum();
        let nw: f64 = weights.iter().map(|w| w * w).sum::<f64>().sqrt();
        let nt: f64 = lat.iter().map(|t| t * t).sum::<f64>().sqrt();
        if nw == 0.0 || nt == 0.0 || !nt.is_finite() {
            return 0.0;
        }
        dot / (nw * nt)
    }
}

impl SelectionPolicy for WdmoePolicy {
    fn select(&mut self, gate: &GateWeights, ctx: &SelectionContext<'_>) -> Selection {
        let mut sel = Selection::empty();
        self.select_into(gate, ctx, &mut sel, &mut SelectScratch::default());
        sel
    }
    fn select_into(
        &mut self,
        gate: &GateWeights,
        ctx: &SelectionContext<'_>,
        out: &mut Selection,
        scratch: &mut SelectScratch,
    ) {
        // Line 2: start from top-2 (the trained router's own choice).
        Selection::top_k_into(
            gate,
            ctx.top_k.max(2),
            out,
            &mut scratch.spare_mask,
            &mut scratch.spare_weights,
        );
        enforce_online(out, gate, ctx.online);

        // Line 3: initial WLR under the starting selection.
        let wlr_hat = total_wlr(out, ctx.latencies);
        if wlr_hat <= 0.0 {
            return; // degenerate (all latencies infinite / no tokens)
        }

        // Token latency vectors are identical across tokens (t_{i,j,k} =
        // t_{i,k}, §III-B), and neither the gate weights nor the latency
        // estimate changes between θ rounds — precompute each token's
        // cosine once (the dominant cost at MMLU-scale batches) into the
        // reused scratch buffer.
        let lat = &ctx.latencies.per_token;
        scratch.cos.clear();
        scratch
            .cos
            .extend((0..out.n_tokens()).map(|j| Self::cosine(&gate.weights[j], lat)));

        // Lines 4–10: escalate θ until total WLR clears the guard.
        let mut theta = self.cfg.theta_init;
        loop {
            for j in 0..out.n_tokens() {
                if out.fanout(j) <= 1 {
                    continue; // constraint (16)
                }
                if scratch.cos[j] <= theta {
                    if let Some(weak) = out.weakest_expert(j) {
                        out.drop_expert(j, weak);
                    }
                }
            }
            let wlr = total_wlr(out, ctx.latencies);
            if wlr > self.cfg.wlr_guard * wlr_hat {
                break; // WLR objective met
            }
            theta += self.cfg.theta_step;
            if theta > 1.0 {
                break; // cosine of non-negative vectors never exceeds 1
            }
        }
        debug_assert!(out.validate().is_ok());
    }
    fn name(&self) -> &'static str {
        "wdmoe-alg1"
    }
}

// ----------------------------------------------------------- TestbedPolicy

/// Paper Algorithm 2 — latency-history-driven selection for the testbed.
pub struct TestbedPolicy {
    cfg: PolicyConfig,
    /// Running mean latency per token per device (Eq. (30)).
    mean_lat: Vec<f64>,
    counts: Vec<u64>,
}

impl TestbedPolicy {
    pub fn new(cfg: PolicyConfig, n_devices: usize) -> Self {
        Self {
            cfg,
            mean_lat: vec![0.0; n_devices],
            counts: vec![0; n_devices],
        }
    }

    /// Mean observed latency per token; falls back to the analytic
    /// estimate when no history exists yet (cold start).
    fn lat_estimate(&self, ctx: &SelectionContext<'_>) -> Vec<f64> {
        (0..self.mean_lat.len())
            .map(|k| {
                if self.counts[k] > 0 {
                    self.mean_lat[k]
                } else {
                    ctx.latencies.per_token[k]
                }
            })
            .collect()
    }

    /// Third quartile (linear interpolation) of a sample.
    pub fn third_quartile(values: &[f64]) -> f64 {
        assert!(!values.is_empty());
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let pos = 0.75 * (v.len() as f64 - 1.0);
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
        }
    }
}

impl SelectionPolicy for TestbedPolicy {
    fn select(&mut self, gate: &GateWeights, ctx: &SelectionContext<'_>) -> Selection {
        // Line 1: Q ← Top-K(w), K = 2.
        let mut sel = Selection::top_k(gate, ctx.top_k.max(2));
        enforce_online(&mut sel, gate, ctx.online);
        let u = sel.n_experts();

        // Lines 4–7: predict per-device completion times t̂_k = t̄_k · J_k.
        let lat = self.lat_estimate(ctx);
        let counts = sel.tokens_per_device();
        let pred: Vec<f64> = (0..u).map(|k| lat[k] * counts[k]).collect();

        // Line 8: bottleneck device.
        let khat = pred
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(k, _)| k)
            .unwrap_or(0);

        // §VI-C: act only when the bottleneck exceeds 1.5× the third
        // quartile of the *other* devices' predicted latencies (with a
        // handful of devices, an inclusive quartile is dragged up by the
        // bottleneck itself and the trigger never fires).
        let rest: Vec<f64> = pred
            .iter()
            .enumerate()
            .filter(|&(k, _)| k != khat)
            .map(|(_, &v)| v)
            .collect();
        if rest.is_empty() {
            return sel;
        }
        let q3 = Self::third_quartile(&rest);
        if !(pred[khat] > self.cfg.bottleneck_factor * q3) || lat[khat] <= 0.0 {
            return sel;
        }

        // Line 9 / Eq. (32): J_drop = floor((t̂_k̂ − t̂_q3) / t̄_k̂).
        let j_drop = ((pred[khat] - q3) / lat[khat]).floor() as usize;
        if j_drop == 0 {
            return sel;
        }

        // Lines 10–15: candidate tokens on the bottleneck device whose
        // weight is below drop_weight_frac × the device's routed mass.
        let device_mass: f64 = (0..sel.n_tokens())
            .filter(|&j| sel.mask[j][khat])
            .map(|j| sel.weights[j][khat])
            .sum();
        let thresh = self.cfg.drop_weight_frac * device_mass;
        let mut candidates: Vec<(usize, f64)> = (0..sel.n_tokens())
            .filter(|&j| sel.mask[j][khat] && sel.fanout(j) > 1)
            .filter(|&j| sel.weights[j][khat] < thresh)
            .map(|j| (j, sel.weights[j][khat]))
            .collect();

        // Lines 16–21: drop the J_drop smallest-weight candidates (all of
        // them if fewer qualify).
        candidates.sort_by(|a, b| a.1.total_cmp(&b.1));
        for &(j, _) in candidates.iter().take(j_drop) {
            sel.drop_expert(j, khat);
        }
        debug_assert!(sel.validate().is_ok());
        sel
    }

    fn name(&self) -> &'static str {
        "wdmoe-alg2-testbed"
    }

    /// Update the running mean (Eq. (30)) with a measured per-token latency.
    fn observe(&mut self, device: usize, latency_per_token: f64) {
        if !latency_per_token.is_finite() {
            return;
        }
        let c = self.counts[device] as f64;
        self.mean_lat[device] = (self.mean_lat[device] * c + latency_per_token) / (c + 1.0);
        self.counts[device] += 1;
    }
}

// ------------------------------------------------------------ RandomPolicy

/// Uniform-random k online experts per token — ablation floor.
pub struct RandomPolicy {
    rng: Rng,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed ^ 0x5e1ec7),
        }
    }
}

impl SelectionPolicy for RandomPolicy {
    fn select(&mut self, gate: &GateWeights, ctx: &SelectionContext<'_>) -> Selection {
        let n = gate.n_experts();
        let online: Vec<usize> = (0..n).filter(|&k| ctx.online[k]).collect();
        let mut mask = vec![vec![false; n]; gate.n_tokens()];
        let mut weights = vec![vec![0.0; n]; gate.n_tokens()];
        for j in 0..gate.n_tokens() {
            let mut pool = online.clone();
            for _ in 0..ctx.top_k.min(pool.len()) {
                let i = self.rng.below(pool.len());
                let k = pool.swap_remove(i);
                mask[j][k] = true;
                weights[j][k] = gate.weights[j][k];
            }
        }
        Selection { mask, weights }
    }
    fn name(&self) -> &'static str {
        "random-k"
    }
}

/// Instantiate a policy from config.
pub fn make_policy(
    kind: crate::config::PolicyKind,
    cfg: &PolicyConfig,
    n_devices: usize,
    seed: u64,
) -> Box<dyn SelectionPolicy> {
    use crate::config::PolicyKind::*;
    match kind {
        VanillaTopK => Box::new(self::VanillaTopK),
        Wdmoe => Box::new(WdmoePolicy::new(cfg.clone())),
        Testbed => Box::new(TestbedPolicy::new(cfg.clone(), n_devices)),
        Random => Box::new(RandomPolicy::new(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(rows: Vec<Vec<f64>>) -> GateWeights {
        GateWeights::new(rows)
    }

    fn ctx<'a>(lat: &'a TokenLatencies, online: &'a [bool]) -> SelectionContext<'a> {
        SelectionContext {
            latencies: lat,
            top_k: 2,
            online,
        }
    }

    fn uniform_gate(j: usize, n: usize) -> GateWeights {
        // Slightly perturbed so top-k is deterministic but non-degenerate.
        GateWeights::new(
            (0..j)
                .map(|jj| {
                    (0..n)
                        .map(|k| 1.0 / n as f64 + 1e-3 * (((jj * 7 + k * 3) % n) as f64))
                        .collect()
                })
                .collect(),
        )
    }

    #[test]
    fn vanilla_selects_exactly_top_k() {
        let g = gate(vec![vec![0.4, 0.3, 0.2, 0.1]; 5]);
        let lat = TokenLatencies { per_token: vec![1e-3; 4] };
        let online = vec![true; 4];
        let mut p = VanillaTopK;
        let s = p.select(&g, &ctx(&lat, &online));
        for j in 0..5 {
            assert_eq!(s.selected(j), vec![0, 1]);
        }
    }

    #[test]
    fn vanilla_avoids_offline_devices() {
        let g = gate(vec![vec![0.4, 0.3, 0.2, 0.1]; 3]);
        let lat = TokenLatencies { per_token: vec![1e-3; 4] };
        let online = vec![false, true, true, true];
        let mut p = VanillaTopK;
        let s = p.select(&g, &ctx(&lat, &online));
        for j in 0..3 {
            assert!(!s.mask[j][0], "token {j} routed to offline device");
            assert!(s.fanout(j) >= 1);
        }
    }

    #[test]
    fn cosine_bounds_and_alignment() {
        let w = [0.9, 0.05, 0.05];
        let aligned = [0.9, 0.05, 0.05];
        let anti = [0.05, 0.9, 0.9];
        let ca = WdmoePolicy::cosine(&w, &aligned);
        let cb = WdmoePolicy::cosine(&w, &anti);
        assert!(ca > 0.99 && ca <= 1.0 + 1e-12);
        assert!(cb < ca && cb >= 0.0);
    }

    #[test]
    fn cosine_degenerate_zero() {
        assert_eq!(WdmoePolicy::cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
        assert_eq!(WdmoePolicy::cosine(&[1.0, 1.0], &[f64::INFINITY, 1.0]), 0.0);
    }

    #[test]
    fn alg1_drops_experts_for_misaligned_tokens() {
        // Weight mass on fast devices, latency mass on slow ones ⇒ low
        // cosine ⇒ Algorithm 1 sheds the weak expert of each token.
        let g = gate(vec![vec![0.6, 0.35, 0.025, 0.025]; 16]);
        let lat = TokenLatencies {
            per_token: vec![1e-4, 1e-4, 50e-3, 50e-3],
        };
        let online = vec![true; 4];
        let mut p = WdmoePolicy::new(PolicyConfig::default());
        let s = p.select(&g, &ctx(&lat, &online));
        let fan: usize = (0..16).map(|j| s.fanout(j)).sum();
        assert!(
            fan < 32,
            "expected some drops below top-2 fanout, got {fan}"
        );
        s.validate().unwrap();
    }

    #[test]
    fn alg1_keeps_top2_for_aligned_tokens() {
        // Weights aligned with latency (both mass on device 0) ⇒ cosine
        // near 1 ⇒ no drops below θ escalation except at the very top.
        let g = gate(vec![vec![0.97, 0.01, 0.01, 0.01]; 8]);
        let lat = TokenLatencies {
            per_token: vec![50e-3, 1e-4, 1e-4, 1e-4],
        };
        let online = vec![true; 4];
        let mut p = WdmoePolicy::new(PolicyConfig {
            wlr_guard: 1e9, // never satisfied -> escalates θ to the cap
            ..PolicyConfig::default()
        });
        let s = p.select(&g, &ctx(&lat, &online));
        // θ caps at 1.0 and cosine ≈ 1 > θ is false at the last round;
        // tokens may drop at θ=1.0. What must hold: constraint (16).
        s.validate().unwrap();
    }

    #[test]
    fn alg1_never_violates_constraint_16() {
        let g = uniform_gate(64, 8);
        let lat = TokenLatencies {
            per_token: (0..8).map(|k| 1e-4 * (k + 1) as f64).collect(),
        };
        let online = vec![true; 8];
        let mut p = WdmoePolicy::new(PolicyConfig::default());
        let s = p.select(&g, &ctx(&lat, &online));
        for j in 0..64 {
            assert!(s.fanout(j) >= 1);
        }
    }

    #[test]
    fn alg1_reduces_load_vs_vanilla() {
        let g = uniform_gate(128, 8);
        let lat = TokenLatencies {
            per_token: vec![1e-4, 2e-4, 1e-3, 5e-3, 1e-2, 2e-2, 5e-2, 1e-1],
        };
        let online = vec![true; 8];
        let mut v = VanillaTopK;
        let mut w = WdmoePolicy::new(PolicyConfig::default());
        let sv = v.select(&g, &ctx(&lat, &online));
        let sw = w.select(&g, &ctx(&lat, &online));
        let load = |s: &Selection| s.tokens_per_device().iter().sum::<f64>();
        assert!(
            load(&sw) <= load(&sv),
            "Alg1 load {} should not exceed vanilla {}",
            load(&sw),
            load(&sv)
        );
    }

    #[test]
    fn third_quartile_interpolates() {
        assert_eq!(TestbedPolicy::third_quartile(&[1.0, 2.0, 3.0, 4.0]), 3.25);
        assert_eq!(TestbedPolicy::third_quartile(&[5.0]), 5.0);
        assert_eq!(TestbedPolicy::third_quartile(&[1.0, 1.0, 1.0, 10.0]), 3.25);
    }

    #[test]
    fn alg2_sheds_load_from_bottleneck() {
        let n = 4;
        // Device 3 is 100× slower — becomes the predicted bottleneck.
        let mut p = TestbedPolicy::new(PolicyConfig::default(), n);
        for _ in 0..8 {
            p.observe(0, 1e-4);
            p.observe(1, 1e-4);
            p.observe(2, 1e-4);
            p.observe(3, 1e-2);
        }
        // Tokens spread weight so device 3 is in many top-2 sets with a
        // small weight (droppable).
        let g = GateWeights::new(
            (0..32)
                .map(|j| {
                    let main = j % 3;
                    let mut row = vec![0.02; n];
                    row[main] = 0.78;
                    row[3] = 0.18;
                    row
                })
                .collect(),
        );
        let lat = TokenLatencies { per_token: vec![1e-4; n] };
        let online = vec![true; n];
        let before = Selection::top_k(&g, 2).tokens_per_device()[3];
        let s = p.select(&g, &ctx(&lat, &online));
        let after = s.tokens_per_device()[3];
        assert!(
            after < before,
            "bottleneck load should drop: {before} -> {after}"
        );
        s.validate().unwrap();
    }

    #[test]
    fn alg2_noop_when_balanced() {
        let n = 4;
        let mut p = TestbedPolicy::new(PolicyConfig::default(), n);
        for k in 0..n {
            p.observe(k, 1e-4);
        }
        let g = uniform_gate(32, n);
        let lat = TokenLatencies { per_token: vec![1e-4; n] };
        let online = vec![true; n];
        let s = p.select(&g, &ctx(&lat, &online));
        let v = Selection::top_k(&g, 2);
        assert_eq!(s.mask, v.mask, "balanced fleet must keep vanilla top-2");
    }

    #[test]
    fn alg2_history_mean_update() {
        let mut p = TestbedPolicy::new(PolicyConfig::default(), 2);
        p.observe(0, 1.0);
        p.observe(0, 3.0);
        assert_eq!(p.mean_lat[0], 2.0);
        p.observe(0, f64::INFINITY); // ignored
        assert_eq!(p.mean_lat[0], 2.0);
        assert_eq!(p.counts[0], 2);
    }

    #[test]
    fn random_policy_respects_k_and_online() {
        let g = uniform_gate(64, 8);
        let lat = TokenLatencies { per_token: vec![1e-4; 8] };
        let online = vec![true, true, false, true, true, true, true, true];
        let mut p = RandomPolicy::new(0);
        let s = p.select(&g, &ctx(&lat, &online));
        for j in 0..64 {
            assert_eq!(s.fanout(j), 2);
            assert!(!s.mask[j][2]);
        }
    }

    #[test]
    fn select_into_matches_select_for_every_policy() {
        use crate::config::PolicyKind;
        let lat = TokenLatencies {
            per_token: vec![1e-4, 2e-4, 1e-3, 5e-3, 1e-2, 2e-2, 5e-2, 1e-1],
        };
        let online = vec![true, true, false, true, true, true, true, true];
        for kind in [
            PolicyKind::VanillaTopK,
            PolicyKind::Wdmoe,
            PolicyKind::Testbed,
            PolicyKind::Random,
        ] {
            let cfg = PolicyConfig::default();
            // Two policy instances with identical state (same seed), so
            // stateful policies (Random's RNG stream) stay comparable.
            let mut a = make_policy(kind, &cfg, 8, 3);
            let mut b = make_policy(kind, &cfg, 8, 3);
            let mut out = Selection::empty();
            let mut scratch = SelectScratch::default();
            // Varying token counts exercise the scratch reshaping.
            for tokens in [48usize, 16, 64] {
                let g = uniform_gate(tokens, 8);
                let fresh = a.select(&g, &ctx(&lat, &online));
                b.select_into(&g, &ctx(&lat, &online), &mut out, &mut scratch);
                assert_eq!(out.mask, fresh.mask, "{kind:?} tokens={tokens}");
                assert_eq!(out.weights, fresh.weights, "{kind:?} tokens={tokens}");
            }
        }
    }

    #[test]
    fn make_policy_dispatches() {
        use crate::config::PolicyKind;
        let cfg = PolicyConfig::default();
        assert_eq!(make_policy(PolicyKind::VanillaTopK, &cfg, 4, 0).name(), "vanilla-topk");
        assert_eq!(make_policy(PolicyKind::Wdmoe, &cfg, 4, 0).name(), "wdmoe-alg1");
        assert_eq!(make_policy(PolicyKind::Testbed, &cfg, 4, 0).name(), "wdmoe-alg2-testbed");
        assert_eq!(make_policy(PolicyKind::Random, &cfg, 4, 0).name(), "random-k");
    }
}
