//! Weight-to-latency ratio (WLR) — paper Eq. (12).
//!
//! `WLR_k^i = (Σ_j q_{j,k}^i w_{j,k}^i) / t_k^i` quantifies, from device
//! k's perspective, how much routing weight it delivers per second of
//! completion time. The lower-level problem P2 maximises `Σ_i Σ_k WLR_k^i`;
//! Algorithm 1 uses the total WLR as the guard that stops threshold
//! escalation.

use super::gate::Selection;
use crate::latency::TokenLatencies;

/// `WLR_k` for a single device in one block. Devices with no tokens have
/// zero completion time; their WLR is defined as 0 (they deliver no
/// weight and consume no time).
pub fn device_wlr(sel: &Selection, lat: &TokenLatencies, k: usize) -> f64 {
    let weight_sum: f64 = (0..sel.n_tokens())
        .filter(|&j| sel.mask[j][k])
        .map(|j| sel.weights[j][k])
        .sum();
    let count = sel
        .mask
        .iter()
        .filter(|row| row[k])
        .count() as f64;
    if count == 0.0 {
        return 0.0;
    }
    let t_k = count * lat.per_token[k]; // Eq. (10)
    if t_k <= 0.0 || !t_k.is_finite() {
        return 0.0;
    }
    weight_sum / t_k
}

/// `Σ_k WLR_k^i` for one block.
pub fn total_wlr(sel: &Selection, lat: &TokenLatencies) -> f64 {
    (0..sel.n_experts()).map(|k| device_wlr(sel, lat, k)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::gate::GateWeights;

    fn setup() -> (Selection, TokenLatencies) {
        let gate = GateWeights::new(vec![
            vec![0.5, 0.3, 0.2],
            vec![0.2, 0.6, 0.2],
        ]);
        let sel = Selection::top_k(&gate, 2);
        let lat = TokenLatencies {
            per_token: vec![1e-3, 2e-3, 4e-3],
        };
        (sel, lat)
    }

    #[test]
    fn wlr_matches_hand_computation() {
        let (sel, lat) = setup();
        // device 0: tokens {0 (w=.5), 1 (w=.2)} -> t_0 = 2 * 1e-3
        let w0 = device_wlr(&sel, &lat, 0);
        assert!((w0 - 0.7 / 2e-3).abs() < 1e-9);
        // device 1: tokens {0 (.3), 1 (.6)} -> t_1 = 2 * 2e-3
        let w1 = device_wlr(&sel, &lat, 1);
        assert!((w1 - 0.9 / 4e-3).abs() < 1e-9);
        // device 2: no tokens after top-2
        assert_eq!(device_wlr(&sel, &lat, 2), 0.0);
    }

    #[test]
    fn total_is_sum() {
        let (sel, lat) = setup();
        let t = total_wlr(&sel, &lat);
        let s: f64 = (0..3).map(|k| device_wlr(&sel, &lat, k)).sum();
        assert_eq!(t, s);
    }

    #[test]
    fn dropping_slow_low_weight_token_raises_wlr() {
        // Token with tiny weight on a slow device: removing it should
        // increase that device's WLR (the Algorithm-1 premise).
        let gate = GateWeights::new(vec![
            vec![0.55, 0.45],
            vec![0.95, 0.05],
        ]);
        let mut sel = Selection::top_k(&gate, 2);
        let lat = TokenLatencies {
            per_token: vec![1e-3, 8e-3],
        };
        let before = device_wlr(&sel, &lat, 1);
        assert!(sel.drop_expert(1, 1)); // token 1 drops expert 1 (w=0.05)
        let after = device_wlr(&sel, &lat, 1);
        assert!(after > before, "WLR should rise: {before} -> {after}");
    }

    #[test]
    fn infinite_latency_device_has_zero_wlr() {
        let (sel, _) = setup();
        let lat = TokenLatencies {
            per_token: vec![f64::INFINITY, 1e-3, 1e-3],
        };
        assert_eq!(device_wlr(&sel, &lat, 0), 0.0);
    }
}
