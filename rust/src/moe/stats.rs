//! Routing statistics — the §V-D deployment insight (paper Fig. 8).
//!
//! Fig. 8 plots, per MoE layer, "the maximum ratio of the same expert
//! selection in one batch": the share of tokens whose *selected expert
//! set* coincides with the most common selected set. High values mean
//! co-deploying those experts on one device would cut duplicate token
//! transmissions (§V-D).

use super::gate::Selection;
use std::collections::BTreeMap;

/// Fraction of tokens sharing the most frequent expert-selection set.
pub fn max_same_selection_ratio(sel: &Selection) -> f64 {
    if sel.n_tokens() == 0 {
        return 0.0;
    }
    let mut counts: BTreeMap<Vec<usize>, usize> = BTreeMap::new();
    for j in 0..sel.n_tokens() {
        *counts.entry(sel.selected(j)).or_insert(0) += 1;
    }
    let max = counts.values().copied().max().unwrap_or(0);
    max as f64 / sel.n_tokens() as f64
}

/// Full histogram of expert-selection sets (set → token count), sorted
/// by count descending then key ascending — used by the Fig. 8 harness
/// for its per-layer breakdown. The sort key is total, so the output
/// order is a pure function of the selection: equal-count sets used to
/// land in `HashMap` iteration order, which varies run to run.
pub fn selection_histogram(sel: &Selection) -> Vec<(Vec<usize>, usize)> {
    let mut counts: BTreeMap<Vec<usize>, usize> = BTreeMap::new();
    for j in 0..sel.n_tokens() {
        *counts.entry(sel.selected(j)).or_insert(0) += 1;
    }
    let mut v: Vec<(Vec<usize>, usize)> = counts.into_iter().collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    v
}

/// Expert-pair co-selection: for top-2 routing, how often each unordered
/// pair appears; the §V-D placement hint ("deploy the two most frequently
/// selected expert networks for the same token" together).
pub fn pair_frequencies(sel: &Selection) -> Vec<((usize, usize), usize)> {
    let mut counts: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for j in 0..sel.n_tokens() {
        let sset = sel.selected(j);
        for a in 0..sset.len() {
            for b in (a + 1)..sset.len() {
                let key = (sset[a].min(sset[b]), sset[a].max(sset[b]));
                *counts.entry(key).or_insert(0) += 1;
            }
        }
    }
    let mut v: Vec<((usize, usize), usize)> = counts.into_iter().collect();
    // Total order (count desc, pair asc): ties between equally frequent
    // pairs break deterministically instead of by hash-iteration order.
    v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::gate::GateWeights;

    fn sel_from_masks(masks: Vec<Vec<bool>>) -> Selection {
        let n = masks[0].len();
        let weights = masks
            .iter()
            .map(|row| row.iter().map(|&b| if b { 0.5 } else { 0.0 }).collect())
            .collect();
        let _ = n;
        Selection { mask: masks, weights }
    }

    #[test]
    fn all_same_selection_ratio_one() {
        let s = sel_from_masks(vec![vec![true, true, false, false]; 10]);
        assert_eq!(max_same_selection_ratio(&s), 1.0);
    }

    #[test]
    fn distinct_selections_ratio_fraction() {
        let s = sel_from_masks(vec![
            vec![true, true, false, false],
            vec![true, true, false, false],
            vec![false, false, true, true],
            vec![false, true, true, false],
        ]);
        assert_eq!(max_same_selection_ratio(&s), 0.5);
    }

    #[test]
    fn empty_selection_zero() {
        let s = Selection {
            mask: vec![],
            weights: vec![],
        };
        assert_eq!(max_same_selection_ratio(&s), 0.0);
    }

    #[test]
    fn histogram_sorted_and_complete() {
        let s = sel_from_masks(vec![
            vec![true, true],
            vec![true, true],
            vec![true, false],
        ]);
        let h = selection_histogram(&s);
        assert_eq!(h[0], (vec![0, 1], 2));
        assert_eq!(h[1], (vec![0], 1));
        let total: usize = h.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn pair_frequencies_counts_unordered() {
        let g = GateWeights::new(vec![
            vec![0.5, 0.4, 0.1],
            vec![0.4, 0.5, 0.1],
            vec![0.1, 0.5, 0.4],
        ]);
        let s = Selection::top_k(&g, 2);
        let pf = pair_frequencies(&s);
        assert_eq!(pf[0], ((0, 1), 2));
        assert_eq!(pf[1], ((1, 2), 1));
    }

    #[test]
    fn tie_order_is_deterministic_under_shuffle() {
        // Five distinct selection sets over eight tokens, three of them
        // with count 2 and two with count 1: the count key ties in both
        // groups, so only the secondary (key-ascending) ordering keeps
        // the output stable. Feeding the same tokens in a different
        // order must produce the identical histogram and pair list.
        let masks: Vec<Vec<bool>> = (0..8usize)
            .map(|i| {
                (0..5)
                    .map(|e| e == i % 5 || e == (i + 2) % 5)
                    .collect::<Vec<bool>>()
            })
            .collect();
        let mut shuffled = masks.clone();
        shuffled.reverse();
        shuffled.swap(1, 5);
        shuffled.swap(2, 7);
        let a = sel_from_masks(masks);
        let b = sel_from_masks(shuffled);
        assert_eq!(selection_histogram(&a), selection_histogram(&b));
        assert_eq!(pair_frequencies(&a), pair_frequencies(&b));
        // And the tie-break itself: counts descending, keys ascending
        // within equal counts.
        let h = selection_histogram(&a);
        for w in h.windows(2) {
            assert!(
                w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                "histogram not in (count desc, key asc) order: {w:?}"
            );
        }
        let pf = pair_frequencies(&a);
        for w in pf.windows(2) {
            assert!(
                w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                "pairs not in (count desc, key asc) order: {w:?}"
            );
        }
    }

    #[test]
    fn mixed_fanout_handled() {
        // top-1 tokens contribute no pairs but count in the histogram
        let s = sel_from_masks(vec![vec![true, false], vec![true, true]]);
        assert_eq!(pair_frequencies(&s), vec![((0, 1), 1)]);
        assert_eq!(selection_histogram(&s).len(), 2);
    }
}
