//! MoE routing: gate weights, the weight-to-latency ratio, and the
//! expert-selection policies (the lower level of the bilevel problem).

pub mod gate;
pub mod selection;
pub mod stats;
pub mod wlr;

pub use gate::{GateWeights, Selection};
pub use selection::{
    RandomPolicy, SelectionContext, SelectionPolicy, TestbedPolicy, VanillaTopK, WdmoePolicy,
};
pub use wlr::{device_wlr, total_wlr};
