//! Gate-weight containers and top-k utilities.

/// Router output for one MoE block: `w_{j,k}` per token per expert
/// (paper §II-A; rows are softmax distributions over the n experts).
#[derive(Debug, Clone, PartialEq)]
pub struct GateWeights {
    /// J × n, row-major.
    pub weights: Vec<Vec<f64>>,
}

impl GateWeights {
    pub fn new(weights: Vec<Vec<f64>>) -> Self {
        debug_assert!(weights.iter().all(|r| r.len() == weights[0].len()));
        Self { weights }
    }

    /// Build from a flat row-major f32 buffer (the PJRT gate output).
    pub fn from_flat(flat: &[f32], n_tokens: usize, n_experts: usize) -> Self {
        assert_eq!(flat.len(), n_tokens * n_experts);
        Self {
            weights: (0..n_tokens)
                .map(|j| {
                    flat[j * n_experts..(j + 1) * n_experts]
                        .iter()
                        .map(|&w| w as f64)
                        .collect()
                })
                .collect(),
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.weights.len()
    }

    pub fn n_experts(&self) -> usize {
        self.weights.first().map_or(0, |r| r.len())
    }

    /// Indices of the top-k experts of token `j`, best first.
    pub fn top_k(&self, j: usize, k: usize) -> Vec<usize> {
        let row = &self.weights[j];
        if k == 1 || k == 2 {
            // Hot path (Mixtral top-2): single pass, no allocation churn.
            let mut best = 0usize;
            for (i, &w) in row.iter().enumerate() {
                if w > row[best] {
                    best = i;
                }
            }
            if k == 1 {
                return vec![best];
            }
            let mut second = usize::MAX;
            for (i, &w) in row.iter().enumerate() {
                if i != best && (second == usize::MAX || w > row[second]) {
                    second = i;
                }
            }
            return if second == usize::MAX {
                vec![best]
            } else {
                vec![best, second]
            };
        }
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
        idx.truncate(k);
        idx
    }
}

/// An expert selection `Q^i` for one block: mask + the effective weights
/// (gate weights zeroed where dropped; renormalisation happens in the
/// combine artifact, matching Eq. (1) with the adjusted weights).
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    /// `q_{j,k}` — J × n boolean routing matrix.
    pub mask: Vec<Vec<bool>>,
    /// Effective weights after selection (0 where dropped).
    pub weights: Vec<Vec<f64>>,
}

impl Selection {
    /// Top-k selection from gate weights — the Mixtral baseline.
    pub fn top_k(gate: &GateWeights, k: usize) -> Self {
        let n = gate.n_experts();
        let mut mask = vec![vec![false; n]; gate.n_tokens()];
        let mut weights = vec![vec![0.0; n]; gate.n_tokens()];
        for j in 0..gate.n_tokens() {
            for &e in &gate.top_k(j, k) {
                mask[j][e] = true;
                weights[j][e] = gate.weights[j][e];
            }
        }
        Self { mask, weights }
    }

    /// An empty selection shell for use as reusable scratch with
    /// [`Self::top_k_into`].
    pub fn empty() -> Self {
        Self {
            mask: Vec::new(),
            weights: Vec::new(),
        }
    }

    /// [`Self::top_k`] into a reused selection, recycling row buffers
    /// through the caller's spare pools — allocation-free at steady
    /// state, and bit-identical to the allocating constructor: top-k by
    /// repeated strict argmax picks the same experts, in the same order,
    /// as the stable descending sort (ties fall to the lowest index in
    /// both).
    pub fn top_k_into(
        gate: &GateWeights,
        k: usize,
        out: &mut Self,
        spare_mask: &mut Vec<Vec<bool>>,
        spare_weights: &mut Vec<Vec<f64>>,
    ) {
        let n = gate.n_experts();
        let j_tokens = gate.n_tokens();
        crate::util::reshape_rows(&mut out.mask, spare_mask, j_tokens, n, false);
        crate::util::reshape_rows(&mut out.weights, spare_weights, j_tokens, n, 0.0);
        for j in 0..j_tokens {
            let row = &gate.weights[j];
            for _ in 0..k.min(n) {
                let mut best: Option<usize> = None;
                for (e, &w) in row.iter().enumerate() {
                    if out.mask[j][e] {
                        continue; // already picked in an earlier pass
                    }
                    let better = match best {
                        None => true,
                        Some(b) => w > row[b],
                    };
                    if better {
                        best = Some(e);
                    }
                }
                if let Some(b) = best {
                    out.mask[j][b] = true;
                    out.weights[j][b] = row[b];
                }
            }
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.mask.len()
    }

    pub fn n_experts(&self) -> usize {
        self.mask.first().map_or(0, |r| r.len())
    }

    /// Experts currently selected for token `j`.
    pub fn selected(&self, j: usize) -> Vec<usize> {
        (0..self.n_experts()).filter(|&k| self.mask[j][k]).collect()
    }

    /// Number of experts selected for token `j`.
    pub fn fanout(&self, j: usize) -> usize {
        self.mask[j].iter().filter(|&&b| b).count()
    }

    /// Drop expert `k` for token `j` ("assigning a weight of zero to that
    /// expert", §IV-A). Refuses to violate constraint (16): every token
    /// keeps at least one expert. Returns whether the drop happened.
    pub fn drop_expert(&mut self, j: usize, k: usize) -> bool {
        if !self.mask[j][k] || self.fanout(j) <= 1 {
            return false;
        }
        self.mask[j][k] = false;
        self.weights[j][k] = 0.0;
        true
    }

    /// The lowest-weight currently-selected expert of token `j`.
    /// A single strict-`<` scan: no allocation (this runs per drop in
    /// the Algorithm 1 escalation loop), and the first minimum wins —
    /// the same tie-break as `Iterator::min_by` over ascending indices.
    pub fn weakest_expert(&self, j: usize) -> Option<usize> {
        let mut weak: Option<usize> = None;
        for k in 0..self.n_experts() {
            if !self.mask[j][k] {
                continue;
            }
            let weaker = match weak {
                None => true,
                Some(w) => self.weights[j][k] < self.weights[j][w],
            };
            if weaker {
                weak = Some(k);
            }
        }
        weak
    }

    /// Token counts per device — Eq. (9).
    pub fn tokens_per_device(&self) -> Vec<f64> {
        crate::latency::tokens_per_device(&self.mask, self.n_experts())
    }

    /// [`Self::tokens_per_device`] into a reused buffer (cleared first).
    pub fn tokens_per_device_into(&self, counts: &mut Vec<f64>) {
        crate::latency::tokens_per_device_into(&self.mask, self.n_experts(), counts)
    }

    /// Invariant check: constraint (16) — every token on ≥1 device, and
    /// weights are zero exactly off the mask.
    pub fn validate(&self) -> Result<(), String> {
        for j in 0..self.n_tokens() {
            if self.fanout(j) == 0 {
                return Err(format!("token {j} has no expert (constraint 16)"));
            }
            for k in 0..self.n_experts() {
                if !self.mask[j][k] && self.weights[j][k] != 0.0 {
                    return Err(format!("token {j}: weight off-mask at expert {k}"));
                }
            }
        }
        Ok(())
    }

    /// Flatten the mask to f32 row-major — the `combine` artifact input.
    pub fn mask_flat_f32(&self) -> Vec<f32> {
        self.mask
            .iter()
            .flat_map(|row| row.iter().map(|&b| if b { 1.0 } else { 0.0 }))
            .collect()
    }

    /// Flatten effective weights to f32 row-major.
    pub fn weights_flat_f32(&self) -> Vec<f32> {
        self.weights
            .iter()
            .flat_map(|row| row.iter().map(|&w| w as f32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate() -> GateWeights {
        GateWeights::new(vec![
            vec![0.4, 0.3, 0.2, 0.1],
            vec![0.1, 0.1, 0.1, 0.7],
            vec![0.25, 0.25, 0.25, 0.25],
        ])
    }

    #[test]
    fn top_k_orders_by_weight() {
        let g = gate();
        assert_eq!(g.top_k(0, 2), vec![0, 1]);
        assert_eq!(g.top_k(1, 2), vec![3, 0]);
        assert_eq!(g.top_k(1, 1), vec![3]);
    }

    #[test]
    fn from_flat_roundtrip() {
        let flat: Vec<f32> = vec![0.1, 0.9, 0.8, 0.2];
        let g = GateWeights::from_flat(&flat, 2, 2);
        assert_eq!(g.n_tokens(), 2);
        assert!((g.weights[0][1] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn selection_top2_masks_and_weights() {
        let s = Selection::top_k(&gate(), 2);
        assert_eq!(s.selected(0), vec![0, 1]);
        assert_eq!(s.weights[0][2], 0.0);
        assert_eq!(s.weights[0][0], 0.4);
        s.validate().unwrap();
    }

    #[test]
    fn drop_respects_constraint_16() {
        let mut s = Selection::top_k(&gate(), 2);
        assert!(s.drop_expert(0, 1));
        assert_eq!(s.fanout(0), 1);
        // cannot drop the last expert
        assert!(!s.drop_expert(0, 0));
        assert_eq!(s.fanout(0), 1);
        s.validate().unwrap();
    }

    #[test]
    fn drop_unselected_is_noop() {
        let mut s = Selection::top_k(&gate(), 2);
        assert!(!s.drop_expert(0, 3));
    }

    #[test]
    fn top_k_into_matches_allocating_top_k() {
        // Includes ties (uniform row) so the argmax/stable-sort
        // tie-break equivalence is actually exercised, and k > 2 so the
        // sort path is covered too.
        let g = gate();
        let mut out = Selection::empty();
        let mut spare_mask = Vec::new();
        let mut spare_weights = Vec::new();
        for k in 1..=4 {
            Selection::top_k_into(&g, k, &mut out, &mut spare_mask, &mut spare_weights);
            let fresh = Selection::top_k(&g, k);
            assert_eq!(out.mask, fresh.mask, "k={k}");
            assert_eq!(out.weights, fresh.weights, "k={k}");
        }
        // Shrinking to a smaller gate reuses the scratch correctly.
        let small = GateWeights::new(vec![vec![0.2, 0.8]]);
        Selection::top_k_into(&small, 1, &mut out, &mut spare_mask, &mut spare_weights);
        assert_eq!(out.mask, Selection::top_k(&small, 1).mask);
        assert_eq!(out.n_tokens(), 1);
    }

    #[test]
    fn weakest_expert_is_lowest_weight_selected() {
        let s = Selection::top_k(&gate(), 2);
        assert_eq!(s.weakest_expert(0), Some(1));
        assert_eq!(s.weakest_expert(1), Some(0));
    }

    #[test]
    fn token_counts_match_mask() {
        let s = Selection::top_k(&gate(), 2);
        let c = s.tokens_per_device();
        // token0 -> {0,1}, token1 -> {3,0}, token2 -> top2 of uniform = first two by sort order
        assert_eq!(c.iter().sum::<f64>(), 6.0);
    }

    #[test]
    fn flat_f32_shapes() {
        let s = Selection::top_k(&gate(), 2);
        assert_eq!(s.mask_flat_f32().len(), 12);
        assert_eq!(s.weights_flat_f32().len(), 12);
    }

    #[test]
    fn validate_catches_empty_token() {
        let mut s = Selection::top_k(&gate(), 1);
        s.mask[1] = vec![false; 4];
        assert!(s.validate().is_err());
    }
}
